"""Aggregate dry-run artifacts into the §Roofline table (deliverable (g)).

Reads benchmarks/artifacts/dryrun/*.json (produced by
`python -m repro.launch.dryrun --all`) and emits:
  * CSV lines for benchmarks.run,
  * benchmarks/artifacts/roofline_table.md — the EXPERIMENTS.md table.
"""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, csv_line


# v5e single-core peaks used by the analytic pair-apply cells below
_V5E_F32_FLOPS = 9.85e13   # MXU f32 (half the 197 TF bf16 figure)
_V5E_HBM_BPS = 8.19e11


def pair_apply_roofline(
    sweep=((64, 16), (64, 64), (256, 16), (256, 64)),
    B: int = 256, V: int = 2,
) -> list[str]:
    """Analytic roofline cells for the presampled-schedule value pass
    (schedule length T x cell size C): modeled HBM traffic, flops, and
    arithmetic intensity for the three backends.

    * lax (XLA scan): the (B, C, V) state round-trips memory every tick
      (the select-based row update materializes the full state), so
      bytes grow with T while flops stay tiny — deep in the
      memory-bound regime;
    * pallas pair_apply: one state load + one store per chunk plus the
      SMEM schedule — traffic is T-independent, which is the whole
      point of walking the schedule in VMEM;
    * matmul composition: log2(T) batched (C, C) GEMMs trade extra
      flops for MXU-shaped work (intensity grows with C).
    """
    rows = []
    out = {}
    for T, C in sweep:
        state_b = B * C * V * 4
        sched_b = 4 * T * B * 4
        flops_apply = T * B * 2 * V
        cells = {
            "lax": (2 * T * state_b, flops_apply),
            "pallas": (2 * state_b + sched_b, flops_apply),
            "matmul": (
                T * B * C * C * 4 + 2 * state_b,
                (T - 1) * B * 2 * C**3 + B * 2 * C * C * V,
            ),
        }
        for name, (bytes_, flops) in cells.items():
            ai = flops / bytes_
            t_mem = bytes_ / _V5E_HBM_BPS
            t_cmp = flops / _V5E_F32_FLOPS
            bound = "compute" if t_cmp > t_mem else "memory"
            out[f"T{T}_C{C}_{name}"] = {
                "bytes": bytes_, "flops": flops, "intensity": ai,
                "bound": bound, "modeled_us": max(t_mem, t_cmp) * 1e6,
            }
            rows.append(csv_line(
                f"roofline/pair_apply_T{T}_C{C}_{name}",
                max(t_mem, t_cmp) * 1e6,
                f"B={B} bytes={bytes_/1e6:.2f}MB flops={flops/1e6:.2f}MF "
                f"AI={ai:.3f} bound={bound}",
            ))
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "pair_apply_roofline.json"), "w") as f:
        json.dump({"B": B, "V": V, "cells": out}, f, indent=1)
    rows.append(csv_line(
        "roofline/pair_apply_table", 0.0,
        f"cells={len(out)} -> benchmarks/artifacts/pair_apply_roofline.json",
    ))
    return rows


def run() -> list[str]:
    lines = pair_apply_roofline()
    paths = sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*.json")))
    if not paths:
        return lines + [csv_line("roofline/missing", 0.0,
                        "run `python -m repro.launch.dryrun --all` first")]
    rows = []
    for p in paths:
        r = json.load(open(p))
        if r["status"] != "ok" or "roofline" not in r:
            rows.append(r)
            continue
        rf = r["roofline"]
        terms = {
            "compute": rf["compute_s"],
            "memory": rf["memory_s"],
            "collective": rf["collective_s"],
        }
        dom = rf["dominant"]
        total = max(sum(terms.values()), 1e-30)
        # roofline fraction: share of the (serial-sum) step bound that is
        # compute at peak — 1.0 == perfectly compute-bound at roofline
        frac = terms["compute"] / total
        r["_summary"] = {
            "terms": terms, "dominant": dom, "roofline_fraction": frac,
            "model_ratio": rf.get("model_flops_ratio", 0.0),
        }
        rows.append(r)
        if r["mesh"] == "pod16x16":  # assignment: roofline table single-pod
            lines.append(csv_line(
                f"roofline/{r['arch']}__{r['shape']}", 0.0,
                f"c={terms['compute']*1e3:.1f}ms m={terms['memory']*1e3:.1f}ms "
                f"x={terms['collective']*1e3:.1f}ms dom={dom} "
                f"frac={frac:.2f} model_ratio={r['_summary']['model_ratio']:.2f}",
            ))

    md = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) |"
        " dominant | MODEL/HLO flops | fits 16GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip":
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" skipped: {r['reason']} | — | — |"
            )
            continue
        if r["status"] != "ok":
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" ERROR | — | — |"
            )
            continue
        s = r.get("_summary")
        fits = r["memory"]["fits_16GiB"]
        if s is None:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" compile-only | — | {fits} |"
            )
            continue
        t = s["terms"]
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {t['compute']:.3f} | {t['memory']:.3f} | {t['collective']:.4f} |"
            f" {s['dominant']} | {s['model_ratio']:.2f} | {fits} |"
        )
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "roofline_table.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    lines.append(csv_line(
        "roofline/table", 0.0,
        f"cells={len(rows)} -> benchmarks/artifacts/roofline_table.md",
    ))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
