"""Aggregate dry-run artifacts into the §Roofline table (deliverable (g)).

Reads benchmarks/artifacts/dryrun/*.json (produced by
`python -m repro.launch.dryrun --all`) and emits:
  * CSV lines for benchmarks.run,
  * benchmarks/artifacts/roofline_table.md — the EXPERIMENTS.md table.
"""
from __future__ import annotations

import glob
import json
import os

from .common import ARTIFACTS, csv_line


def run() -> list[str]:
    paths = sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun", "*.json")))
    if not paths:
        return [csv_line("roofline/missing", 0.0,
                         "run `python -m repro.launch.dryrun --all` first")]
    rows, lines = [], []
    for p in paths:
        r = json.load(open(p))
        if r["status"] != "ok" or "roofline" not in r:
            rows.append(r)
            continue
        rf = r["roofline"]
        terms = {
            "compute": rf["compute_s"],
            "memory": rf["memory_s"],
            "collective": rf["collective_s"],
        }
        dom = rf["dominant"]
        total = max(sum(terms.values()), 1e-30)
        # roofline fraction: share of the (serial-sum) step bound that is
        # compute at peak — 1.0 == perfectly compute-bound at roofline
        frac = terms["compute"] / total
        r["_summary"] = {
            "terms": terms, "dominant": dom, "roofline_fraction": frac,
            "model_ratio": rf.get("model_flops_ratio", 0.0),
        }
        rows.append(r)
        if r["mesh"] == "pod16x16":  # assignment: roofline table single-pod
            lines.append(csv_line(
                f"roofline/{r['arch']}__{r['shape']}", 0.0,
                f"c={terms['compute']*1e3:.1f}ms m={terms['memory']*1e3:.1f}ms "
                f"x={terms['collective']*1e3:.1f}ms dom={dom} "
                f"frac={frac:.2f} model_ratio={r['_summary']['model_ratio']:.2f}",
            ))

    md = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) |"
        " dominant | MODEL/HLO flops | fits 16GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "skip":
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" skipped: {r['reason']} | — | — |"
            )
            continue
        if r["status"] != "ok":
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" ERROR | — | — |"
            )
            continue
        s = r.get("_summary")
        fits = r["memory"]["fits_16GiB"]
        if s is None:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — |"
                f" compile-only | — | {fits} |"
            )
            continue
        t = s["terms"]
        md.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} |"
            f" {t['compute']:.3f} | {t['memory']:.3f} | {t['collective']:.4f} |"
            f" {s['dominant']} | {s['model_ratio']:.2f} | {fits} |"
        )
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "roofline_table.md"), "w") as f:
        f.write("\n".join(md) + "\n")
    lines.append(csv_line(
        "roofline/table", 0.0,
        f"cells={len(rows)} -> benchmarks/artifacts/roofline_table.md",
    ))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
