"""BENCH_gossip.json — the standardized gossip perf-trajectory artifact.

Every entry snapshots the simulation hot path's measured performance at
one commit: the fig3 smoke wall-clocks per engine backend (from the
backend-suffixed smoke artifacts `fig3_smoke_lax` / `fig3_smoke_pallas`)
plus the pair-apply kernel microbenchmark sweep.  The file lives at the
repo root and is append-only (one entry per (commit, label);
re-running replaces that entry), so future PRs diff their numbers
against a measured baseline instead of an empty trajectory.

The fig3 numbers are read from whatever smoke artifacts are on disk —
regenerate them FIRST so the entry reflects the code being stamped
(`REPRO_BENCH_SMOKE=1 tools/ci.sh` does this in the right order).
Entries measured on an uncommitted tree are stamped `<sha>-dirty`.

    python -m benchmarks.gossip_trajectory [--label msg] [--no-kernels]

Also exposed as the `gossip` suite in `benchmarks.run`.
"""
from __future__ import annotations

import glob
import json
import os
import subprocess
import time

from .common import ARTIFACTS, csv_line, load_artifact

TRAJECTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_gossip.json",
)
SMOKE_ARTIFACTS = {"lax": "fig3_smoke_lax", "pallas": "fig3_smoke_pallas"}


def _git_commit() -> str:
    """Short HEAD sha, suffixed `-dirty` when the working tree differs
    from it — measurements from uncommitted trees must not masquerade
    as the clean commit's record."""
    repo = os.path.dirname(TRAJECTORY)
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10, cwd=repo,
        ).stdout.strip() or "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--untracked-files=no"],
            capture_output=True, text=True, timeout=10, cwd=repo,
        ).stdout.strip()
        return f"{sha}-dirty" if dirty else sha
    except Exception:
        return "unknown"


def load_trajectory() -> list:
    if not os.path.exists(TRAJECTORY):
        return []
    return json.load(open(TRAJECTORY))


def validate_entry(entry: dict) -> None:
    """Reject a malformed NEW entry before it lands in the trajectory.

    Every new entry must stamp a real `unix_time` and every present
    fig3 smoke record must carry the measured `jit_warmup_s` — `null`
    placeholders made the earliest entries useless for warmup-cost
    trend lines.  Historical entries already in the file are NOT
    backfilled or re-validated; the gate applies at append time only.
    """
    ut = entry.get("unix_time")
    if not isinstance(ut, int) or ut <= 0:
        raise ValueError(f"gossip_trajectory entry missing unix_time: {ut!r}")
    for backend, rec in entry.get("fig3_smoke", {}).items():
        if "missing" in rec:
            continue
        if not isinstance(rec.get("jit_warmup_s"), (int, float)):
            raise ValueError(
                f"gossip_trajectory entry fig3_smoke[{backend!r}] lacks "
                f"jit_warmup_s — regenerate the smoke artifact "
                f"(REPRO_BENCH_SMOKE=1 tools/ci.sh) before recording"
            )


def record_entry(entry: dict) -> None:
    """Append `entry`, replacing any prior entry for the same
    (commit, label) — re-running at one commit updates in place while
    distinct labels (e.g. a pinned baseline) survive.  New entries are
    validated (`validate_entry`); the historical tail is left as-is."""
    validate_entry(entry)
    key = (entry["commit"], entry.get("label", ""))
    traj = [
        e for e in load_trajectory()
        if (e.get("commit"), e.get("label", "")) != key
    ]
    traj.append(entry)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)


def build_entry(label: str = "", kernels: bool = True) -> dict:
    entry = {
        "commit": _git_commit(),
        "unix_time": int(time.time()),
        "label": label,
        "fig3_smoke": {},
    }
    for backend, name in SMOKE_ARTIFACTS.items():
        art = load_artifact(name)
        if art is None:
            entry["fig3_smoke"][backend] = {
                "missing": f"benchmarks/artifacts/{name}.json — run "
                           "REPRO_BENCH_SMOKE=1 tools/ci.sh first"
            }
            continue
        entry["fig3_smoke"][backend] = {
            "n": sorted(int(n) for a in art["summary"].values() for n in a)[0],
            "trials": art["trials"],
            "jit_warmup_s": art.get("jit_warmup_s"),
            "wall_clock_s": art["wall_clock_s"],
            "plan_build_s": art.get("plan_build_s"),
            "messages_mean": {
                algo: next(iter(rows.values()))["messages_mean"]
                for algo, rows in art["summary"].items()
            },
        }
    large = {}
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "large_n_*.json"))):
        name = os.path.splitext(os.path.basename(path))[0]
        if name.endswith("_check"):
            continue
        art = load_artifact(name)
        large[name] = {
            "n": art["n"],
            "trials": art["trials"],
            "backend": art["backend"],
            "fixed_ticks_scale": art["fixed_ticks_scale"],
            "messages": art["messages"],
            "err": art["err"],
            "wall_clock_s": art["wall_clock_s"],
            "graph_gen_s": art.get("graph_gen_s"),
            "plan_build_s": art["plan_build_s"],
            "workers": art.get("workers"),
            "setup": art.get("setup"),
            "memory": art["memory"],
            "overlap_ratio": (art.get("overlap") or {}).get("ratio"),
        }
    if large:
        entry["large_n"] = large
    if kernels:
        from .kernel_bench import pair_apply_bench

        entry["pair_apply_us"] = pair_apply_bench(as_rows=False)
    return entry


def run(label: str = "", kernels: bool = True) -> list[str]:
    entry = build_entry(label=label, kernels=kernels)
    record_entry(entry)
    lines = []
    for backend, rec in entry["fig3_smoke"].items():
        if "missing" in rec:
            lines.append(csv_line(f"gossip/fig3_smoke_{backend}", 0.0,
                                  rec["missing"]))
            continue
        ms = rec["wall_clock_s"].get("multiscale", 0.0)
        lines.append(csv_line(
            f"gossip/fig3_smoke_{backend}", ms * 1e6,
            f"n={rec['n']} multiscale_wall={ms:.2f}s "
            f"msgs={rec['messages_mean'].get('multiscale', 0):.0f}",
        ))
    for name, rec in entry.get("large_n", {}).items():
        setup = rec.get("setup") or {}
        setup_note = (
            f"setup_cold={setup['cold_s']:.2f}s "
            f"setup_warm={setup['warm_s']:.3f}s "
            if setup else ""
        )
        lines.append(csv_line(
            f"gossip/{name}", rec["wall_clock_s"]["execute_cold"] * 1e6,
            f"n={rec['n']} msgs={rec['messages'][0]} "
            f"graph={rec.get('graph_gen_s') or 0.0:.2f}s "
            f"plan={rec['plan_build_s'].get('total', 0.0):.2f}s "
            f"{setup_note}"
            f"warm={rec['wall_clock_s']['execute_warm']:.2f}s",
        ))
    for key, us in entry.get("pair_apply_us", {}).items():
        lines.append(csv_line(f"gossip/pair_apply_{key}", us, "see kernels"))
    lines.append(csv_line(
        "gossip/trajectory", 0.0,
        f"entries={len(load_trajectory())} -> BENCH_gossip.json "
        f"commit={entry['commit']}",
    ))
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--label", default="")
    ap.add_argument("--no-kernels", action="store_true")
    args = ap.parse_args()
    for line in run(label=args.label, kernels=not args.no_kernels):
        print(line)
