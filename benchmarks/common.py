"""Shared benchmark helpers: trial running, CSV/JSON artifact output.

CPU-budget note (DESIGN.md §8): the paper's experiments average 10-25
trials on graphs up to 8000 nodes; on this single-core container the
default benchmark profile uses 3 trials and the same size range, with
`--full` restoring the paper's trial counts.  Scaling-law fits still
span >= 1 decade of n.

Trial-vmapping note: the multiscale benchmarks run all trials of one
configuration in a single compiled vmapped call (`multiscale_gossip(...,
trials=T, backend=...)`); artifacts record `wall_clock_s` per algorithm
plus the `backend` used so perf regressions are visible in CI diffs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

ENGINE_BACKENDS = ("lax", "pallas", "matmul")


def timed(fn, *args, **kwargs):
    """(result, seconds) of one call — wall-clock for artifact payloads."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def save_artifact(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_artifact(name: str):
    path = os.path.join(ARTIFACTS, f"{name}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
