"""Shared benchmark helpers: trial running, CSV/JSON artifact output.

CPU-budget note (DESIGN.md §8): the paper's experiments average 10-25
trials on graphs up to 8000 nodes; on this single-core container the
default benchmark profile uses 3 trials and the same size range, with
`--full` restoring the paper's trial counts.  Scaling-law fits still
span >= 1 decade of n.

Trial-vmapping note: the multiscale benchmarks run all trials of one
configuration in a single compiled vmapped call (`multiscale_gossip(...,
trials=T, backend=...)`); artifacts record `wall_clock_s` per algorithm
plus the `backend` used so perf regressions are visible in CI diffs.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(__file__), "artifacts")

ENGINE_BACKENDS = ("lax", "pallas", "matmul")
SCHEDULES = ("presampled", "per_tick")


def exec_options(backend: str = "lax", schedule: str = "presampled", **kw):
    """The figure benchmarks' uniform `ExecOptions` constructor: every
    `run()` takes the same (backend, schedule) pair and threads it to
    the engine through here instead of the deprecated flat kwargs."""
    from repro.core import ExecOptions

    return ExecOptions(backend=backend, schedule=schedule, **kw)


def _tuple_arg(elem):
    def parse(s):
        return tuple(elem(x) for x in s.split(","))
    return parse


def bench_cli(run_fn, argv=None) -> None:
    """Uniform standalone CLI for `python -m benchmarks.figX`.

    Builds argparse flags from `run_fn`'s keyword defaults, so every
    figure benchmark exposes the same surface (--trials, --backend,
    --schedule, --artifact, plus its own numeric knobs) without each
    module hand-rolling a parser.  Tuple defaults parse as
    comma-separated lists (e.g. ``--sizes 500,1000``).
    """
    import argparse
    import inspect

    ap = argparse.ArgumentParser(description=run_fn.__module__)
    for name, p in inspect.signature(run_fn).parameters.items():
        d = p.default
        if d is inspect.Parameter.empty or d is None:
            continue
        flag = f"--{name.replace('_', '-')}"
        if name == "backend":
            ap.add_argument(flag, default=d, choices=ENGINE_BACKENDS)
        elif name == "schedule":
            ap.add_argument(flag, default=d, choices=SCHEDULES)
        elif isinstance(d, bool):
            ap.add_argument(flag, action=argparse.BooleanOptionalAction,
                            default=d)
        elif isinstance(d, tuple):
            ap.add_argument(flag, type=_tuple_arg(type(d[0])), default=d,
                            metavar=",".join(str(x) for x in d[:2]) + ",…")
        elif isinstance(d, (int, float, str)):
            ap.add_argument(flag, type=type(d), default=d)
    args = vars(ap.parse_args(argv))
    for line in run_fn(**{k: v for k, v in args.items() if v is not None}):
        print(line)


def timed(fn, *args, **kwargs):
    """(result, seconds) of one call — wall-clock for artifact payloads."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def save_artifact(name: str, payload: dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def load_artifact(name: str):
    path = os.path.join(ARTIFACTS, f"{name}.json")
    if not os.path.exists(path):
        return None
    return json.load(open(path))


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
