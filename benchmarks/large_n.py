"""Large-n gossip benchmark: the CSR plan/execute path at 10^5-10^6
nodes, with a dense-path oracle cross-check at an overlapping size.

Two parts:

1. **Overlap parity** — at `overlap_n` (fig2-sized, where the historical
   dense/loop builder is still affordable) the benchmark builds the plan
   with BOTH `build_plan` methods (`reference`: the per-cell/per-group
   loop builder; `vectorized`: the CSR fast path) and executes each with
   the identical engine config.  The message counts must agree within
   ±15%; the builders are in fact bitwise-identical, so the recorded
   ratio is exactly 1.0 and any future drift is a plan-construction bug,
   not noise.

2. **Large-n run** — one fixed-iterations (FI) trial at `n` through the
   vectorized builder and the lax presampled engine: cold setup (graph
   generation via the streamed bucket builder + plan build, with the
   per-stage `build_seconds` breakdown, forced with `refresh=True`),
   warm setup (a content-addressed plan-cache hit via
   `core.plan_cache.setup_plan` — the acceptance bar is warm < 5% of
   cold), cold execute (includes compile) and warm execute wall-clocks,
   total messages and final error, plus the peak host RSS / live
   device-buffer bytes from `tools.membuf_probe`.  `--workers N` shards
   plan construction over a fork pool (bitwise-identical output; a
   wall-clock lever on multi-core hosts only).

The FI profile (eps sentinel off, `fixed_ticks_scale=0.2`) is the
large-n configuration of record: convergence detection at 10^6 nodes
costs a full extra residual reduction per check and the paper's FI
variant is the one intended for known deployments.  `eps` here is only
the tick-budget scale parameter fed to `fi_ticks`.

    python -m benchmarks.large_n [--n 100000] [--smoke]

`--smoke` is the CI profile (n=20000, artifact `large_n_smoke`) wired
into `REPRO_BENCH_SMOKE=1 tools/ci.sh` and drift-gated by
`tools/check_artifacts.py --large-n-only`.  `gossip_trajectory` folds
any committed `large_n_*` artifacts into the BENCH_gossip.json entry.
"""
from __future__ import annotations

import os
import sys

import numpy as np

from repro.core import build_plan, execute_plan, random_geometric_graph
from repro.core.plan_cache import setup_plan

from .common import csv_line, save_artifact, timed

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from tools.membuf_probe import memory_report  # noqa: E402

OVERLAP_TOLERANCE = 0.15


def _execute_stats(plan, x0, *, eps, fixed_ticks_scale, seeds, backend):
    from repro.core import ExecOptions

    res, dt = timed(
        execute_plan, plan, x0, eps=eps, seeds=seeds, weighted=True,
        fixed_ticks_scale=fixed_ticks_scale,
        options=ExecOptions(backend=backend),
    )
    return res, dt


def overlap_check(overlap_n: int, *, eps: float, fixed_ticks_scale: float,
                  backend: str, seed: int = 0) -> dict:
    """Execute reference-built vs vectorized-built plans at a size both
    can afford; return the message-count comparison."""
    g = random_geometric_graph(overlap_n, seed=1000 + overlap_n)
    x0 = np.random.default_rng(overlap_n).normal(0, 1, overlap_n)
    msgs = {}
    for method in ("reference", "vectorized"):
        plan = build_plan(g, seed=seed, method=method)
        res, _ = _execute_stats(
            plan, x0, eps=eps, fixed_ticks_scale=fixed_ticks_scale,
            seeds=(seed,), backend=backend,
        )
        msgs[method] = int(res.messages[0])
    ratio = msgs["vectorized"] / max(msgs["reference"], 1)
    return {
        "n": int(overlap_n),
        "messages": msgs,
        "ratio": float(ratio),
        "tolerance": OVERLAP_TOLERANCE,
        "ok": bool(abs(ratio - 1.0) <= OVERLAP_TOLERANCE),
    }


def default_cache_dir() -> str:
    """Benchmark-local plan cache (gitignored)."""
    return os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "artifacts", "plan_cache"
    )


def run(n: int = 100_000, overlap_n: int = 2000, trials: int = 1,
        eps: float = 1e-3, fixed_ticks_scale: float = 0.2,
        backend: str = "lax", seed: int = 0, workers: int = 0,
        cache_dir: str | None = None,
        artifact: str | None = None) -> list[str]:
    artifact = artifact or f"large_n_{n}"
    cache_dir = cache_dir or default_cache_dir()
    overlap = overlap_check(
        overlap_n, eps=eps, fixed_ticks_scale=fixed_ticks_scale,
        backend=backend, seed=seed,
    ) if overlap_n else None

    # cold setup: streamed graph gen + plan build, forced fresh (the
    # store also primes the cache for the warm measurement)
    plan, cold_info = setup_plan(
        n=n, graph_seed=1000 + n, seed=seed, workers=workers,
        cache_dir=cache_dir, refresh=True,
    )
    graph_s = float(cold_info["graph_gen_s"])
    plan_s = float(cold_info["plan_build_s"].get("total", 0.0))
    cold_setup_s = graph_s + plan_s
    # warm setup: content-addressed cache hit, graph gen + build skipped
    warm_plan, warm_info = setup_plan(
        n=n, graph_seed=1000 + n, seed=seed, workers=workers,
        cache_dir=cache_dir,
    )
    warm_setup_s = float(warm_info["setup_s"])
    assert warm_info["cache"] == "hit", warm_info
    del warm_plan
    x0 = np.random.default_rng(n).normal(0, 1, n)
    seeds = tuple(seed + t for t in range(trials))
    res, cold_s = _execute_stats(
        plan, x0, eps=eps, fixed_ticks_scale=fixed_ticks_scale,
        seeds=seeds, backend=backend,
    )
    _, warm_s = _execute_stats(
        plan, x0, eps=eps, fixed_ticks_scale=fixed_ticks_scale,
        seeds=seeds, backend=backend,
    )
    payload = {
        "n": int(n),
        "trials": trials,
        "backend": backend,
        "mode": "fixed_iterations",
        "eps": eps,
        "fixed_ticks_scale": fixed_ticks_scale,
        "graph_seed": 1000 + int(n),
        "levels": len(plan.levels),
        "workers": int(workers),
        "graph_gen_s": graph_s,
        "plan_build_s": dict(plan.build_seconds or {}),
        "setup": {
            "cold_s": float(cold_setup_s),
            "warm_s": float(warm_setup_s),
            "warm_over_cold": float(warm_setup_s / max(cold_setup_s, 1e-9)),
            "cache_key": warm_info["key"],
            "load_s": float(warm_info.get("load_s", 0.0)),
        },
        "wall_clock_s": {
            "graph": float(graph_s),
            "plan": plan_s,
            "setup_cold": float(cold_setup_s),
            "setup_warm": float(warm_setup_s),
            "execute_cold": float(cold_s),
            "execute_warm": float(warm_s),
        },
        "messages": [int(m) for m in np.asarray(res.messages)],
        "err": [float(e) for e in np.atleast_1d(res.error(x0))],
        "memory": memory_report(),
        "overlap": overlap,
    }
    save_artifact(artifact, payload)
    if overlap is not None and not overlap["ok"]:
        raise SystemExit(
            f"large_n: overlap parity FAILED at n={overlap_n}: "
            f"vectorized/reference message ratio {overlap['ratio']:.3f} "
            f"outside ±{OVERLAP_TOLERANCE:.0%}"
        )
    out = []
    mem = payload["memory"]
    out.append(csv_line(
        f"large_n/n{n}", cold_s * 1e6,
        f"msgs={payload['messages'][0]} err={payload['err'][0]:.2e} "
        f"graph={graph_s:.2f}s "
        f"plan={payload['plan_build_s'].get('total', 0.0):.2f}s "
        f"warm={warm_s:.2f}s "
        f"rss={mem['host_peak_rss_bytes'] / 2**30:.2f}GiB",
    ))
    out.append(csv_line(
        f"large_n/setup_n{n}", cold_setup_s * 1e6,
        f"cold={cold_setup_s:.2f}s warm={warm_setup_s:.3f}s "
        f"({payload['setup']['warm_over_cold']:.1%} of cold, cache hit) "
        f"workers={workers}",
    ))
    if overlap is not None:
        out.append(csv_line(
            "large_n/overlap_parity", 0.0,
            f"n={overlap_n} ratio={overlap['ratio']:.3f} "
            f"(vectorized vs reference plan, tol ±{OVERLAP_TOLERANCE:.0%})",
        ))
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--overlap-n", type=int, default=2000,
                    help="0 disables the dense-path overlap check")
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--scale", type=float, default=0.2,
                    help="fixed_ticks_scale (FI tick budget)")
    ap.add_argument("--backend", default="lax")
    ap.add_argument("--workers", type=int, default=0,
                    help="fork-pool width for plan construction "
                         "(bitwise-identical to serial; wall-clock only)")
    ap.add_argument("--cache-dir", default=None,
                    help="plan-cache directory "
                         "(default benchmarks/artifacts/plan_cache)")
    ap.add_argument("--artifact", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: n=20000 -> artifact large_n_smoke")
    args = ap.parse_args()
    if args.smoke:
        args.n, args.artifact = 20_000, args.artifact or "large_n_smoke"
    for line in run(
        n=args.n, overlap_n=args.overlap_n, trials=args.trials,
        eps=args.eps, fixed_ticks_scale=args.scale, backend=args.backend,
        workers=args.workers, cache_dir=args.cache_dir,
        artifact=args.artifact,
    ):
        print(line)
