"""BENCH_serve.json — the serving-fleet perf-trajectory artifact.

Every entry snapshots the serving stack at one commit:

* **fleet** — the gossip-coordinated fleet simulation (`serve.fleet`)
  run once per router on a fixed seed: tokens/tick, completed requests,
  admission latency, and the control plane's message/byte bill, plus
  the headline `p2c_over_oracle` throughput ratio (the decentralized-
  routing acceptance number);
* **model_decode** — the real paged decode path (`ModelBackend` over a
  reduced llama config): steady-state live tok/s through the
  continuous-batching engine and `jit_warmup_s` for the two compiled
  entry points, so compile-time regressions are visible separately from
  throughput ones.

Same trajectory discipline as BENCH_gossip.json: repo-root, append-only
keyed by (commit, label), dirty trees stamped `-dirty`.

    python -m benchmarks.serve_bench [--label msg] [--no-model]

Also exposed as the `serve` suite in `benchmarks.run`;
`REPRO_BENCH_SMOKE=1 tools/ci.sh` appends an entry per CI run.
"""
from __future__ import annotations

import json
import os
import time

from .common import csv_line
from .gossip_trajectory import _git_commit

TRAJECTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_serve.json",
)

FLEET = dict(replicas=16, ticks=120, seed=0)


def load_trajectory() -> list:
    if not os.path.exists(TRAJECTORY):
        return []
    return json.load(open(TRAJECTORY))


def record_entry(entry: dict) -> None:
    key = (entry["commit"], entry.get("label", ""))
    traj = [
        e for e in load_trajectory()
        if (e.get("commit"), e.get("label", "")) != key
    ]
    traj.append(entry)
    with open(TRAJECTORY, "w") as f:
        json.dump(traj, f, indent=1)


def fleet_bench() -> dict:
    from repro.serve import ROUTERS, FleetConfig, run_fleet

    out = {}
    for router in ROUTERS:
        cfg = FleetConfig(router=router, **FLEET)
        r = run_fleet(cfg)
        out[router] = {
            "throughput_tok_per_tick": r.throughput,
            "completed": r.completed,
            "admission_latency_mean": r.admission_latency_mean,
            "page_utilization_mean": r.page_utilization_mean,
            "control_rounds": r.control_rounds,
            "control_messages": r.control_messages,
            "control_bytes": r.control_bytes,
            "bytes_per_round": r.bytes_per_round,
        }
    out["p2c_over_oracle"] = (
        out["p2c_gossip"]["throughput_tok_per_tick"]
        / max(out["oracle"]["throughput_tok_per_tick"], 1e-9)
    )
    out.update(FLEET)
    return out


def model_decode_bench(decode_steps: int = 24) -> dict:
    """Steady-state paged decode tok/s on the reduced llama config."""
    import jax
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.models import Transformer
    from repro.serve import BatchingEngine, ModelBackend, PageTable

    cfg = reduce_config(get_config("llama3.2-3b"))
    params = Transformer(cfg, model_axis=1).init(jax.random.PRNGKey(0))
    S, ps, P = 4, 4, 8
    table = PageTable(num_pages=S * P, page_size=ps, num_slots=S,
                      pages_per_slot=P)
    backend = ModelBackend(cfg, params, num_slots=S, num_pages=S * P,
                           page_size=ps, max_prompt_len=8)
    warmup_s = backend.warmup(table)
    eng = BatchingEngine(backend, table, eos_id=-1, seed=0)
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, (S, 4)
    ).astype(np.int32)
    for b in range(S):
        eng.submit(prompts[b], decode_steps)
    t0 = time.perf_counter()
    eng.run()
    wall = time.perf_counter() - t0
    return {
        "arch": cfg.name,
        "slots": S,
        "decode_steps": decode_steps,
        "jit_warmup_s": warmup_s,
        "wall_clock_s": wall,
        "tok_s": eng.tokens_generated / wall,
    }


def build_entry(label: str = "", model: bool = True) -> dict:
    entry = {
        "commit": _git_commit(),
        "unix_time": int(time.time()),
        "label": label,
        "fleet": fleet_bench(),
    }
    if model:
        entry["model_decode"] = model_decode_bench()
    return entry


def run(label: str = "", model: bool = True) -> list[str]:
    entry = build_entry(label=label, model=model)
    record_entry(entry)
    fl = entry["fleet"]
    lines = []
    for router in ("p2c_gossip", "oracle", "random"):
        r = fl[router]
        lines.append(csv_line(
            f"serve/fleet_{router}", 0.0,
            f"tok_per_tick={r['throughput_tok_per_tick']:.1f} "
            f"done={r['completed']} "
            f"ctrl_bytes={r['control_bytes']}",
        ))
    lines.append(csv_line(
        "serve/p2c_over_oracle", 0.0, f"{fl['p2c_over_oracle']:.3f}"
    ))
    if "model_decode" in entry:
        md = entry["model_decode"]
        lines.append(csv_line(
            "serve/paged_decode", md["wall_clock_s"] * 1e6,
            f"{md['arch']} tok_s={md['tok_s']:.0f} "
            f"jit_warmup_s={md['jit_warmup_s']:.2f}",
        ))
    lines.append(csv_line(
        "serve/trajectory", 0.0,
        f"entries={len(load_trajectory())} -> BENCH_serve.json "
        f"commit={entry['commit']}",
    ))
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--label", default="")
    ap.add_argument("--no-model", action="store_true")
    args = ap.parse_args()
    for line in run(label=args.label, model=not args.no_model):
        print(line)
