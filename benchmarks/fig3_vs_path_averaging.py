"""Paper Fig. 3: total messages to eps=1e-4 vs network size, for
MultiscaleGossip (auto-k), MultiscaleGossipFI (fixed iterations),
MultiscaleGossip2level (k=2, a=1/2), and path averaging [13].

Expected (paper): every multiscale variant uses noticeably fewer
transmissions than path averaging, near-linear growth in n.

Multiscale variants run through the plan/execute core: one
`HierarchyPlan` per (n, partition config), all trials vmapped into a
single compiled call.  Wall-clock per algorithm and the engine backend
are recorded in the artifact.

Standalone:  python -m benchmarks.fig3_vs_path_averaging \
                 [--sizes 500,1000] [--trials 3] [--backend lax|pallas]
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    build_plan, multiscale_gossip, path_averaging, random_geometric_graph,
)

from .common import csv_line, exec_options, save_artifact, timed


def _warm_jit(opts) -> float:
    """Absorb one-time XLA/LLVM process-init cost before the timed rows.

    Compiles a throwaway executor on a tiny unrelated graph: none of the
    timed configurations share shapes with it (so nothing timed is
    pre-cached), but backend initialization, first-compile allocator
    warmup, etc. stop being attributed to whichever algorithm happens to
    run first.  Returns the warmup seconds (recorded in the artifact).
    """
    def warm():
        # two distinct tiny compiles: the first absorbs backend/LLVM
        # init, the second the remaining first-recompile overhead
        # (allocator, lowering-rule caches)
        for n in (24, 40):
            g = random_geometric_graph(n, seed=9)
            multiscale_gossip(g, np.zeros(n), eps=1e-2, seed=0, options=opts)

    _, dt = timed(warm)
    return dt


def run(sizes=(500, 1000, 2000, 4000, 8000), trials: int = 3,
        eps: float = 1e-4, backend: str = "lax", schedule: str = "presampled",
        artifact: str = "fig3_vs_path_averaging") -> list[str]:
    opts = exec_options(backend, schedule)
    algo_names = ["multiscale", "multiscale_fi", "multiscale_2level",
                  "path_averaging"]
    table: dict = {a: {} for a in algo_names}
    timing: dict = {a: 0.0 for a in algo_names}
    plan_build_s: dict = {}
    graph_gen_s: dict = {}
    warmup_s = _warm_jit(opts)

    def record(name, n, res, x0, dt):
        timing[name] += dt
        errs = np.atleast_1d(res.error(x0))
        msgs = np.atleast_1d(res.messages)
        table[name][n] = [
            {"messages": int(m), "err": float(e)} for m, e in zip(msgs, errs)
        ]

    for n in sizes:
        g, g_dt = timed(random_geometric_graph, n, seed=1000 + n)
        graph_gen_s[int(n)] = float(g_dt)
        x0 = np.stack([
            np.random.default_rng(n + t).normal(0, 1, n) for t in range(trials)
        ])
        plan_auto = build_plan(g, seed=0)          # shared by auto-k variants
        plan_2l = build_plan(g, k=2, a=0.5, seed=0)
        plan_build_s[int(n)] = {
            "auto_k": dict(plan_auto.build_seconds or {}),
            "k2": dict(plan_2l.build_seconds or {}),
        }
        ms_variants = {
            "multiscale": dict(plan=plan_auto),
            "multiscale_fi": dict(plan=plan_auto, fixed_ticks_scale=1.0),
            "multiscale_2level": dict(plan=plan_2l),
        }
        def run_ms(name):
            r, dt = timed(
                multiscale_gossip, g, x0 if trials > 1 else x0[0], eps=eps,
                seed=0, weighted=True, trials=trials, options=opts,
                **ms_variants[name],
            )
            return name, r, dt

        # rows run serially: overlapping path averaging (GIL-holding
        # numpy) with the executors' tracing phase (also GIL-holding)
        # inflated both rows with contention on small hosts — serialized
        # timings are attributable per algorithm
        for name in ms_variants:
            name, r, dt = run_ms(name)
            record(name, n, r, x0 if trials > 1 else x0[0], dt)
        pa, pa_dt = timed(lambda: [
            path_averaging(g, x0[t], eps=eps, seed=t)
            for t in range(trials)
        ])
        timing["path_averaging"] += pa_dt
        table["path_averaging"][n] = [
            {"messages": int(r.messages), "err": float(r.error(x0[t]))}
            for t, r in enumerate(pa)
        ]

    summary = {
        name: {
            n: {
                "messages_mean": float(np.mean([x["messages"] for x in v])),
                "err_mean": float(np.mean([x["err"] for x in v])),
            }
            for n, v in rows.items()
        }
        for name, rows in table.items()
    }
    # scaling exponents (log-log fit)
    fits = {}
    for name, rows in summary.items():
        ns = sorted(rows)
        if len(ns) > 1:
            fits[name] = float(np.polyfit(
                np.log([float(n) for n in ns]),
                np.log([rows[n]["messages_mean"] for n in ns]), 1
            )[0])
        else:
            fits[name] = None  # a single size has no slope (avoid NaN JSON)
    save_artifact(
        artifact,
        {
            "eps": eps,
            "trials": trials,
            "backend": backend,
            "schedule": schedule,
            # trials share one deployment per n (graph seed 1000+n, the
            # vmapped plan/execute design): messages variance is gossip
            # noise only, NOT across-graph variance as in the paper's
            # error bars; x0 is redrawn per trial
            "trial_mode": "vmapped-shared-graph",
            "graph_seeds": {int(n): 1000 + int(n) for n in sizes},
            "jit_warmup_s": float(warmup_s),
            "wall_clock_s": {k: float(v) for k, v in timing.items()},
            "graph_gen_s": graph_gen_s,
            "plan_build_s": plan_build_s,
            "summary": summary,
            "scaling_exponent": fits,
        },
    )
    out = []
    n_big = max(sizes)
    for name, rows in summary.items():
        calls = len(sizes) * trials
        exp = f"{fits[name]:.2f}" if fits[name] is not None else "n/a"
        out.append(csv_line(
            f"fig3/{name}", timing[name] * 1e6 / calls,
            f"messages@n{n_big}={rows[n_big]['messages_mean']:.0f} "
            f"exponent={exp} wall={timing[name]:.1f}s",
        ))
    ratio = (
        summary["path_averaging"][n_big]["messages_mean"]
        / summary["multiscale"][n_big]["messages_mean"]
    )
    out.append(csv_line(
        "fig3/pa_over_multiscale", 0.0,
        f"ratio@n{n_big}={ratio:.2f} (paper: multiscale wins, Fig.3)",
    ))
    return out


if __name__ == "__main__":
    from .common import bench_cli

    bench_cli(run)
