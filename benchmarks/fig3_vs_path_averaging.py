"""Paper Fig. 3: total messages to eps=1e-4 vs network size, for
MultiscaleGossip (auto-k), MultiscaleGossipFI (fixed iterations),
MultiscaleGossip2level (k=2, a=1/2), and path averaging [13].

Expected (paper): every multiscale variant uses noticeably fewer
transmissions than path averaging, near-linear growth in n.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import multiscale_gossip, path_averaging, random_geometric_graph

from .common import csv_line, save_artifact


def run(sizes=(500, 1000, 2000, 4000, 8000), trials: int = 3,
        eps: float = 1e-4) -> list[str]:
    algos = {
        "multiscale": lambda g, x, s: multiscale_gossip(
            g, x, eps=eps, seed=s, weighted=True
        ),
        "multiscale_fi": lambda g, x, s: multiscale_gossip(
            g, x, eps=eps, seed=s, weighted=True, fixed_ticks_scale=1.0
        ),
        "multiscale_2level": lambda g, x, s: multiscale_gossip(
            g, x, eps=eps, seed=s, weighted=True, k=2, a=0.5
        ),
        "path_averaging": lambda g, x, s: path_averaging(g, x, eps=eps, seed=s),
    }
    table: dict = {a: {} for a in algos}
    timing: dict = {a: 0.0 for a in algos}
    for n in sizes:
        for t in range(trials):
            g = random_geometric_graph(n, seed=1000 + n + t)
            x0 = np.random.default_rng(n + t).normal(0, 1, n)
            for name, fn in algos.items():
                t0 = time.time()
                r = fn(g, x0, t)
                timing[name] += time.time() - t0
                err = r.error(x0)
                table[name].setdefault(n, []).append(
                    {"messages": int(r.messages), "err": float(err)}
                )
    summary = {
        name: {
            n: {
                "messages_mean": float(np.mean([x["messages"] for x in v])),
                "err_mean": float(np.mean([x["err"] for x in v])),
            }
            for n, v in rows.items()
        }
        for name, rows in table.items()
    }
    # scaling exponents (log-log fit)
    fits = {}
    for name, rows in summary.items():
        ns = sorted(rows)
        slope = np.polyfit(
            np.log([float(n) for n in ns]),
            np.log([rows[n]["messages_mean"] for n in ns]), 1
        )[0]
        fits[name] = float(slope)
    save_artifact(
        "fig3_vs_path_averaging",
        {"eps": eps, "summary": summary, "scaling_exponent": fits},
    )
    out = []
    n_big = max(sizes)
    for name, rows in summary.items():
        calls = len(sizes) * trials
        out.append(csv_line(
            f"fig3/{name}", timing[name] * 1e6 / calls,
            f"messages@n{n_big}={rows[n_big]['messages_mean']:.0f} "
            f"exponent={fits[name]:.2f}",
        ))
    ratio = (
        summary["path_averaging"][n_big]["messages_mean"]
        / summary["multiscale"][n_big]["messages_mean"]
    )
    out.append(csv_line(
        "fig3/pa_over_multiscale", 0.0,
        f"ratio@n{n_big}={ratio:.2f} (paper: multiscale wins, Fig.3)",
    ))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
