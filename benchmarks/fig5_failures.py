"""Paper Fig. 5 + §VI-C-2: operation under transmission failures.

Handshake model: per-hop ACK/retransmit — the trajectory is unchanged,
cost inflates by iid Geometric(p) per single-hop transmission; sampled
exactly post-hoc (repro.core.failures.handshake_cost).  Expected:
multiscale degrades much less than path averaging as p drops, because
its messages travel <= O(n^(1/3)) hops.

Message-loss model: transmissions fail permanently — neither algorithm
meets eps; we report achieved error and message blow-up (paper observed
multiscale ~0.06, path averaging ~0.02 achieved accuracy, with PA's
messages exploding).

Reliable runs use `trials` seeds for both algorithms (multiscale vmapped
through the plan/execute engine, path averaging seeded per trial);
handshake costs use trial-mean message counts.  The loss-model runs are
single-trial and labeled as such.  Wall-clock per algorithm and the
backend are recorded in the artifact.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    handshake_cost, multiscale_gossip, path_averaging, random_geometric_graph,
)

from .common import csv_line, save_artifact, timed


def run(n: int = 2000, eps: float = 1e-4,
        ps=(0.5, 0.6, 0.7, 0.8, 0.9, 1.0), trials: int = 3,
        backend: str = "lax") -> list[str]:
    g = random_geometric_graph(n, seed=21)
    x0 = np.random.default_rng(3).normal(0, 1, n)
    timing = {}
    ms, timing["multiscale"] = timed(
        multiscale_gossip, g, x0, eps=eps, seed=0, weighted=True,
        trials=trials, backend=backend,
    )
    pa_runs, timing["path_averaging"] = timed(lambda: [
        path_averaging(g, x0, eps=eps, seed=t) for t in range(trials)
    ])
    ms_msgs = int(np.mean(np.atleast_1d(ms.messages)))
    pa_msgs = int(np.mean([r.messages for r in pa_runs]))
    rng = np.random.default_rng(0)
    handshake = {
        str(p): {
            "multiscale": int(handshake_cost(ms_msgs, p, rng)),
            "path_averaging": int(handshake_cost(pa_msgs, p, rng)),
        }
        for p in ps
    }

    # message-loss model (changes the trajectory): bounded budgets,
    # single-trial runs (labeled as such in the artifact)
    loss_p = 0.9
    ms_loss, timing["multiscale_loss"] = timed(
        multiscale_gossip, g, x0, eps=eps, seed=0, weighted=True,
        loss_p=loss_p, max_ticks_per_level=60_000, backend=backend,
    )
    pa_loss, timing["path_averaging_loss"] = timed(
        path_averaging, g, x0, eps=eps, seed=0, loss_p=loss_p,
        max_iters=60_000,
    )
    payload = {
        "n": n,
        "trials": trials,
        "backend": backend,
        "trial_mode": "vmapped",
        "wall_clock_s": {k: float(v) for k, v in timing.items()},
        "handshake": handshake,
        "reliable_messages": {
            "multiscale": ms_msgs, "path_averaging": pa_msgs
        },
        "loss_model": {
            "p": loss_p,
            "trials": 1,
            "multiscale": {"err": float(ms_loss.error(x0)),
                           "messages": int(ms_loss.messages)},
            "path_averaging": {"err": float(pa_loss.error(x0)),
                               "messages": int(pa_loss.messages)},
        },
    }
    save_artifact("fig5_failures", payload)
    us = sum(timing.values()) * 1e6
    out = []
    for p in ps:
        h = handshake[str(p)]
        out.append(csv_line(
            f"fig5/handshake_p{p}", us / len(ps),
            f"ms={h['multiscale']} pa={h['path_averaging']} "
            f"ratio={h['path_averaging']/max(h['multiscale'],1):.2f}",
        ))
    lm = payload["loss_model"]
    out.append(csv_line(
        "fig5/loss_model_p0.9", 0.0,
        f"ms_err={lm['multiscale']['err']:.3f} "
        f"pa_err={lm['path_averaging']['err']:.3f} (accuracy floor, §VI-C-2)",
    ))
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
