"""Paper Fig. 5 + §VI-C: operation under transmission failures, plus
the beyond-paper failure-scenario matrix.

Handshake model: per-hop ACK/retransmit — the trajectory is unchanged,
cost inflates by iid Geometric(p) per single-hop transmission.  Priced
per trial with `repro.core.price_messages` (supersedes the scalar
`handshake_cost`), so the artifact records the spread, not just a
trial-mean point.  Expected: multiscale degrades much less than path
averaging as p drops, because its messages travel <= O(n^(1/3)) hops.

Message-loss model: transmissions fail permanently — neither algorithm
meets eps; we report achieved error and message blow-up (paper observed
multiscale ~0.06, path averaging ~0.02 achieved accuracy, with PA's
messages exploding).  Loss runs use the same `trials` seeds as the
reliable runs (multiscale vmapped in one call, path averaging seeded
per trial) and the artifact records per-trial errors and their spread.

Scenario matrix (`repro.core.scenarios`): churn / stragglers / regional
outage / Byzantine drops replayed over ONE shared plan in
fixed-iterations mode, each cell reporting achieved error (all nodes
and survivors) and the priced medium cost (energy with retransmissions
at `scenario_retransmit_p` and congestion).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    CostModel,
    FailureModel,
    build_plan,
    multiscale_gossip,
    path_averaging,
    price_messages,
    random_geometric_graph,
    run_scenario_matrix,
    scenario_matrix,
)

from .common import csv_line, exec_options, save_artifact, timed


def run(n: int = 2000, eps: float = 1e-4,
        ps=(0.5, 0.6, 0.7, 0.8, 0.9, 1.0), trials: int = 3,
        backend: str = "lax", schedule: str = "presampled",
        scenario_trials: int = 0, scenario_scale: float = 0.25,
        scenario_retransmit_p: float = 0.9,
        artifact: str = "fig5_failures") -> list[str]:
    """`scenario_trials > 0` appends the failure-scenario matrix (at the
    same n, fixed-iterations mode) to the artifact and CSV."""
    opts = exec_options(backend, schedule)
    g = random_geometric_graph(n, seed=21)
    x0 = np.random.default_rng(3).normal(0, 1, n)
    timing = {}
    ms, timing["multiscale"] = timed(
        multiscale_gossip, g, x0, eps=eps, seed=0, weighted=True,
        trials=trials, options=opts,
    )
    pa_runs, timing["path_averaging"] = timed(lambda: [
        path_averaging(g, x0, eps=eps, seed=t) for t in range(trials)
    ])
    ms_trial_msgs = np.atleast_1d(np.asarray(ms.messages, np.int64))
    pa_trial_msgs = np.asarray([r.messages for r in pa_runs], np.int64)
    ms_msgs = int(ms_trial_msgs.mean())
    pa_msgs = int(pa_trial_msgs.mean())
    # per-trial handshake pricing: each trial's own message count drawn
    # through its own NegBinomial — the artifact keeps mean AND spread
    rng = np.random.default_rng(0)
    handshake = {}
    for p in ps:
        cm = CostModel(retransmit_p=p)
        c_ms = price_messages(ms_trial_msgs, cm, rng)
        c_pa = price_messages(pa_trial_msgs, cm, rng)
        handshake[str(p)] = {
            "multiscale": int(c_ms.physical_transmissions.mean()),
            "path_averaging": int(c_pa.physical_transmissions.mean()),
            "multiscale_per_trial": c_ms.physical_transmissions.tolist(),
            "path_averaging_per_trial": c_pa.physical_transmissions.tolist(),
            "multiscale_std": float(c_ms.physical_transmissions.std()),
            "path_averaging_std": float(c_pa.physical_transmissions.std()),
        }

    # message-loss model (changes the trajectory): bounded budgets, the
    # same `trials` seeds as the reliable runs (multiscale vmapped)
    loss_p = 0.9
    loss_opts = exec_options(backend, schedule, max_ticks_per_level=60_000)
    ms_loss, timing["multiscale_loss"] = timed(
        multiscale_gossip, g, x0, eps=eps, seed=0, weighted=True,
        trials=trials, options=loss_opts, failures=FailureModel(loss_p=loss_p),
    )
    pa_loss, timing["path_averaging_loss"] = timed(lambda: [
        path_averaging(g, x0, eps=eps, seed=t, loss_p=loss_p,
                       max_iters=60_000)
        for t in range(trials)
    ])
    ms_loss_errs = np.atleast_1d(ms_loss.error(x0))
    pa_loss_errs = np.asarray([r.error(x0) for r in pa_loss])
    loss_model = {
        "p": loss_p,
        "trials": trials,
        "multiscale": {
            "err": float(ms_loss_errs.mean()),
            "err_std": float(ms_loss_errs.std()),
            "err_per_trial": ms_loss_errs.tolist(),
            "messages": int(np.atleast_1d(ms_loss.messages).mean()),
            "messages_per_trial":
                np.atleast_1d(ms_loss.messages).tolist(),
        },
        "path_averaging": {
            "err": float(pa_loss_errs.mean()),
            "err_std": float(pa_loss_errs.std()),
            "err_per_trial": pa_loss_errs.tolist(),
            "messages": int(np.mean([r.messages for r in pa_loss])),
            "messages_per_trial": [int(r.messages) for r in pa_loss],
        },
    }

    scenarios = None
    if scenario_trials > 0:
        plan = build_plan(g, seed=0)
        sc_cost = CostModel(retransmit_p=scenario_retransmit_p,
                            congestion_alpha=0.01)
        sc_res, timing["scenario_matrix"] = timed(
            run_scenario_matrix, g, x0, scenario_matrix(),
            eps=eps, trials=scenario_trials, seed=0, weighted=True,
            fixed_ticks_scale=scenario_scale, options=opts, cost=sc_cost,
            plan=plan,
        )
        scenarios = {
            r.scenario.name: {
                "description": r.scenario.description,
                "err_mean": r.err_mean,
                "err_std": r.err_std,
                "err_per_trial": r.errors.tolist(),
                "survivor_err_mean": float(r.survivor_errors.mean()),
                "messages_mean": float(r.messages.mean()),
                "energy_mean": r.energy_mean,
                "retransmissions_mean": float(r.cost.retransmissions.mean()),
                "congestion_mean": float(r.cost.congestion.mean()),
            }
            for r in sc_res
        }

    payload = {
        "n": n,
        "eps": eps,
        "trials": trials,
        "backend": backend,
        "schedule": schedule,
        "trial_mode": "vmapped",
        "wall_clock_s": {k: float(v) for k, v in timing.items()},
        "handshake": handshake,
        "reliable_messages": {
            "multiscale": ms_msgs, "path_averaging": pa_msgs,
            "multiscale_per_trial": ms_trial_msgs.tolist(),
            "path_averaging_per_trial": pa_trial_msgs.tolist(),
        },
        "loss_model": loss_model,
    }
    if scenarios is not None:
        payload["scenario_matrix"] = {
            "trials": scenario_trials,
            "fixed_ticks_scale": scenario_scale,
            "retransmit_p": scenario_retransmit_p,
            "scenarios": scenarios,
        }
    save_artifact(artifact, payload)
    us = sum(timing.values()) * 1e6
    out = []
    for p in ps:
        h = handshake[str(p)]
        out.append(csv_line(
            f"fig5/handshake_p{p}", us / len(ps),
            f"ms={h['multiscale']} pa={h['path_averaging']} "
            f"ratio={h['path_averaging']/max(h['multiscale'],1):.2f}",
        ))
    lm = loss_model
    out.append(csv_line(
        f"fig5/loss_model_p{loss_p}_trials{trials}", 0.0,
        f"ms_err={lm['multiscale']['err']:.3f}"
        f"±{lm['multiscale']['err_std']:.3f} "
        f"pa_err={lm['path_averaging']['err']:.3f}"
        f"±{lm['path_averaging']['err_std']:.3f} "
        "(accuracy floor, §VI-C-2)",
    ))
    if scenarios is not None:
        for name, row in scenarios.items():
            out.append(csv_line(
                f"fig5/scenario_{name}", 0.0,
                f"err={row['err_mean']:.3f}±{row['err_std']:.3f} "
                f"surv_err={row['survivor_err_mean']:.3f} "
                f"energy={row['energy_mean']:.0f}",
            ))
    return out


if __name__ == "__main__":
    from .common import bench_cli

    bench_cli(run)
