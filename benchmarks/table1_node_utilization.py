"""Paper Table I + Fig. 6: transmissions by representative-role count
(random representative election, 5 levels).

Expected: nodes that served as representatives more often transmit
more, but even 3-time representatives stay modest; the average node
sends fewer messages than it has neighbors.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import multiscale_gossip, random_geometric_graph

from .common import csv_line, exec_options, save_artifact


def run(n: int = 2000, eps: float = 1e-4, k: int = 5, seed: int = 0,
        trials: int = 1, backend: str = "lax", schedule: str = "presampled",
        artifact: str = "table1_node_utilization") -> list[str]:
    t0 = time.time()
    g = random_geometric_graph(n, seed=11)
    x0 = np.random.default_rng(1).normal(0, 1, n)
    r = multiscale_gossip(g, x0, eps=eps, k=k, seed=seed, rep_mode="random",
                          weighted=True, trials=trials,
                          options=exec_options(backend, schedule))
    # trial-mean per-node sends (a single trial keeps the historical
    # numbers bit-for-bit; the election — rep_counts — is plan-shared)
    node_sends = np.atleast_2d(r.node_sends).mean(axis=0)
    rows = {}
    for count in sorted(np.unique(r.rep_counts), reverse=True):
        sel = r.rep_counts == count
        rows[int(count)] = {
            "nodes": int(sel.sum()),
            "mean_sends": float(node_sends[sel].mean()),
            "std_sends": float(node_sends[sel].std()),
        }
    avg_degree = float(g.degrees.mean())
    payload = {
        "n": n, "k": k, "trials": trials, "backend": backend,
        "schedule": schedule, "rows": rows,
        "all_mean": float(node_sends.mean()),
        "all_std": float(node_sends.std()),
        "avg_degree": avg_degree,
        "mean_below_degree": bool(node_sends.mean() < avg_degree),
    }
    save_artifact(artifact, payload)
    us = (time.time() - t0) * 1e6
    out = []
    for count, row in rows.items():
        out.append(csv_line(
            f"table1/reps_{count}x", us / max(len(rows), 1),
            f"nodes={row['nodes']} mean={row['mean_sends']:.1f} "
            f"std={row['std_sends']:.1f}",
        ))
    out.append(csv_line(
        "table1/all_nodes", 0.0,
        f"mean={payload['all_mean']:.1f} std={payload['all_std']:.1f} "
        f"avg_degree={avg_degree:.0f} "
        f"mean<degree={payload['mean_below_degree']} (paper: true)",
    ))
    return out


if __name__ == "__main__":
    from .common import bench_cli

    bench_cli(run)
