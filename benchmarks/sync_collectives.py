"""Collective-traffic comparison of the gradient-sync strategies — the
paper's message-complexity claim measured on compiled HLO (DESIGN §2).

Lowered on a 32-replica mesh (2 "pods" x 16) with a ~64 MB gradient
tree; for each strategy we count collective ops/bytes and the cross-pod
share.  Expected, mirroring the paper:
  * allreduce: one global all-reduce per leaf — every byte crosses pods;
  * hierarchical: grouped reduces — cross-pod bytes shrink to the
    top-level fusion only;
  * ring: many collective-permute rounds (flat gossip is chatty — the
    paper's slow baseline);
  * multiscale: permutes mostly INSIDE cells; only representative
    promotion crosses pods — the O(n^(1/3))-hop analogue.

Cross-pod classification goes through `device_pod_map`: partition ids in
lowered replica_groups index the mesh device assignment (reshapes of the
replica axis remap them), so the raw `id // pod_size` heuristic is only
the fallback.

Run standalone (sets its own device count): python -m benchmarks.sync_collectives
    --wallclock   additionally times the compiled sync on the available
                  devices (skips cleanly on single-device hosts)
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"

import json
import time

import numpy as np


def run(wallclock: bool = False) -> list[str]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import SyncConfig, suggest_levels, sync_gradients
    from repro.launch.hlo_analysis import collective_bytes, device_pod_map
    from repro.launch.mesh import set_mesh
    from .common import csv_line, load_artifact, save_artifact

    R = 32
    mesh = jax.make_mesh((R,), ("replica",))
    grads_abs = {
        "w1": jax.ShapeDtypeStruct((R, 1024, 1024), jnp.float32),
        "w2": jax.ShapeDtypeStruct((R, 4096, 512), jnp.float32),
        "w3": jax.ShapeDtypeStruct((R, 65536,), jnp.float32),
    }
    per_replica_bytes = sum(
        np.prod(a.shape[1:]) * 4 for a in grads_abs.values()
    )
    sh = {k: NamedSharding(mesh, P("replica", *([None] * (len(a.shape) - 1))))
          for k, a in grads_abs.items()}
    levels = suggest_levels(R)           # (4, 2, 4) for 32
    strategies = {
        "allreduce": SyncConfig("allreduce"),
        "hierarchical": SyncConfig("hierarchical", levels=levels),
        "ring": SyncConfig("ring", rounds=(2 * R,)),
        "multiscale": SyncConfig("multiscale", levels=levels),
        "multiscale_exact": SyncConfig("multiscale", levels=levels,
                                       exact_fusion=True),
    }
    # 16 replicas per "pod"; partition ids map through the assignment
    pod_of = device_pod_map(list(mesh.devices.flat), pod_size=16)
    can_time = jax.device_count() >= 2
    # standalone mode forces 32 emulated host devices — wallclock numbers
    # are then scheduling-emulation times, not real interconnect traffic;
    # label them so they are never read as hardware measurements
    emulated = "--xla_force_host_platform_device_count" in os.environ.get(
        "XLA_FLAGS", ""
    )
    grads = None
    if wallclock and can_time:  # identical for every strategy — build once
        grads = {
            k: jax.device_put(
                np.random.default_rng(0).normal(0, 1, a.shape).astype(
                    np.float32
                ),
                sh[k],
            )
            for k, a in grads_abs.items()
        }
    rows, lines = {}, []
    for name, cfg_s in strategies.items():
        with set_mesh(mesh):
            compiled = (
                jax.jit(
                    lambda g: sync_gradients(g, cfg_s, R),
                    in_shardings=(sh,), out_shardings=sh,
                )
                .lower(grads_abs)
                .compile()
            )
        stats = collective_bytes(compiled.as_text(), pod_size=16, pod_of=pod_of)
        rows[name] = stats.asdict()
        rows[name]["bytes_per_replica_payload"] = float(per_replica_bytes)
        lines.append(csv_line(
            f"sync/{name}", 0.0,
            f"coll_bytes={stats.total_bytes} "
            f"cross_pod={stats.cross_pod_bytes} "
            f"ops={stats.count} "
            f"xpod_frac={stats.cross_pod_bytes/max(stats.total_bytes,1):.2f}",
        ))
        if wallclock and can_time:
            jax.block_until_ready(compiled(grads))  # warm-up
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(compiled(grads))
            ms = (time.perf_counter() - t0) * 1e3 / reps
            rows[name]["wallclock_ms"] = ms
            rows[name]["wallclock_emulated"] = emulated
            lines.append(csv_line(
                f"sync/{name}/wallclock", ms * 1e3,
                f"ms_per_sync={ms:.1f} devices={jax.device_count()} "
                f"emulated={emulated}",
            ))
    if wallclock and not can_time:
        lines.append(csv_line(
            "sync/wallclock", 0.0,
            f"SKIP: single-device host (devices={jax.device_count()})",
        ))
    payload = {"levels": list(levels), "rows": rows}
    if wallclock:
        payload["wallclock_devices"] = jax.device_count()
        payload["wallclock_emulated"] = emulated
    else:
        # lowering-only runs keep the last measured wall-clock data so a
        # default CI pass does not erase it from the tracked artifact
        prev = load_artifact("sync_collectives") or {}
        for k in ("wallclock_devices", "wallclock_emulated"):
            if k in prev:
                payload[k] = prev[k]
        for name, row in payload["rows"].items():
            old = prev.get("rows", {}).get(name, {})
            for k in ("wallclock_ms", "wallclock_emulated"):
                if k in old:
                    row[k] = old[k]
    save_artifact("sync_collectives", payload)
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--wallclock", action="store_true",
                    help="time compiled sync_gradients on available devices")
    for line in run(wallclock=ap.parse_args().wallclock):
        print(line)
