"""Collective-traffic comparison of the gradient-sync strategies — the
paper's message-complexity claim measured on compiled HLO (DESIGN §2).

Lowered on a 32-replica mesh (2 "pods" x 16) with a ~64 MB gradient
tree; for each strategy we count collective ops/bytes and the cross-pod
share.  Expected, mirroring the paper:
  * allreduce: one global all-reduce per leaf — every byte crosses pods;
  * hierarchical: grouped reduces — cross-pod bytes shrink to the
    top-level fusion only;
  * ring: many collective-permute rounds (flat gossip is chatty — the
    paper's slow baseline);
  * multiscale: permutes mostly INSIDE cells; only representative
    promotion crosses pods — the O(n^(1/3))-hop analogue.

Strategies lower through the plan/execute split (`build_sync_plan` +
`execute_sync`), including error-feedback-compressed and rotated
(randomized-cell) gossip variants.  The simulation exchanges dense f32
tensors; `total_bytes`/`by_kind` report the lowering as-is, which for
compressed/rotated variants includes compression-COMPUTE collectives
(the emulated top-k sort all-gathers rows; the rotation permutation
lowers as gathers) on top of the mixing payload.  The `wire_bytes`
column models what a packed wire format would actually carry: the base
strategy's mixing collective bytes x `compression.wire_fraction` (topk
ships (value, index) pairs, so fraction 0.125 keeps wire at 0.25x
dense; int8 is 1 byte per entry = 0.25x; rotation relabels neighbors
without changing traffic).  `modeled_wire_bytes` is the
device-independent `plan_wire_bytes` accounting used by the train-step
metric.

Cross-pod classification goes through `device_pod_map`: partition ids in
lowered replica_groups index the mesh device assignment (reshapes of the
replica axis remap them), so the raw `id // pod_size` heuristic is only
the fallback.

`--wallclock` additionally records the serialized-vs-overlapped
comparison: `serialized_ms` chains a stand-in backward compute into the
sync (the old pipeline — sync strictly after backward), `overlapped_ms`
runs the same compute and the sync of an INDEPENDENT (previous-step)
gradient buffer in one program (the async one-step pipeline,
`dist.async_sync`), both through the shard_map executor so the
collectives are scheduling-explicit; `overlap_delta_ms` is the
wall-clock the overlap reclaims.  Timed for the representative subset
`OVERLAP_TIMED` (exact baselines + both multiscale variants) — the
64-round flat ring is minutes of pure collective chatter per call on
the emulated mesh and adds nothing to the comparison.  On the emulated
host mesh the delta reflects scheduler behavior, not real interconnect
overlap — `wallclock_emulated` flags it.

Run standalone (sets its own device count): python -m benchmarks.sync_collectives
    --wallclock   additionally times the compiled sync on the available
                  devices (skips cleanly on single-device hosts)
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"

import json
import time

import numpy as np


def run(wallclock: bool = False) -> list[str]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import (
        CompressionConfig, SyncConfig, SyncFailureModel, build_sync_plan,
        execute_sync, execute_sync_sharded, plan_wire_bytes, suggest_levels,
        wire_fraction,
    )
    from repro.launch.hlo_analysis import collective_bytes, device_pod_map
    from repro.launch.mesh import set_mesh
    from .common import csv_line, load_artifact, save_artifact

    R = 32
    mesh = jax.make_mesh((R,), ("replica",))
    grads_abs = {
        "w1": jax.ShapeDtypeStruct((R, 1024, 1024), jnp.float32),
        "w2": jax.ShapeDtypeStruct((R, 4096, 512), jnp.float32),
        "w3": jax.ShapeDtypeStruct((R, 65536,), jnp.float32),
    }
    per_replica_bytes = sum(
        np.prod(a.shape[1:]) * 4 for a in grads_abs.values()
    )
    sh = {k: NamedSharding(mesh, P("replica", *([None] * (len(a.shape) - 1))))
          for k, a in grads_abs.items()}
    step_abs = jax.ShapeDtypeStruct((), jnp.int32)
    step_sh = NamedSharding(mesh, P())
    levels = suggest_levels(R)           # (4, 2, 4) for 32
    topk = CompressionConfig("topk", topk_fraction=0.125)  # 2x/entry -> 0.25x wire
    int8 = CompressionConfig("int8")
    strategies = {
        "allreduce": SyncConfig("allreduce"),
        "hierarchical": SyncConfig("hierarchical", levels=levels),
        "ring": SyncConfig("ring", rounds=(2 * R,)),
        "multiscale": SyncConfig("multiscale", levels=levels),
        "multiscale_exact": SyncConfig("multiscale", levels=levels,
                                       exact_fusion=True),
        "ring_int8": SyncConfig("ring", rounds=(2 * R,), compression=int8),
        "multiscale_topk": SyncConfig("multiscale", levels=levels,
                                      compression=topk),
        "multiscale_int8": SyncConfig("multiscale", levels=levels,
                                      compression=int8),
        "multiscale_rotated": SyncConfig("multiscale", levels=levels,
                                         rotation_period=4),
        # fault-tolerant variants (dist.failures / dist.robust): the same
        # lowering pipeline with failure injection + robust aggregation
        # fused into the executor — their extra collectives (mask
        # broadcasts, the trimmed-mean all-gather) are the measured cost
        # of the defense
        "multiscale_churn_survivor": SyncConfig(
            "multiscale", levels=levels, aggregation="survivor_weighted",
            failures=SyncFailureModel(churn_fraction=0.25, seed=0)),
        "multiscale_topk_churn": SyncConfig(
            "multiscale", levels=levels, compression=topk,
            failures=SyncFailureModel(churn_fraction=0.25, seed=0)),
        "allreduce_trimmed_byzantine": SyncConfig(
            "allreduce", aggregation="trimmed_mean",
            failures=SyncFailureModel(byzantine_fraction=0.125,
                                      byzantine_scale=10.0, seed=0)),
    }
    # serialized-vs-overlapped timing subset (see module docstring)
    OVERLAP_TIMED = {
        "allreduce", "hierarchical", "multiscale", "multiscale_exact",
    }
    # 16 replicas per "pod"; partition ids map through the assignment
    pod_of = device_pod_map(list(mesh.devices.flat), pod_size=16)
    can_time = jax.device_count() >= 2
    # standalone mode forces 32 emulated host devices — wallclock numbers
    # are then scheduling-emulation times, not real interconnect traffic;
    # label them so they are never read as hardware measurements
    emulated = "--xla_force_host_platform_device_count" in os.environ.get(
        "XLA_FLAGS", ""
    )
    grads = None
    if wallclock and can_time:  # identical for every strategy — build once
        grads = {
            k: jax.device_put(
                np.random.default_rng(0).normal(0, 1, a.shape).astype(
                    np.float32
                ),
                sh[k],
            )
            for k, a in grads_abs.items()
        }
        # stand-in backward for the serialized-vs-overlapped comparison:
        # a per-replica matmul chain, replica-sharded like the gradients
        act = jax.device_put(
            np.random.default_rng(1).normal(0, 1, (R, 128, 128)).astype(
                np.float32
            ),
            NamedSharding(mesh, P("replica", None, None)),
        )

        def backward_like(a):
            for _ in range(4):
                a = jnp.tanh(jnp.einsum("rij,rjk->rik", a, a) / 128.0)
            return a

        def time_compiled(fn, args, reps=3):
            jax.block_until_ready(fn(*args))  # warm-up / compile
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(fn(*args))
            return (time.perf_counter() - t0) * 1e3 / reps
    rows, lines = {}, []
    # dense-base mixing collectives per (strategy, levels, rounds,
    # exact_fusion): compressed/rotated variants inherit their base's
    # payload traffic for the wire_bytes model (iteration order puts
    # every base before its variants)
    base_bytes: dict = {}
    for name, cfg_s in strategies.items():
        plan = build_sync_plan(cfg_s, R)
        compressed = cfg_s.compression.scheme != "none"
        with set_mesh(mesh):
            if compressed:  # residuals ride along as a second input pytree
                fn = lambda g, r, s, p=plan: execute_sync(p, g, r, s)
                jitted = jax.jit(fn, in_shardings=(sh, sh, step_sh),
                                 out_shardings=(sh, sh))
                abs_args = (grads_abs, grads_abs, step_abs)
            else:
                fn = lambda g, s, p=plan: execute_sync(p, g, None, s)[0]
                jitted = jax.jit(fn, in_shardings=(sh, step_sh),
                                 out_shardings=sh)
                abs_args = (grads_abs, step_abs)
            compiled = jitted.lower(*abs_args).compile()
        stats = collective_bytes(compiled.as_text(), pod_size=16, pod_of=pod_of)
        frac = wire_fraction(cfg_s.compression)
        key = (cfg_s.strategy, plan.levels, plan.rounds, plan.exact_fusion)
        if not compressed and not plan.rotated and not plan.faulty:
            base_bytes.setdefault(key, stats.total_bytes)
        # variants must follow their dense base in `strategies`: falling back
        # to the variant's own lowering would count compression-compute
        # collectives (top-k sort gathers, rotation gathers) as wire payload
        assert key in base_bytes, (
            f"{name}: dense base for {key} must be listed before its variants"
        )
        mixing_bytes = base_bytes[key]
        rows[name] = stats.asdict()
        rows[name]["bytes_per_replica_payload"] = float(per_replica_bytes)
        rows[name]["wire_fraction"] = frac
        rows[name]["wire_bytes"] = float(mixing_bytes) * frac
        rows[name]["modeled_wire_bytes"] = plan_wire_bytes(plan, grads_abs)
        rows[name]["compression"] = cfg_s.compression.scheme
        rows[name]["rotation_period"] = cfg_s.rotation_period
        rows[name]["aggregation"] = cfg_s.aggregation
        fm = cfg_s.failures
        rows[name]["failures"] = (
            "none" if fm is None else
            f"churn={fm.churn_fraction:g},straggler="
            f"{fm.straggler_fraction:g},byzantine={fm.byzantine_fraction:g}")
        lines.append(csv_line(
            f"sync/{name}", 0.0,
            f"coll_bytes={stats.total_bytes} "
            f"cross_pod={stats.cross_pod_bytes} "
            f"ops={stats.count} "
            f"xpod_frac={stats.cross_pod_bytes/max(stats.total_bytes,1):.2f} "
            f"wire_bytes={rows[name]['wire_bytes']:.0f} "
            f"wire_frac={frac:.3f} "
            f"agg={cfg_s.aggregation} "
            f"failures={rows[name]['failures']}",
        ))
        if wallclock and can_time:
            args = (grads, jnp.int32(0))
            if compressed:
                res = {k: jax.device_put(np.zeros(a.shape, np.float32), sh[k])
                       for k, a in grads_abs.items()}
                args = (grads, res, jnp.int32(0))
            jax.block_until_ready(compiled(*args))  # warm-up
            reps = 3
            t0 = time.perf_counter()
            for _ in range(reps):
                jax.block_until_ready(compiled(*args))
            ms = (time.perf_counter() - t0) * 1e3 / reps
            rows[name]["wallclock_ms"] = ms
            rows[name]["wallclock_emulated"] = emulated
            lines.append(csv_line(
                f"sync/{name}/wallclock", ms * 1e3,
                f"ms_per_sync={ms:.1f} devices={jax.device_count()} "
                f"emulated={emulated}",
            ))
        if wallclock and can_time and name in OVERLAP_TIMED:
            # serialized (backward then sync, data-dependent) vs
            # overlapped (backward plus the sync of an independent
            # previous-step buffer — the async one-step pipeline), both
            # through the shard_map executor
            def serialized_fn(g, a, s, p=plan):
                h = backward_like(a)
                # the sync input depends on the backward product
                g = jax.tree.map(
                    lambda x: x + jnp.tanh(jnp.mean(h)) * 1e-20, g
                )
                out, _ = execute_sync_sharded(p, g, None, s, mesh=mesh)
                return out, h

            def overlapped_fn(g, a, s, p=plan):
                out, _ = execute_sync_sharded(p, g, None, s, mesh=mesh)
                h = backward_like(a)
                return out, h

            args2 = (grads, act, jnp.int32(0))
            ser_ms = time_compiled(jax.jit(serialized_fn), args2)
            ovl_ms = time_compiled(jax.jit(overlapped_fn), args2)
            rows[name]["serialized_ms"] = ser_ms
            rows[name]["overlapped_ms"] = ovl_ms
            rows[name]["overlap_delta_ms"] = ser_ms - ovl_ms
            lines.append(csv_line(
                f"sync/{name}/overlap", ovl_ms * 1e3,
                f"serialized_ms={ser_ms:.1f} overlapped_ms={ovl_ms:.1f} "
                f"delta_ms={ser_ms - ovl_ms:.1f} emulated={emulated}",
            ))
    if wallclock and not can_time:
        lines.append(csv_line(
            "sync/wallclock", 0.0,
            f"SKIP: single-device host (devices={jax.device_count()})",
        ))
    payload = {"levels": list(levels), "rows": rows}
    if wallclock:
        payload["wallclock_devices"] = jax.device_count()
        payload["wallclock_emulated"] = emulated
    else:
        # lowering-only runs keep the last measured wall-clock data so a
        # default CI pass does not erase it from the tracked artifact
        prev = load_artifact("sync_collectives") or {}
        for k in ("wallclock_devices", "wallclock_emulated"):
            if k in prev:
                payload[k] = prev[k]
        for name, row in payload["rows"].items():
            old = prev.get("rows", {}).get(name, {})
            for k in ("wallclock_ms", "wallclock_emulated", "serialized_ms",
                      "overlapped_ms", "overlap_delta_ms"):
                if k in old:
                    row[k] = old[k]
    save_artifact("sync_collectives", payload)
    return lines


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--wallclock", action="store_true",
                    help="time compiled sync_gradients on available devices")
    for line in run(wallclock=ap.parse_args().wallclock):
        print(line)
