"""Collective-traffic comparison of the gradient-sync strategies — the
paper's message-complexity claim measured on compiled HLO (DESIGN §2).

Lowered on a 32-replica mesh (2 "pods" x 16) with a ~64 MB gradient
tree; for each strategy we count collective ops/bytes and the cross-pod
share.  Expected, mirroring the paper:
  * allreduce: one global all-reduce per leaf — every byte crosses pods;
  * hierarchical: grouped reduces — cross-pod bytes shrink to the
    top-level fusion only;
  * ring: many collective-permute rounds (flat gossip is chatty — the
    paper's slow baseline);
  * multiscale: permutes mostly INSIDE cells; only representative
    promotion crosses pods — the O(n^(1/3))-hop analogue.

Run standalone (sets its own device count): python -m benchmarks.sync_collectives
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"

import json

import numpy as np


def run() -> list[str]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist import SyncConfig, suggest_levels, sync_gradients
    from repro.launch.hlo_analysis import collective_bytes
    from repro.launch.mesh import set_mesh
    from .common import csv_line, save_artifact

    R = 32
    mesh = jax.make_mesh((R,), ("replica",))
    grads_abs = {
        "w1": jax.ShapeDtypeStruct((R, 1024, 1024), jnp.float32),
        "w2": jax.ShapeDtypeStruct((R, 4096, 512), jnp.float32),
        "w3": jax.ShapeDtypeStruct((R, 65536,), jnp.float32),
    }
    per_replica_bytes = sum(
        np.prod(a.shape[1:]) * 4 for a in grads_abs.values()
    )
    sh = {k: NamedSharding(mesh, P("replica", *([None] * (len(a.shape) - 1))))
          for k, a in grads_abs.items()}
    levels = suggest_levels(R)           # (4, 2, 4) for 32
    strategies = {
        "allreduce": SyncConfig("allreduce"),
        "hierarchical": SyncConfig("hierarchical", levels=levels),
        "ring": SyncConfig("ring", rounds=(2 * R,)),
        "multiscale": SyncConfig("multiscale", levels=levels),
        "multiscale_exact": SyncConfig("multiscale", levels=levels,
                                       exact_fusion=True),
    }
    rows, lines = {}, []
    for name, cfg_s in strategies.items():
        with set_mesh(mesh):
            compiled = (
                jax.jit(
                    lambda g: sync_gradients(g, cfg_s, R),
                    in_shardings=(sh,), out_shardings=sh,
                )
                .lower(grads_abs)
                .compile()
            )
        # 16 replicas per "pod" for the cross-pod classification
        stats = collective_bytes(compiled.as_text(), pod_size=16)
        rows[name] = stats.asdict()
        rows[name]["bytes_per_replica_payload"] = float(per_replica_bytes)
        lines.append(csv_line(
            f"sync/{name}", 0.0,
            f"coll_bytes={stats.total_bytes} "
            f"cross_pod={stats.cross_pod_bytes} "
            f"ops={stats.count} "
            f"xpod_frac={stats.cross_pod_bytes/max(stats.total_bytes,1):.2f}",
        ))
    save_artifact("sync_collectives", {"levels": list(levels), "rows": rows})
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
