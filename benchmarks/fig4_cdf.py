"""Paper Fig. 4: CDF of per-node transmissions (n=2000, eps=1e-4).

Expected (paper): the busiest multiscale node transmits less than
~22% of path-averaging nodes do — load is spread, no hot relays.

Multiscale trials run vmapped through the plan/execute engine; the CDF
aggregates node sends over all trials.  Wall-clock per algorithm and the
backend are recorded in the artifact.
"""
from __future__ import annotations

import numpy as np

from repro.core import multiscale_gossip, path_averaging, random_geometric_graph

from .common import csv_line, exec_options, save_artifact, timed


def run(n: int = 2000, eps: float = 1e-4, seed: int = 0, trials: int = 3,
        backend: str = "lax", schedule: str = "presampled",
        artifact: str = "fig4_cdf") -> list[str]:
    g = random_geometric_graph(n, seed=42)
    x0 = np.random.default_rng(7).normal(0, 1, n)
    ms, t_ms = timed(
        multiscale_gossip, g, x0, eps=eps, seed=seed, weighted=True,
        trials=trials, options=exec_options(backend, schedule),
    )
    pa_runs, t_pa = timed(lambda: [
        path_averaging(g, x0, eps=eps, seed=seed + t) for t in range(trials)
    ])
    ms_by_trial = np.atleast_2d(ms.node_sends)
    ms_sends = np.sort(ms_by_trial.ravel())
    pa_sends = np.sort(np.concatenate([r.node_sends for r in pa_runs]))
    # fraction of PA nodes transmitting more than the busiest MS node,
    # paired per trial (the pooled max over T trials is an order
    # statistic that would bias the single-run paper metric downward)
    frac_per_trial = [
        float((pa_runs[t].node_sends > ms_by_trial[t].max()).mean())
        for t in range(trials)
    ]
    frac_pa_above_ms_max = float(np.mean(frac_per_trial))
    # per-trial busiest-node means match the paper's single-run metric;
    # the quantiles/CDFs below pool ALL trials' nodes and are labeled so
    # (a pooled max is an order statistic that grows with T)
    ms_max = float(np.mean([ms_by_trial[t].max() for t in range(trials)]))
    pa_max = float(np.mean([r.node_sends.max() for r in pa_runs]))
    qs = [0.5, 0.9, 0.99, 1.0]
    stride = max(1, len(ms_sends) // 200)
    payload = {
        "n": n,
        "trials": trials,
        "backend": backend,
        "schedule": schedule,
        "trial_mode": "vmapped",
        "wall_clock_s": {"multiscale": t_ms, "path_averaging": t_pa},
        "ms_max_trial_mean": ms_max,
        "pa_max_trial_mean": pa_max,
        "frac_pa_above_ms_max_per_trial": frac_per_trial,
        "ms_quantiles_pooled": {
            str(q): float(np.quantile(ms_sends, q)) for q in qs
        },
        "pa_quantiles_pooled": {
            str(q): float(np.quantile(pa_sends, q)) for q in qs
        },
        "frac_pa_nodes_above_ms_max": frac_pa_above_ms_max,
        "ms_cdf_sends_pooled": ms_sends[::stride].tolist(),
        "pa_cdf_sends_pooled": pa_sends[::stride].tolist(),
    }
    save_artifact(artifact, payload)
    us = (t_ms + t_pa) * 1e6
    return [
        csv_line(
            "fig4/ms_max_vs_pa", us,
            f"ms_max={ms_max:.0f} pa_max={pa_max:.0f} "
            f"frac_pa_above_ms_max={frac_pa_above_ms_max:.2f} "
            "(paper: ~0.22)",
        )
    ]


if __name__ == "__main__":
    from .common import bench_cli

    bench_cli(run)
