"""Paper Fig. 4: CDF of per-node transmissions (n=2000, eps=1e-4).

Expected (paper): the busiest multiscale node transmits less than
~22% of path-averaging nodes do — load is spread, no hot relays.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import multiscale_gossip, path_averaging, random_geometric_graph

from .common import csv_line, save_artifact


def run(n: int = 2000, eps: float = 1e-4, seed: int = 0) -> list[str]:
    t0 = time.time()
    g = random_geometric_graph(n, seed=42)
    x0 = np.random.default_rng(7).normal(0, 1, n)
    ms = multiscale_gossip(g, x0, eps=eps, seed=seed, weighted=True)
    pa = path_averaging(g, x0, eps=eps, seed=seed)
    ms_sends = np.sort(ms.node_sends)
    pa_sends = np.sort(pa.node_sends)
    # fraction of PA nodes transmitting more than the busiest MS node
    frac_pa_above_ms_max = float((pa_sends > ms_sends[-1]).mean())
    qs = [0.5, 0.9, 0.99, 1.0]
    payload = {
        "n": n,
        "ms_quantiles": {str(q): float(np.quantile(ms_sends, q)) for q in qs},
        "pa_quantiles": {str(q): float(np.quantile(pa_sends, q)) for q in qs},
        "frac_pa_nodes_above_ms_max": frac_pa_above_ms_max,
        "ms_cdf_sends": ms_sends[:: max(1, n // 200)].tolist(),
        "pa_cdf_sends": pa_sends[:: max(1, n // 200)].tolist(),
    }
    save_artifact("fig4_cdf", payload)
    us = (time.time() - t0) * 1e6
    return [
        csv_line(
            "fig4/ms_max_vs_pa", us,
            f"ms_max={int(ms_sends[-1])} pa_max={int(pa_sends[-1])} "
            f"frac_pa_above_ms_max={frac_pa_above_ms_max:.2f} "
            "(paper: ~0.22)",
        )
    ]


if __name__ == "__main__":
    for line in run():
        print(line)
