"""Benchmark harness — one entry per paper table/figure + system layers.

Prints ``name,us_per_call,derived`` CSV.  Profiles:
  default: reduced trial counts sized for a single-core CPU container;
  --full:  the paper's trial counts / sizes (longer).

Every figure benchmark exposes the same `run()` surface — `trials`,
`backend`, `schedule`, `artifact` plus its own size knobs — so the
harness dispatches them from one profile table instead of
special-casing each module; `--backend` / `--schedule` apply to all of
them at once.

The dry-run roofline cells are produced separately
(`python -m repro.launch.dryrun --all`, hours of XLA compile time) and
aggregated here if present.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trials (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig3,roofline")
    ap.add_argument("--backend", default="lax",
                    help="engine backend for every figure benchmark")
    ap.add_argument("--schedule", default="presampled",
                    help="engine schedule mode for every figure benchmark")
    args = ap.parse_args()

    from . import (
        fig2_levels, fig3_vs_path_averaging, fig4_cdf, fig5_failures,
        gossip_trajectory, kernel_bench, large_n, roofline, serve_bench,
        table1_node_utilization,
    )

    # figure suites share one run() signature; each entry is
    # (module, default-profile kwargs, --full overrides)
    figures = {
        "fig2": (fig2_levels, dict(n=2000, trials=3),
                 dict(n=5000, trials=10)),
        "fig3": (fig3_vs_path_averaging,
                 dict(sizes=(500, 1000, 2000, 4000, 8000), trials=3),
                 dict(trials=10)),
        "fig4": (fig4_cdf, dict(n=2000), {}),
        "fig5": (fig5_failures, dict(n=2000, scenario_trials=3),
                 dict(scenario_trials=10)),
        "table1": (table1_node_utilization, dict(n=2000), dict(n=5000)),
    }

    def fig_suite(mod, base, full):
        kwargs = dict(base)
        if args.full:
            kwargs.update(full)
        return lambda: mod.run(
            backend=args.backend, schedule=args.schedule, **kwargs
        )

    suites = {name: fig_suite(*spec) for name, spec in figures.items()}
    suites.update({
        "kernels": kernel_bench.run,
        "sync": lambda: _subprocess_lines("benchmarks.sync_collectives"),
        "roofline": roofline.run,
        "gossip": gossip_trajectory.run,
        "large_n": lambda: large_n.run(
            n=1_000_000 if args.full else 100_000
        ),
        "serve": serve_bench.run,
    })
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)


def _subprocess_lines(module: str) -> list[str]:
    """Run a benchmark that needs its own XLA device count in a fresh
    process (the forced count must precede jax init)."""
    proc = subprocess.run(
        [sys.executable, "-m", module], capture_output=True, text=True,
        timeout=1800,
    )
    if proc.returncode != 0:
        return [f"{module}/ERROR,0.0,exit={proc.returncode}: "
                f"{proc.stderr.strip().splitlines()[-1] if proc.stderr else ''}"]
    return [l for l in proc.stdout.splitlines() if l.strip()]


if __name__ == "__main__":
    main()
