"""Benchmark harness — one entry per paper table/figure + system layers.

Prints ``name,us_per_call,derived`` CSV.  Profiles:
  default: reduced trial counts sized for a single-core CPU container;
  --full:  the paper's trial counts / sizes (longer).

The dry-run roofline cells are produced separately
(`python -m repro.launch.dryrun --all`, hours of XLA compile time) and
aggregated here if present.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale trials (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig3,roofline")
    args = ap.parse_args()

    from . import (
        fig2_levels, fig3_vs_path_averaging, fig4_cdf, fig5_failures,
        gossip_trajectory, kernel_bench, large_n, roofline, serve_bench,
        table1_node_utilization,
    )

    suites = {
        "fig2": lambda: fig2_levels.run(
            n=5000 if args.full else 2000, trials=10 if args.full else 3
        ),
        "fig3": lambda: fig3_vs_path_averaging.run(
            sizes=(500, 1000, 2000, 4000, 8000),
            trials=10 if args.full else 3,
        ),
        "fig4": lambda: fig4_cdf.run(n=2000),
        "fig5": lambda: fig5_failures.run(n=2000),
        "table1": lambda: table1_node_utilization.run(
            n=5000 if args.full else 2000
        ),
        "kernels": kernel_bench.run,
        "sync": lambda: _subprocess_lines("benchmarks.sync_collectives"),
        "roofline": roofline.run,
        "gossip": gossip_trajectory.run,
        "large_n": lambda: large_n.run(
            n=1_000_000 if args.full else 100_000
        ),
        "serve": serve_bench.run,
    }
    if args.only:
        keep = set(args.only.split(","))
        suites = {k: v for k, v in suites.items() if k in keep}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        try:
            for line in fn():
                print(line, flush=True)
        except Exception as e:
            traceback.print_exc(file=sys.stderr)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}", flush=True)


def _subprocess_lines(module: str) -> list[str]:
    """Run a benchmark that needs its own XLA device count in a fresh
    process (the forced count must precede jax init)."""
    proc = subprocess.run(
        [sys.executable, "-m", module], capture_output=True, text=True,
        timeout=1800,
    )
    if proc.returncode != 0:
        return [f"{module}/ERROR,0.0,exit={proc.returncode}: "
                f"{proc.stderr.strip().splitlines()[-1] if proc.stderr else ''}"]
    return [l for l in proc.stdout.splitlines() if l.strip()]


if __name__ == "__main__":
    main()
