"""Paper Fig. 2: messages to 1e-4 accuracy vs number of hierarchy
levels k.  Expected: diminishing reward beyond 4-5 levels."""
from __future__ import annotations

import time

import numpy as np

from repro.core import build_plan, multiscale_gossip, random_geometric_graph

from .common import csv_line, exec_options, save_artifact, timed


def run(n: int = 2000, trials: int = 3, eps: float = 1e-4,
        max_k: int = 6, backend: str = "lax", schedule: str = "presampled",
        artifact: str = "fig2_levels") -> list[str]:
    opts = exec_options(backend, schedule)
    rows = {}
    plan_build_s: dict = {}
    graph_gen: list[float] = []
    t0 = time.time()
    for k in range(2, max_k + 1):
        msgs, errs, builds = [], [], []
        for t in range(trials):
            g, g_dt = timed(random_geometric_graph, n, seed=100 + t)
            graph_gen.append(g_dt)
            x0 = np.random.default_rng(t).normal(0, 1, n)
            # the plan multiscale_gossip would build internally, made
            # explicit so its build_seconds breakdown can be recorded
            plan = build_plan(g, k=k, seed=t)
            builds.append(plan.build_seconds or {})
            r = multiscale_gossip(
                g, x0, eps=eps, k=k, seed=t, weighted=True, plan=plan,
                options=opts,
            )
            msgs.append(r.messages)
            errs.append(r.error(x0))
        rows[k] = {
            "messages_mean": float(np.mean(msgs)),
            "messages_std": float(np.std(msgs)),
            "err_mean": float(np.mean(errs)),
        }
        stages = sorted({s for b in builds for s in b})
        plan_build_s[k] = {
            s: float(np.mean([b.get(s, 0.0) for b in builds])) for s in stages
        }
    save_artifact(
        artifact,
        {"n": n, "eps": eps, "backend": backend, "schedule": schedule,
         "rows": rows, "plan_build_s": plan_build_s,
         "graph_gen_s": float(np.mean(graph_gen))},
    )
    total_us = (time.time() - t0) * 1e6
    out = []
    best_k = min(rows, key=lambda k: rows[k]["messages_mean"])
    for k, r in rows.items():
        out.append(csv_line(
            f"fig2/levels_k{k}", total_us / len(rows),
            f"messages={r['messages_mean']:.0f} err={r['err_mean']:.2e}",
        ))
    out.append(csv_line(
        "fig2/diminishing_reward", total_us,
        f"best_k={best_k} n={n} (paper: 4-5 levels suffice)",
    ))
    return out


if __name__ == "__main__":
    from .common import bench_cli

    bench_cli(run)
