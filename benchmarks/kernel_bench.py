"""Kernel & engine microbenchmarks (CPU host; Pallas kernels target TPU
and are validated in interpret mode — these numbers time the XLA oracle
paths and the simulation engine, which ARE the CPU-resident layers)."""
from __future__ import annotations

import time

import numpy as np


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        r = fn(*args)
    try:
        r.block_until_ready()
    except AttributeError:
        pass
    return (time.time() - t0) / reps * 1e6  # us


def pair_apply_bench(
    sweep=((64, 16), (64, 64), (256, 16)), B: int = 32, V: int = 2,
    as_rows: bool = True,
):
    """Presampled-schedule value-pass sweep over (schedule length T,
    cell size C): the lax scan oracle, the Pallas pair-apply kernel in
    interpret mode (kernel-validation path — NOT TPU performance), and
    the associative-scan matmul composition (compose + cell-mixing
    apply, the MXU-facing backend's XLA oracle path).

    Returns CSV rows (`as_rows=True`) or a {key: us_per_call} dict for
    the BENCH_gossip.json trajectory.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.schedule import compose_schedule
    from repro.kernels.cell_mixing import cell_mixing
    from repro.kernels.pair_apply import pair_apply, pair_apply_ref
    from .common import csv_line

    rng = np.random.default_rng(11)
    rows, flat = [], {}
    for T, C in sweep:
        x = jnp.asarray(rng.normal(size=(B, C, V)), jnp.float32)
        i = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
        j = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
        ui = jnp.asarray(rng.uniform(size=(T, B)) < 0.9)
        uj = jnp.asarray(rng.uniform(size=(T, B)) < 0.95)

        lax_f = jax.jit(pair_apply_ref)
        matmul_f = jax.jit(lambda x, i, j, ui, uj: cell_mixing(
            compose_schedule(C, i, j, ui, uj), x, rounds=1, use_pallas=False
        ))
        variants = {
            "lax": lambda: lax_f(x, i, j, ui, uj),
            "pallas_interp": lambda: pair_apply(
                x, i, j, ui, uj, use_pallas=True, interpret=True
            ),
            "matmul": lambda: matmul_f(x, i, j, ui, uj),
        }
        for name, fn in variants.items():
            us = _time(fn, reps=3 if name == "pallas_interp" else 5)
            key = f"T{T}_C{C}_{name}"
            flat[key] = us
            rows.append(csv_line(
                f"kernel/pair_apply_{key}", us,
                f"B={B} V={V} ticks_per_us={T/max(us,1e-9):.2f}"
                + (" (interpreter, not TPU perf)"
                   if name == "pallas_interp" else ""),
            ))
    return rows if as_rows else flat


def run() -> list[str]:
    import jax.numpy as jnp

    from repro.core import batched_graphs, gossip_until, random_geometric_graph
    from repro.kernels.cell_mixing import cell_mixing, mixing_matrix
    from .common import csv_line

    lines = []

    # batched async gossip engine throughput
    g = random_geometric_graph(1000, seed=3)
    from repro.core.partition import build_partition
    part = build_partition(1000)
    cell = part.cell_of(g.coords, part.k)
    from repro.core.rgg import induced_subgraph
    subs = [induced_subgraph(g, np.where(cell == c)[0])[0]
            for c in np.unique(cell)]
    neighbors, degrees, n_nodes, mask = batched_graphs(subs)
    x0 = np.where(mask, np.random.default_rng(0).normal(size=mask.shape), 0)

    t0 = time.time()
    res = gossip_until(x0.astype(np.float32), neighbors, degrees, n_nodes,
                       eps=-1.0, fixed_ticks=512, seed=0)
    dt = time.time() - t0
    ticks = int(res.ticks.sum())
    lines.append(csv_line(
        "engine/async_ticks", dt * 1e6,
        f"cells={len(subs)} ticks={ticks} ticks_per_sec={ticks/dt:.0f}",
    ))

    # presampled-schedule value pass (lax vs pallas vs associative-scan)
    lines.extend(pair_apply_bench())

    # synchronous cell mixing (jnp oracle = production XLA path)
    w = jnp.asarray(mixing_matrix(neighbors, degrees, n_nodes))
    xb = jnp.asarray(np.where(mask[..., None], np.random.default_rng(1)
                              .normal(size=(*mask.shape, 128)), 0), jnp.float32)
    us = _time(lambda: cell_mixing(w, xb, rounds=8, use_pallas=False))
    B, C = mask.shape
    flops = 2 * B * C * C * 128 * 8
    lines.append(csv_line(
        "kernel/cell_mixing_r8_d128", us,
        f"B={B} m={C} gflops_per_call={flops/1e9:.2f}",
    ))

    # flash attention oracle vs chunked XLA path
    from repro.kernels.flash_attention import attention_ref
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, 4, 1024, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 1024, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(1024)[None], (1, 1024))
    import jax
    ref_f = jax.jit(lambda: attention_ref(q, k, v, causal=True))
    chk_f = jax.jit(lambda: chunked_attention(
        q, k, v, pos, pos, causal=True, window=None, softcap=None,
        scale=0.125, chunk=256))
    us_ref = _time(ref_f)
    us_chk = _time(chk_f)
    lines.append(csv_line("kernel/attention_ref_1k", us_ref, "full softmax"))
    lines.append(csv_line(
        "kernel/attention_chunked_1k", us_chk,
        f"online-softmax scan (flash XLA path) ratio={us_chk/us_ref:.2f}",
    ))

    # rwkv6 scan oracle
    from repro.kernels.rwkv6 import rwkv6_ref
    r_ = jnp.asarray(rng.normal(size=(8, 512, 64)), jnp.float32)
    w_ = jnp.asarray(rng.uniform(0.9, 0.999, size=(8, 512, 64)), jnp.float32)
    u_ = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    wkv_f = jax.jit(lambda: rwkv6_ref(r_, r_, r_, w_, u_))
    us_wkv = _time(wkv_f)
    lines.append(csv_line("kernel/rwkv6_scan_512", us_wkv, "BH=8 N=64"))
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
