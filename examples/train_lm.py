"""End-to-end training driver: an LM trained with the full substrate —
deterministic data pipeline, AdamW + cosine schedule, atomic
checkpointing with auto-resume, metrics JSONL.

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset smoke --steps 20

Presets (decoder-only llama-style):
  smoke : ~2M params  (seconds on CPU)
  25m   : ~25M params
  100m  : ~115M params (the assignment's "~100M for a few hundred
          steps"; several hours on a 1-core CPU container — sized for a
          real accelerator)
"""
import argparse
import os

import jax
import numpy as np

from repro.data import SyntheticLM
from repro.models import Transformer
from repro.models.config import ModelConfig
from repro.optim import adamw, cosine_schedule
from repro.train import Trainer, init_train_state, make_train_step

PRESETS = {
    "smoke": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                  head_dim=32, d_ff=256, vocab_size=2048, seq=128, batch=4),
    "25m": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=4,
                head_dim=64, d_ff=1536, vocab_size=8192, seq=256, batch=8),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768, seq=512, batch=8),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"train-lm-{args.preset}", family="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        tie_embeddings=True, remat=False, dtype="float32",
    )
    model = Transformer(cfg, model_axis=1)
    print(f"model: {model.num_params / 1e6:.1f}M params")

    opt = adamw(weight_decay=0.01)
    lr = cosine_schedule(args.lr, warmup=20, total=args.steps)
    data = SyntheticLM(cfg.vocab_size, seq_len=p["seq"],
                       global_batch=p["batch"], seed=0)
    step_fn = make_train_step(cfg, opt, lr, dp=None)
    state = init_train_state(model.init(jax.random.PRNGKey(0)), opt)

    os.makedirs(args.ckpt_dir, exist_ok=True)
    trainer = Trainer(
        step_fn, state, data,
        ckpt_dir=args.ckpt_dir, save_every=50,
        log_path=os.path.join(args.ckpt_dir, "metrics.jsonl"),
    )
    history = trainer.run(args.steps)
    first, last = history[0], history[-1]
    print(f"step {first['step']}: loss={first['loss']:.3f}")
    print(f"step {last['step']}: loss={last['loss']:.3f} "
          f"({last['sec_per_step']:.2f}s/step)")
    assert last["loss"] < first["loss"], "loss should decrease"
    print(f"checkpoints under {args.ckpt_dir} — rerun to auto-resume")


if __name__ == "__main__":
    main()
