"""Gossip-coordinated serving fleet demo.

Runs N simulated replicas (real BatchingEngine/PageTable scheduling,
stubbed model) under streaming Poisson traffic three times — once per
router — and prints throughput, admission latency, and control-plane
cost.  The point: power-of-two-choices routing from purely gossiped
load estimates tracks the centralized least-loaded oracle while paying
only the multiscale control-plane bytes.

    PYTHONPATH=src python examples/serve_fleet.py --replicas 16
"""
import argparse

from repro.serve import ROUTERS, FleetConfig, run_fleet


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--ticks", type=int, default=240)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--gossip-interval", type=int, default=4)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="requests/tick (0 = ~90%% of fleet capacity)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    results = {}
    for router in ROUTERS:
        cfg = FleetConfig(
            replicas=args.replicas, ticks=args.ticks,
            slots_per_replica=args.slots,
            gossip_interval=args.gossip_interval,
            arrival_rate=args.arrival_rate, router=router, seed=args.seed,
        )
        results[router] = run_fleet(cfg)

    print(f"fleet: {args.replicas} replicas x {args.slots} slots, "
          f"{args.ticks} ticks, arrival {cfg.resolved_rate():.2f} req/tick")
    hdr = (f"{'router':>12} {'tok/tick':>9} {'done':>6} {'adm.lat':>8} "
           f"{'p95':>6} {'pages':>6} {'ctrl bytes':>11}")
    print(hdr)
    for router, r in results.items():
        print(f"{router:>12} {r.throughput:>9.1f} {r.completed:>6d} "
              f"{r.admission_latency_mean:>8.2f} "
              f"{r.admission_latency_p95:>6.1f} "
              f"{r.page_utilization_mean:>6.2f} {r.control_bytes:>11d}")

    p2c, oracle = results["p2c_gossip"], results["oracle"]
    ratio = p2c.throughput / max(oracle.throughput, 1e-9)
    print(f"\np2c_gossip / oracle throughput: {ratio:.3f}")
    print(f"control plane: {p2c.control_rounds} rounds, "
          f"{p2c.control_messages} messages, "
          f"{p2c.bytes_per_round:.0f} bytes/round "
          f"({p2c.payload_values} payload values/packet)")
    if p2c.level_messages is not None:
        print(f"last round per-level messages: "
              f"{p2c.level_messages.tolist()}")


if __name__ == "__main__":
    main()
