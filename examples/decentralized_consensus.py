"""The paper's technique as gradient synchronization: decentralized
training with multiscale gossip vs exact all-reduce.

R replicas each train on their own batch shard; gradients are mixed by
the selected strategy under a static `SyncPlan` (plan/execute split).
Multiscale gossip keeps the replicas within a consensus ball (the
paper's eps) at a fraction of the flat-gossip message cost — printed
per step as `consensus`, alongside the modeled wire megabytes per sync.

Compression (`--compress topk|int8`) exchanges error-feedback
compressed payloads (unsent mass rides per-replica residuals in the
train state); `--rotate P` cycles the paper's randomized cells: a
P-entry permutation schedule re-assigns replicas to cells every step.
`--overlap` switches to the async pipeline (one-step-delayed
averaging): each step applies the previous step's mixed gradients
while the fresh ones ride the double-buffered `prev_grads` state, so
gossip overlaps backward compute (step 0 is warmup).

    PYTHONPATH=src python examples/decentralized_consensus.py --strategy multiscale
    PYTHONPATH=src python examples/decentralized_consensus.py \
        --strategy multiscale --compress topk --rotate 4 --overlap
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.dist import CompressionConfig, SyncConfig, suggest_levels
from repro.models import Transformer
from repro.models.config import ModelConfig
from repro.optim import sgdm
from repro.train import init_decentralized_state, make_decentralized_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="multiscale",
                    choices=["allreduce", "hierarchical", "ring", "multiscale"])
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--compress", default="none", choices=["none", "topk", "int8"],
                    help="error-feedback payload compression scheme")
    ap.add_argument("--topk-fraction", type=float, default=0.25)
    ap.add_argument("--rotate", type=int, default=0, metavar="P",
                    help="randomized-cell rotation period (0 = static cells)")
    ap.add_argument("--overlap", action="store_true",
                    help="one-step-delayed averaging: sync overlaps backward")
    args = ap.parse_args()

    R = args.replicas
    cfg = ModelConfig(
        name="consensus-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=1024,
        remat=False, dtype="float32",
    )
    model = Transformer(cfg, model_axis=1)
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), base)
    opt = sgdm()
    levels = suggest_levels(R)
    sync = SyncConfig(
        strategy=args.strategy, levels=levels,
        compression=CompressionConfig(args.compress, args.topk_fraction),
        rotation_period=args.rotate,
        overlap="one_step" if args.overlap else "none",
    )
    state = init_decentralized_state(params_r, opt, sync=sync)
    print(f"strategy={args.strategy} R={R} levels={levels} "
          f"compress={args.compress} rotate={args.rotate or 'off'} "
          f"overlap={'one_step' if args.overlap else 'off'} "
          f"(paper rule: cells of ~R^(2/3))")
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 5e-2, sync, R))
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=R * 2, seed=0)
    for s in range(args.steps):
        b = data.batch_at(s)
        batch = {k: jnp.asarray(v.reshape(R, 2, *v.shape[1:])) for k, v in b.items()}
        state, m = step(state, batch)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss={float(m['loss']):.3f}  "
                  f"consensus={float(m['consensus_distance']):.2e}  "
                  f"wire={float(m['wire_bytes']) / 2**20:.1f}MiB  "
                  f"overlap={float(m['sync_overlap_fraction']):.0f}")
    if args.strategy in ("allreduce", "hierarchical") and args.compress == "none":
        assert float(m["consensus_distance"]) < 1e-6, "exact modes stay in sync"
        print("exact strategy: replicas remain bitwise-identical  OK")
    else:
        assert float(m["consensus_distance"]) < 1e-1, "replicas drifted apart"
        print("gossip/compressed sync: replicas stay within the consensus "
              "ball (paper Thm 2 analogue)")


if __name__ == "__main__":
    main()
