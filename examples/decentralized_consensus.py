"""The paper's technique as gradient synchronization: decentralized
training with multiscale gossip vs exact all-reduce.

R replicas each train on their own batch shard; gradients are mixed by
the selected strategy.  Multiscale gossip keeps the replicas within a
consensus ball (the paper's eps) at a fraction of the flat-gossip
message cost — printed per step as `consensus`.

    PYTHONPATH=src python examples/decentralized_consensus.py --strategy multiscale
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.dist import SyncConfig, suggest_levels
from repro.models import Transformer
from repro.models.config import ModelConfig
from repro.optim import sgdm
from repro.train import init_decentralized_state, make_decentralized_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="multiscale",
                    choices=["allreduce", "hierarchical", "ring", "multiscale"])
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    R = args.replicas
    cfg = ModelConfig(
        name="consensus-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=1024,
        remat=False, dtype="float32",
    )
    model = Transformer(cfg, model_axis=1)
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), base)
    opt = sgdm()
    state = init_decentralized_state(params_r, opt)
    levels = suggest_levels(R)
    sync = SyncConfig(strategy=args.strategy, levels=levels)
    print(f"strategy={args.strategy} R={R} levels={levels} "
          f"(paper rule: cells of ~R^(2/3))")
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 5e-2, sync, R))
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=R * 2, seed=0)
    for s in range(args.steps):
        b = data.batch_at(s)
        batch = {k: jnp.asarray(v.reshape(R, 2, *v.shape[1:])) for k, v in b.items()}
        state, m = step(state, batch)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss={float(m['loss']):.3f}  "
                  f"consensus={float(m['consensus_distance']):.2e}")
    if args.strategy in ("allreduce", "hierarchical"):
        assert float(m["consensus_distance"]) < 1e-6, "exact modes stay in sync"
        print("exact strategy: replicas remain bitwise-identical  OK")
    else:
        print("gossip strategy: replicas stay within the consensus ball "
              "(paper Thm 2 analogue)")


if __name__ == "__main__":
    main()
