"""Quickstart: multiscale gossip on a random geometric graph.

Reproduces the paper's headline result in one page: multiscale gossip
reaches eps-accuracy with a fraction of path averaging's messages, its
longest routed message is O(n^(1/3)) hops, and the error respects the
Theorem 2 bound.

    PYTHONPATH=src python examples/quickstart.py [--n 2000]
"""
import argparse

import numpy as np

from repro.core import (
    multiscale_gossip, path_averaging, random_geometric_graph,
    standard_gossip, theorem2_bound,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--eps", type=float, default=1e-4)
    args = ap.parse_args()

    print(f"building RGG with n={args.n} ...")
    g = random_geometric_graph(args.n, seed=0)
    print(f"  edges={g.num_edges}  avg_degree={g.degrees.mean():.1f}  "
          f"connected={g.is_connected()}")
    x0 = np.random.default_rng(0).normal(0.0, 1.0, args.n)

    ms = multiscale_gossip(g, x0, eps=args.eps, seed=0, weighted=True)
    part = ms.partition
    print(f"\nmultiscale gossip (k={part.k}, sides={part.sides}):")
    print(f"  messages        = {ms.messages:,}")
    print(f"  final error     = {ms.error(x0):.2e} "
          f"(Thm 2 bound: {theorem2_bound(args.n, args.eps):.2e})")
    print(f"  longest route   = {max(l.max_hops for l in ms.levels)} hops "
          f"(O(n^(1/3)) = {args.n ** (1 / 3):.0f})")

    pa = path_averaging(g, x0, eps=args.eps, seed=0)
    print(f"\npath averaging [13]:")
    print(f"  messages        = {pa.messages:,}  ({pa.messages / ms.messages:.2f}x multiscale)")
    print(f"  final error     = {pa.error(x0):.2e}")

    if args.n <= 2000:
        sg = standard_gossip(g, x0, eps=1e-3, seed=0)
        print(f"\nstandard neighbor gossip [2] (eps=1e-3 — it is slow):")
        print(f"  messages        = {sg.messages:,}")
    print("\npaper claim check: multiscale < path averaging < standard  OK")


if __name__ == "__main__":
    main()
