"""Batched serving demo: train-free random-weight model, batched greedy
generation through the KV-cache decode path (the same `decode_step` the
decode_32k / long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.models import Transformer
from repro.serve import Generator


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {model.num_params / 1e6:.2f}M params (reduced config)")

    frames = None
    if cfg.encoder_layers:
        frames = jax.numpy.asarray(
            np.random.default_rng(0).normal(0, 1, (args.batch, cfg.encoder_seq, cfg.d_model)),
            jax.numpy.float32,
        )
    gen = Generator(cfg, params, max_len=128, temperature=0.8)
    prompts = np.random.default_rng(1).integers(
        2, cfg.vocab_size, (args.batch, 8)
    ).astype(np.int32)
    # first generate compiles prefill + decode; report it separately so
    # steady-state tok/s excludes XLA compile time
    t0 = time.time()
    gen.generate(prompts, steps=1, seed=0, frames=frames)
    jit_warmup_s = time.time() - t0
    t0 = time.time()
    out = gen.generate(prompts, steps=args.steps, seed=0, frames=frames)
    dt = time.time() - t0
    live = gen.last_stats["live_tokens"]
    print(f"jit_warmup_s: {jit_warmup_s:.2f}")
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({live / dt:.0f} live tok/s batched, "
          f"{live}/{out.size} live)")
    print("sample token ids:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
