"""Fault-tolerant decentralized training: failure injection + robust
aggregation + EF-residual recovery (dist.failures / dist.robust).

R replicas train with multiscale gossip sync while a static
`SyncFailureModel` injects replica faults each sync step — churned
replicas (down, transmit nothing), stragglers (miss the round), and
Byzantine replicas (transmit 10x-scaled corrupted gradients).  The
chosen `--aggregation` defends the mix:

* ``survivor_weighted`` — renormalizes the doubly-stochastic gossip
  mass over the live replicas (the natural defense for absence faults);
* ``trimmed_mean`` / ``coordinate_median`` — consensus-style robust
  statistics that bound the Byzantine contribution;
* ``mean`` — no defense (watch the loss blow up under --byzantine).

With ``--compress`` the error-feedback residuals double as the recovery
buffer: a dropped replica's whole accumulator (gradient + residual)
stays in its residual — bitwise, nothing is lost — and re-enters the
stream the moment it rejoins.

Per step the run prints the degradation trio next to the loss:
`survivor_err` (consensus distance over LIVE replicas only),
`eff_frac` (live fraction this sync), `rejected` (Byzantine gradients
excluded by the robust reduction).

    PYTHONPATH=src python examples/robust_training.py \
        --churn 0.25 --byzantine 0.125 --aggregation trimmed_mean
    PYTHONPATH=src python examples/robust_training.py \
        --churn 0.25 --aggregation survivor_weighted --compress topk
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.dist import (
    AGGREGATIONS, CompressionConfig, SyncConfig, SyncFailureModel,
    suggest_levels,
)
from repro.models import Transformer
from repro.models.config import ModelConfig
from repro.optim import sgdm
from repro.train import init_decentralized_state, make_decentralized_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="multiscale",
                    choices=["allreduce", "hierarchical", "ring", "multiscale"])
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--churn", type=float, default=0.25,
                    help="fraction of replicas down each sync")
    ap.add_argument("--stragglers", type=float, default=0.0,
                    help="fraction of replicas missing each sync round")
    ap.add_argument("--byzantine", type=float, default=0.0,
                    help="fraction transmitting corrupted gradients")
    ap.add_argument("--byzantine-scale", type=float, default=10.0)
    ap.add_argument("--aggregation", default="survivor_weighted",
                    choices=list(AGGREGATIONS))
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"],
                    help="EF compression (residuals = the recovery buffer)")
    ap.add_argument("--topk-fraction", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    R = args.replicas
    cfg = ModelConfig(
        name="robust-demo", family="dense", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=1024,
        remat=False, dtype="float32",
    )
    model = Transformer(cfg, model_axis=1)
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), base)
    opt = sgdm()
    failures = SyncFailureModel(
        churn_fraction=args.churn,
        straggler_fraction=args.stragglers,
        byzantine_fraction=args.byzantine,
        byzantine_scale=args.byzantine_scale,
        seed=args.seed,
    )
    sync = SyncConfig(
        strategy=args.strategy, levels=suggest_levels(R),
        compression=CompressionConfig(args.compress, args.topk_fraction),
        aggregation=args.aggregation,
        failures=failures if failures.active else None,
    )
    state = init_decentralized_state(params_r, opt, sync=sync)
    print(f"strategy={args.strategy} R={R} agg={args.aggregation} "
          f"churn={args.churn:g} stragglers={args.stragglers:g} "
          f"byzantine={args.byzantine:g}x{args.byzantine_scale:g} "
          f"compress={args.compress}")
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 5e-2, sync, R))
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=R * 2, seed=0)
    losses = []
    for s in range(args.steps):
        b = data.batch_at(s)
        batch = {k: jnp.asarray(v.reshape(R, 2, *v.shape[1:]))
                 for k, v in b.items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d}  loss={losses[-1]:.3f}  "
                  f"survivor_err={float(m['survivor_consensus_error']):.2e}  "
                  f"eff_frac={float(m['effective_replica_fraction']):.2f}  "
                  f"rejected={float(m['rejected_gradient_count']):.0f}")
    assert np.isfinite(losses[-1]), "training diverged"
    if failures.active:
        assert float(m["effective_replica_fraction"]) < 1.0
        print(f"faulty sync survived: mean loss last 5 = "
              f"{np.mean(losses[-5:]):.3f} (first 5 = "
              f"{np.mean(losses[:5]):.3f})")
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), (
            "loss failed to decrease under faults")
    print("robust decentralized training OK")


if __name__ == "__main__":
    main()
