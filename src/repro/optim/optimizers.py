"""Self-contained optimizers (no optax dependency).

* adamw     — default for <=100B-param archs; fp32 moments.
* adafactor — factored second moment, optional bf16 momentum; the
  memory policy for the giant MoE archs (DESIGN.md §6): state is
  O(rows+cols) per matrix instead of O(rows*cols).
* sgdm      — plain momentum SGD (used by decentralized-gossip examples
  where per-replica state must stay cheap).

All follow the (init_fn, update_fn) convention:
  state = init_fn(params)
  updates, state = update_fn(grads, state, params)
  params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer", "adamw", "adafactor", "sgdm",
    "apply_updates", "global_norm", "clip_by_global_norm",
    "cosine_schedule", "make_optimizer",
]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), n


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return lr


# ------------------------------- adamw --------------------------------


def adamw(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads,
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads,
        )
        mh = jax.tree.map(lambda m_: m_ / (1 - b1**cf), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2**cf), v)
        upd = jax.tree.map(
            lambda mh_, vh_, p: -lr * (
                mh_ / (jnp.sqrt(vh_) + eps) + weight_decay * p.astype(jnp.float32)
            ),
            mh, vh, params,
        )
        return upd, {"m": m, "v": v, "count": c}

    return Optimizer(init, update)


# ----------------------------- adafactor ------------------------------


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    momentum: bool = False,
    momentum_dtype=jnp.bfloat16,
) -> Optimizer:
    """Factored RMS (Shazeer & Stern 2018). For ndim>=2 params keep only
    row/col second-moment vectors over the trailing two dims."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def v_state(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        st = {
            "v": jax.tree.map(v_state, params, is_leaf=lambda x: hasattr(x, "shape")),
            "count": jnp.zeros((), jnp.int32),
        }
        if momentum:
            st["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params)
        return st

    def update(grads, state, params, lr):
        c = state["count"] + 1
        beta = 1.0 - (c.astype(jnp.float32) + 1.0) ** (-decay)

        def upd_one(g, v):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                rfac = jax.lax.rsqrt(vr / denom)[..., None]
                cfac = jax.lax.rsqrt(vc)[..., None, :].swapaxes(-1, -2) if False else (
                    jax.lax.rsqrt(vc)[..., None, :]
                )
                u = gf * rfac * cfac
                nv = {"vr": vr, "vc": vc}
            else:
                vv = beta * v["v"] + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(vv)
                nv = {"v": vv}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return u, nv

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state["v"])
        outs = [upd_one(g, v) for g, v in zip(flat_g, flat_v)]
        upd = jax.tree.unflatten(treedef, [-lr * o[0] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_state = {"v": new_v, "count": c}
        if momentum:
            m = jax.tree.map(
                lambda m_, u_: (0.9 * m_.astype(jnp.float32) + u_).astype(m_.dtype),
                state["m"], upd,
            )
            upd = jax.tree.map(lambda m_: m_.astype(jnp.float32), m)
            new_state["m"] = m
        return upd, new_state

    return Optimizer(init, update)


# -------------------------------- sgdm --------------------------------


def sgdm(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        m = jax.tree.map(
            lambda m_, g: momentum * m_ + g.astype(jnp.float32), state["m"], grads
        )
        upd = jax.tree.map(lambda m_: -lr * m_, m)
        return upd, {"m": m, "count": state["count"] + 1}

    return Optimizer(init, update)


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    if name == "sgdm":
        return sgdm(**kw)
    raise ValueError(name)
