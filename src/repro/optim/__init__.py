from .optimizers import (
    Optimizer, adafactor, adamw, apply_updates, clip_by_global_norm,
    cosine_schedule, global_norm, make_optimizer, sgdm,
)

__all__ = [
    "Optimizer", "adafactor", "adamw", "apply_updates",
    "clip_by_global_norm", "cosine_schedule", "global_norm",
    "make_optimizer", "sgdm",
]
