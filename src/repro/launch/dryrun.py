import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run (deliverable (e)).

For every (architecture x input shape x mesh) cell:
  1. lower + compile the FULL-DEPTH production step (scan-over-layers)
     -> proof the sharding config is coherent and the memory fits
        (compiled.memory_analysis());
  2. lower + compile 1-unit and 2-unit UNROLLED depth variants per
     distinct layer group -> loop-aware per-step totals for flops,
     bytes, and collective bytes (XLA cost_analysis counts while-loop
     bodies once; see hlo_analysis.secant_totals);
  3. emit a JSON artifact under benchmarks/artifacts/dryrun/ with the
     roofline terms (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.launch.hlo_analysis import CollectiveStats, collective_bytes
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.specs import build_cell

HW = {
    "peak_flops_per_chip": 197e12,   # bf16 TFLOP/s (TPU v5e)
    "hbm_bw_per_chip": 819e9,        # B/s
    "ici_bw_per_link": 50e9,         # B/s
}
ARTIFACT_DIR = os.path.join("benchmarks", "artifacts", "dryrun")


# ------------------------- analytic model flops ------------------------


def active_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts; active replaces each MoE
    layer's E experts by the K routed ones."""
    from repro.models import Transformer

    total = Transformer(cfg).num_params
    if not cfg.num_experts:
        return total, total
    n_moe_layers = sum(1 for k in cfg.layer_kinds() if k in ("attn", "local"))
    per_expert = 3 * cfg.d_model * cfg.d_ff
    moe_total = n_moe_layers * cfg.num_experts * per_expert
    moe_active = n_moe_layers * cfg.experts_per_token * per_expert
    return total, total - moe_total + moe_active


def model_flops(cfg, shape_name: str) -> float:
    S, B, mode = SHAPES[shape_name]
    _, n_active = active_params(cfg)
    tokens = B * S if mode in ("train", "prefill") else B
    if mode == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


# ------------------------------ compiles -------------------------------


def _compile(cfg, shape_name, mesh, model_axis=16):
    cell = build_cell(cfg, shape_name, mesh, model_axis=model_axis)
    with set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate,
        )
        lowered = jitted.lower(*cell.args_abs)
        compiled = lowered.compile()
    return cell, lowered, compiled


def _cost_record(compiled, pod_size: int) -> dict:
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "collectives": collective_bytes(text, pod_size=pod_size),
    }


def _depth_variants(cfg):
    """[(variant_1u_cfg, variant_2u_cfg, repeats)] per distinct group."""
    out = []
    for unit, repeats in cfg.scan_groups():
        changes = dict(block_unit=unit, scan_unroll=True)
        enc = cfg.encoder_layers
        v1 = dataclasses.replace(
            cfg, num_layers=len(unit), encoder_layers=min(enc, 1) if enc else 0,
            **changes,
        )
        v2 = dataclasses.replace(
            cfg, num_layers=2 * len(unit),
            encoder_layers=min(enc, 2) if enc else 0, **changes,
        )
        out.append((v1, v2, repeats))
    return out


def loop_aware_totals(cfg, shape_name, mesh, pod_size) -> dict:
    """Exact per-step totals via the secant method over depth variants.

    For whisper the encoder scales with the variants too (enc repeats ==
    decoder repeats for the assigned config), so the unit includes one
    encoder layer and the extrapolation stays exact.
    """
    variants = _depth_variants(cfg)
    stem = None
    total = {"flops": 0.0, "bytes": 0.0, "collectives": CollectiveStats()}
    for i, (v1, v2, repeats) in enumerate(variants):
        _, _, c1 = _compile(v1, shape_name, mesh)
        r1 = _cost_record(c1, pod_size)
        _, _, c2 = _compile(v2, shape_name, mesh)
        r2 = _cost_record(c2, pod_size)
        unit = {
            "flops": r2["flops"] - r1["flops"],
            "bytes": r2["bytes"] - r1["bytes"],
            "collectives": r2["collectives"] - r1["collectives"],
        }
        if stem is None:
            stem = {
                "flops": r1["flops"] - unit["flops"],
                "bytes": r1["bytes"] - unit["bytes"],
                "collectives": r1["collectives"] - unit["collectives"],
            }
        total["flops"] += repeats * unit["flops"]
        total["bytes"] += repeats * unit["bytes"]
        total["collectives"] = total["collectives"] + unit["collectives"].scaled(repeats)
    total["flops"] += stem["flops"]
    total["bytes"] += stem["bytes"]
    total["collectives"] = total["collectives"] + stem["collectives"]
    return total


def roofline_terms(totals: dict, chips: int) -> dict:
    """totals are PER-DEVICE module costs (XLA analyzes the SPMD
    partition); x chips = fleet totals, then the assignment's formulas."""
    # clamp tiny negative secant wiggles (variant-dependent stem patterns)
    flops_global = max(totals["flops"], 0.0) * chips
    bytes_global = max(totals["bytes"], 0.0) * chips
    coll = totals["collectives"]
    coll.total_bytes = max(coll.total_bytes, 0)
    coll.cross_pod_bytes = max(coll.cross_pod_bytes, 0)
    compute_s = flops_global / (chips * HW["peak_flops_per_chip"])
    memory_s = bytes_global / (chips * HW["hbm_bw_per_chip"])
    collective_s = coll.total_bytes / (chips * HW["ici_bw_per_link"])
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "hlo_flops_global": flops_global,
        "hlo_bytes_global": bytes_global,
        "collective_bytes": coll.total_bytes,
        "cross_pod_bytes": coll.cross_pod_bytes,
        "collectives_by_kind": coll.by_kind,
    }


# -------------------------------- cells --------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR, with_roofline: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{mesh_name}.json".replace("/", "_")
    )
    cfg = get_config(arch)
    runnable, reason = cell_is_runnable(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "status": "skip", "reason": reason,
    }
    if not runnable:
        json.dump(rec, open(out_path, "w"), indent=1)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        chips = int(np.prod(list(mesh.shape.values())))
        pod_size = 256
        cell, lowered, compiled = _compile(cfg, shape_name, mesh)
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
            ),
            "fits_16GiB": bool(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes < 16 * 2**30
            ),
        }
        full_coll = collective_bytes(compiled.as_text(), pod_size=pod_size)
        rec.update(
            status="ok",
            compile_seconds=round(time.time() - t0, 1),
            chips=chips,
            mode=cell.mode,
            num_params=cell.meta["num_params"],
            memory=mem,
            fulldepth_collectives_once=full_coll.asdict(),  # body counted once
        )
        if with_roofline:
            totals = loop_aware_totals(cfg, shape_name, mesh, pod_size)
            terms = roofline_terms(totals, chips)
            mf = model_flops(cfg, shape_name)
            terms["model_flops"] = mf
            terms["model_flops_ratio"] = (
                mf / terms["hlo_flops_global"] if terms["hlo_flops_global"] else 0.0
            )
            rec["roofline"] = {
                k: (v if not isinstance(v, dict) else v)
                for k, v in terms.items()
            }
    except Exception as e:  # record the failure — dry-run bugs are bugs
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    json.dump(rec, open(out_path, "w"), indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    cells = []
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))
    for arch, shape, mp in cells:
        rec = run_cell(arch, shape, mp, out_dir=args.out,
                       with_roofline=not args.no_roofline)
        status = rec["status"]
        extra = ""
        if status == "ok":
            extra = (
                f" mem={rec['memory']['peak_bytes']/2**30:.1f}GiB"
                f" fits={rec['memory']['fits_16GiB']}"
            )
            if "roofline" in rec:
                r = rec["roofline"]
                extra += (
                    f" dom={r['dominant']}"
                    f" c={r['compute_s']*1e3:.1f}ms"
                    f" m={r['memory_s']*1e3:.1f}ms"
                    f" x={r['collective_s']*1e3:.1f}ms"
                )
        elif status == "error":
            extra = " " + rec["error"][:120]
        elif status == "skip":
            extra = " " + rec["reason"]
        print(f"[{status:5s}] {arch} {shape} "
              f"{'multi' if mp else 'single'}{extra}", flush=True)


if __name__ == "__main__":
    main()
