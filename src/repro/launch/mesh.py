"""Production meshes (assignment: 16x16 single-pod, 2x16x16 multi-pod).

`make_production_mesh` is a FUNCTION so importing this module never
touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import to build these meshes on a CPU host.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "batch_axes", "set_mesh"]


def set_mesh(mesh):
    """Version-portable mesh context: `jax.set_mesh` (jax >= 0.7), else
    `jax.sharding.use_mesh` (the 0.5/0.6 spelling), else the Mesh object
    itself (a context manager in 0.4.x).  Usage: ``with set_mesh(mesh):``.
    `models.layers.current_mesh` is the matching reader — it prefers the
    abstract mesh these setters install and falls back to the physical
    thread-resources mesh that `with mesh:` sets."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many host devices exist (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")
