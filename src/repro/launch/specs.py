"""Abstract inputs + shardings for every (arch x shape x mesh) cell.

`build_cell(cfg, shape_name, mesh)` returns everything the dry-run (and
the real launcher) needs: the step callable, abstract arguments
(ShapeDtypeStructs — no allocation), and NamedShardings, with
divisibility-sanitized specs (a mesh axis that does not divide a dim is
dropped to replication for that dim — e.g. whisper's 51865 vocab on a
16-way model axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import SHAPES
from repro.models import Transformer, decode_step, forward, init_cache
from repro.models.config import ModelConfig
from repro.models.layers import DTYPES
from repro.optim import adafactor, adamw, cosine_schedule, make_optimizer
from repro.train import init_train_state, make_train_step

__all__ = ["build_cell", "sanitize_spec", "state_shardings", "Cell"]


def sanitize_spec(spec: P, shape: tuple, mesh) -> P:
    new = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            new.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        new.append(ax if shape[i] % size == 0 else None)
    return P(*new)


def _ns(mesh, spec: P, shape: tuple) -> NamedSharding:
    return NamedSharding(mesh, sanitize_spec(spec, shape, mesh))


def state_shardings(mesh, params_abs, param_specs, opt_abs) -> dict:
    """Shardings for {params, opt, step}: optimizer moments follow the
    param layout; adafactor's factored vectors drop the reduced dim."""
    p_sh = jax.tree.map(
        lambda s, a: _ns(mesh, s, a.shape), param_specs, params_abs
    )

    def opt_entry(name, sub_abs):
        if name in ("m",):  # momentum mirrors params
            return p_sh
        if name == "count":
            return NamedSharding(mesh, P())
        if name == "v":
            # adamw: mirrors params; adafactor: {vr, vc} per param
            def build(spec, abs_sub):
                if isinstance(abs_sub, dict) and "vr" in abs_sub:
                    return {
                        "vr": _ns(mesh, P(*spec[:-1]), abs_sub["vr"].shape),
                        "vc": _ns(
                            mesh, P(*(tuple(spec[:-2]) + (spec[-1],)))
                            if len(spec) >= 2 else P(),
                            abs_sub["vc"].shape,
                        ),
                    }
                if isinstance(abs_sub, dict) and "v" in abs_sub:
                    return {"v": _ns(mesh, spec, abs_sub["v"].shape)}
                # adamw leaf mirrors the param
                return _ns(mesh, spec, abs_sub.shape)

            return jax.tree.map(
                build, param_specs, sub_abs,
                is_leaf=lambda x: isinstance(x, P),
            )
        raise KeyError(name)

    opt_sh = {k: opt_entry(k, v) for k, v in opt_abs.items()}
    return {
        "params": p_sh,
        "opt": opt_sh,
        "step": NamedSharding(mesh, P()),
    }


def _batch_abs_and_sh(cfg: ModelConfig, B: int, S: int, mesh, dp,
                      with_labels: bool):
    abs_, sh = {}, {}

    def add(name, shape, dtype, spec):
        abs_[name] = jax.ShapeDtypeStruct(shape, dtype)
        sh[name] = _ns(mesh, spec, shape)

    add("tokens", (B, S), jnp.int32, P(dp, None))
    if with_labels:
        add("labels", (B, S), jnp.int32, P(dp, None))
    if cfg.mrope_sections is not None:
        add("positions", (B, S, 3), jnp.int32, P(dp, None, None))
    if cfg.encoder_layers:
        add(
            "frames", (B, cfg.encoder_seq, cfg.d_model),
            DTYPES[cfg.dtype], P(dp, None, None),
        )
    return abs_, sh


def _cache_shardings(cfg: ModelConfig, cache_abs, mesh, dp):
    """Name-based sharding rules for decode state."""
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]

    def rule(path, leaf):
        name = ""
        for pp in reversed(path):
            if isinstance(pp, jax.tree_util.DictKey):
                name = str(pp.key)
                break
        shape = leaf.shape

        def spec4(base: P) -> P:
            # per-layer states under "groups" carry a leading stacked
            # (scan repeats) axis — prepend None for it
            if len(shape) == len(base) + 1:
                return P(None, *base)
            return base

        if name in ("k", "v"):          # (B, Hkv, L, dh) [+stack]
            hkv_axis = len(shape) - 3
            if shape[hkv_axis] % mesh.shape["model"] == 0:
                return _ns(mesh, spec4(P(dp, "model", None, None)), shape)
            # GQA heads below the TP degree: shard the cache SEQUENCE dim
            # instead (flash-decode style) — softmax stats psum over model
            return _ns(mesh, spec4(P(dp, None, "model", None)), shape)
        if name == "pos":               # (B, L) [+stack]
            return _ns(mesh, spec4(P(dp, None)), shape)
        if name == "wkv":               # (B*H, N, N) [+stack]
            return _ns(mesh, spec4(P(dp, None, None)), shape)
        if name in ("h",):              # (B, D) [+stack]
            return _ns(mesh, spec4(P(dp, "model")), shape)
        if name in ("conv", "tm_prev", "cm_prev"):   # (B, w, D) [+stack]
            return _ns(mesh, spec4(P(dp, None, "model")), shape)
        if name == "memory":            # (B, S, D) — not stacked
            return _ns(mesh, P(dp, None, None), shape)
        if name == "step":
            return NamedSharding(mesh, P())
        return _ns(mesh, P(*([None] * len(shape))), shape)

    return jax.tree_util.tree_map_with_path(rule, cache_abs)


@dataclasses.dataclass
class Cell:
    fn: object            # step callable
    args_abs: tuple       # abstract arguments
    in_shardings: tuple
    out_shardings: object
    donate: tuple
    mode: str
    meta: dict


def build_cell(cfg: ModelConfig, shape_name: str, mesh,
               model_axis: int = 16) -> Cell:
    S, B, mode = SHAPES[shape_name]
    dp = tuple(n for n in mesh.axis_names if n != "model")
    model = Transformer(cfg, model_axis=model_axis)
    params_abs = model.abstract()
    specs = model.specs()
    p_sh = jax.tree.map(lambda s, a: _ns(mesh, s, a.shape), specs, params_abs)
    meta = {"num_params": model.num_params, "dp": dp, "mode": mode}

    if mode == "train":
        opt = make_optimizer(cfg.optimizer)
        lr = cosine_schedule(3e-4, 2000, 100_000)
        state_abs = jax.eval_shape(lambda p: init_train_state(p, opt), params_abs)
        st_sh = state_shardings(mesh, params_abs, specs, state_abs["opt"])
        batch_abs, batch_sh = _batch_abs_and_sh(cfg, B, S, mesh, dp, True)
        fn = make_train_step(cfg, opt, lr, dp=dp)
        return Cell(
            fn=fn,
            args_abs=(state_abs, batch_abs),
            in_shardings=(st_sh, batch_sh),
            out_shardings=(st_sh, None),
            donate=(0,),
            mode=mode,
            meta=meta,
        )

    if mode == "prefill":
        batch_abs, batch_sh = _batch_abs_and_sh(cfg, B, S, mesh, dp, False)
        fn = lambda p, b: forward(p, cfg, b, dp=dp)
        return Cell(
            fn=fn,
            args_abs=(params_abs, batch_abs),
            in_shardings=(p_sh, batch_sh),
            out_shardings=None,
            donate=(),
            mode=mode,
            meta=meta,
        )

    # decode: one new token against a seq_len-deep cache
    frames_abs = (
        jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), DTYPES[cfg.dtype])
        if cfg.encoder_layers else None
    )
    cache_abs = jax.eval_shape(
        lambda p, f: init_cache(p, cfg, batch=B, max_len=S, frames=f, dp=dp),
        params_abs, frames_abs,
    )
    cache_sh = _cache_shardings(cfg, cache_abs, mesh, dp)
    tok_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    tok_sh = _ns(mesh, P(dp), (B,))
    fn = lambda p, c, t: decode_step(p, cfg, c, t, dp=dp)
    return Cell(
        fn=fn,
        args_abs=(params_abs, cache_abs, tok_abs),
        in_shardings=(p_sh, cache_sh, tok_sh),
        out_shardings=(None, cache_sh),
        donate=(1,),
        mode=mode,
        meta=meta,
    )
