"""HLO-level analysis for the dry-run roofline.

Two jobs:

1. `collective_bytes(hlo_text, pod_size)` — sum result-shape bytes of
   every all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute in a compiled module, classified intra- vs
   cross-pod.  Partition ids in replica_groups / source_target_pairs
   index the executable's DEVICE ASSIGNMENT, not raw device ids, and
   XLA frequently emits the iota form `[G,S]<=[dims...]T(perm)` whose
   transpose remaps ids (reshape-of-the-replica-axis strategies do this
   systematically) — so the classifier (a) expands the iota form
   exactly, transpose included, and (b) accepts an explicit
   `pod_of` map built from the mesh device assignment
   (`device_pod_map`), falling back to the `id // pod_size` heuristic
   only when no assignment is provided.

2. Scan-body undercounting fix: XLA's cost_analysis counts a while-loop
   body ONCE regardless of trip count, so a full-depth scan-over-layers
   module under-reports flops by ~num_layers x.  The dry-run therefore
   lowers 1-unit and 2-unit UNROLLED depth variants per distinct layer
   group and extrapolates exactly (`secant_totals`):

      unit_cost = cost(2 units) - cost(1 unit)
      stem_cost = cost(1 unit) - unit_cost
      total     = stem_cost + sum_i repeats_i * unit_cost_i

   This is exact for homogeneous stacks (which scan-over-layers
   guarantees by construction).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence

__all__ = [
    "CollectiveStats",
    "collective_bytes",
    "device_pod_map",
    "secant_totals",
    "DTYPE_BYTES",
]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
# nested-brace attributes: capture through the LAST inner close-brace
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?\})\}")
# iota form, with optional transpose: [G,S]<=[d0,d1,...]T(p0,p1,...)
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.*?\})\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> Optional[list[list[int]]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [G,S]<=[dims...]T(perm): the id list is
        # iota(prod(dims)).reshape(dims).transpose(perm).flatten(),
        # then split into G groups of S.  Ignoring the transpose is how
        # reshape-remapped hierarchical fusions get misclassified as
        # intra-pod (groups look like consecutive-id runs when they are
        # actually strided across the assignment).
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        ids = list(range(total))
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            # flatten(transpose(reshape(iota, dims), perm)) without numpy
            strides = [0] * len(dims)
            acc = 1
            for ax in range(len(dims) - 1, -1, -1):
                strides[ax] = acc
                acc *= dims[ax]
            t_dims = [dims[p] for p in perm]
            t_strides = [strides[p] for p in perm]
            ids = []
            idx = [0] * len(t_dims)
            for _ in range(total):
                ids.append(sum(i * st for i, st in zip(idx, t_strides)))
                for ax in range(len(t_dims) - 1, -1, -1):
                    idx[ax] += 1
                    if idx[ax] < t_dims[ax]:
                        break
                    idx[ax] = 0
        return [ids[i * s : (i + 1) * s] for i in range(g)]
    m = _GROUPS_RE.search(line)
    if m and m.group(1).strip():
        groups = []
        for grp in re.findall(r"\{([\d,\s]*)\}", m.group(1)):
            if grp.strip():
                groups.append([int(x) for x in grp.replace(" ", "").split(",")])
        return groups or None
    return None


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: int = 0
    cross_pod_bytes: int = 0
    by_kind: dict = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, nbytes: int, cross: bool):
        self.total_bytes += nbytes
        if cross:
            self.cross_pod_bytes += nbytes
        self.by_kind[kind] = self.by_kind.get(kind, 0) + nbytes
        self.count += 1

    def asdict(self) -> dict:
        return {
            "total_bytes": self.total_bytes,
            "cross_pod_bytes": self.cross_pod_bytes,
            "by_kind": dict(self.by_kind),
            "count": self.count,
        }

    def __sub__(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats(
            total_bytes=self.total_bytes - other.total_bytes,
            cross_pod_bytes=self.cross_pod_bytes - other.cross_pod_bytes,
            by_kind={
                k: self.by_kind.get(k, 0) - other.by_kind.get(k, 0)
                for k in set(self.by_kind) | set(other.by_kind)
            },
            count=self.count - other.count,
        )
        return out

    def scaled(self, f: float) -> "CollectiveStats":
        return CollectiveStats(
            total_bytes=int(self.total_bytes * f),
            cross_pod_bytes=int(self.cross_pod_bytes * f),
            by_kind={k: int(v * f) for k, v in self.by_kind.items()},
            count=int(self.count * f),
        )

    def __add__(self, other: "CollectiveStats") -> "CollectiveStats":
        return CollectiveStats(
            total_bytes=self.total_bytes + other.total_bytes,
            cross_pod_bytes=self.cross_pod_bytes + other.cross_pod_bytes,
            by_kind={
                k: self.by_kind.get(k, 0) + other.by_kind.get(k, 0)
                for k in set(self.by_kind) | set(other.by_kind)
            },
            count=self.count + other.count,
        )


def device_pod_map(devices: Sequence, pod_size: int) -> list[int]:
    """Pod index per logical partition id, from the mesh device
    assignment (`list(mesh.devices.flat)` — the order jax hands XLA).

    Uses the accelerator's own pod/slice identity when exposed
    (`device.slice_index` on multi-slice TPU); otherwise groups physical
    device ids into pods of `pod_size`.  The point is that partition id
    p maps to `devices[p]`, which after mesh reshapes/transposes need
    NOT be the device with id p — the `id // pod_size` heuristic silently
    assumes it is.
    """
    pods = []
    for i, d in enumerate(devices):
        s = getattr(d, "slice_index", None)
        if s is None:
            s = getattr(d, "id", i) // pod_size
        pods.append(int(s))
    return pods


def collective_bytes(
    hlo_text: str,
    pod_size: int = 256,
    pod_of: Optional[Sequence[int]] = None,
) -> CollectiveStats:
    """Collective op/byte census of an HLO module, classified intra- vs
    cross-pod.  `pod_of` (from `device_pod_map`) maps partition ids
    through the real device assignment; without it the classifier falls
    back to pod = id // pod_size."""

    warned = set()

    def pod(i: int) -> int:
        if pod_of is not None:
            if 0 <= i < len(pod_of):
                return pod_of[i]
            if i not in warned:  # partial map would silently reintroduce
                warned.add(i)    # the id//pod_size misclassification
                import warnings

                warnings.warn(
                    f"partition id {i} outside pod_of (len {len(pod_of)}); "
                    "falling back to id // pod_size for it",
                    stacklevel=2,
                )
        return i // pod_size

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"= [^=]*\b{k}(-start|-done)?\(", stripped):
                kind = k
                break
        if kind is None or f"{kind}-done" in stripped:
            continue  # count the -start, skip the paired -done
        lhs = stripped.split(f" {kind}", 1)[0]
        nbytes = _shape_bytes(lhs)
        if nbytes == 0:
            continue
        cross = False
        pairs = _PAIRS_RE.search(stripped)
        if pairs:
            for a, b in re.findall(r"\{(\d+),(\d+)\}", pairs.group(1)):
                if pod(int(a)) != pod(int(b)):
                    cross = True
                    break
        else:
            groups = _parse_groups(stripped)
            if groups:
                for grp in groups:
                    if len({pod(i) for i in grp}) > 1:
                        cross = True
                        break
            else:
                cross = True  # global (no groups attr) => crosses pods
        stats.add(kind, nbytes, cross)
    return stats


def secant_totals(cost_1u: dict, cost_2u: dict, repeats: int) -> dict:
    """Extrapolate per-step totals from 1-unit / 2-unit depth variants.

    cost dicts carry scalar-addable entries (flops, bytes, CollectiveStats).
    Returns stem + repeats * unit for every key.
    """
    out = {}
    for k in cost_1u:
        a, b = cost_1u[k], cost_2u[k]
        if isinstance(a, CollectiveStats):
            unit = b - a
            stem = a - unit
            out[k] = stem + unit.scaled(repeats)
        else:
            unit = b - a
            out[k] = (a - unit) + repeats * unit
    return out
