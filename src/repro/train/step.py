"""Train-step builders.

`make_train_step` — the production data/tensor-parallel step: params
FSDP-sharded over "data", TP over "model", replicated over "pod"; the
gradient all-reduce over (pod, data) is XLA-inserted (baseline sync).

`make_decentralized_step` — the paper's feature: per-consensus-group
parameter replicas (leading axis R) whose gradients are mixed by a
`repro.dist` strategy instead of an exact global all-reduce.  The
`SyncConfig` is resolved ONCE into a static `SyncPlan`
(`dist.build_sync_plan`) when the step is built; every step then runs
the compiled `dist.execute_sync(plan, grads, residuals, step)` —
compress (error feedback) -> rotate (randomized cells by step index)
-> mix.  Exact strategies (allreduce / hierarchical) keep replicas
bitwise identical; gossip strategies bound the replica disagreement by
the mixing rounds (the paper's eps).  Metrics report the consensus
distance and the modeled per-sync wire bytes
(`dist.plan_wire_bytes` — payload x transmissions x wire_fraction).

When compression is on, the train state carries a per-replica
`residuals` pytree (grown by `init_decentralized_state(..., sync=...)`)
so unsent gradient mass is re-injected next step.

With `SyncConfig(overlap="one_step")` the step runs the ASYNC pipeline
(`dist.async_sync`): the optimizer applies the PREVIOUS step's mixed
gradients while the current step's fresh gradients become the new
in-flight buffer (`prev_grads` in the state) — the mix has no data
dependency on the backward pass, so the two overlap under jit (and
lower as explicit shard_map collectives when a replica `mesh` is
passed).  Staleness correction: the delayed gradients use the rotation
index and learning rate of the step that produced them, so the
overlapped trajectory is the serialized one delayed by exactly one
step on a step-independent gradient stream.  Step 0 is warmup: the
update is computed against the zero buffer and discarded; the metric
`sync_overlap_fraction` reports 0.0 there and 1.0 on every overlapped
step (always 0.0 in serialized mode).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.dist import (
    SyncConfig, build_sync_plan, execute_sync, execute_sync_sharded,
    init_inflight, init_residual, plan_wire_bytes, replica_fault_masks,
)
from repro.models import loss_fn
from repro.models.config import ModelConfig
from repro.optim.optimizers import (
    Optimizer, apply_updates, clip_by_global_norm, global_norm,
)

__all__ = [
    "make_train_step", "make_decentralized_step",
    "init_train_state", "init_decentralized_state", "consensus_distance",
    "survivor_consensus_distance",
]


def init_train_state(params, optimizer: Optimizer) -> dict:
    return {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def init_decentralized_state(
    params_replicated, optimizer: Optimizer, sync: Optional[SyncConfig] = None
) -> dict:
    """params_replicated: leading replica axis R on every leaf; the
    optimizer state is vmapped so its leaves carry R too.

    Pass the step's `SyncConfig` to size the state for it: with a
    non-``none`` compression scheme the state grows a per-replica
    error-feedback `residuals` pytree (zeros, same structure as params)
    that `execute_sync` updates every step; with `overlap="one_step"`
    it grows the double-buffered `prev_grads` pytree (zeros) holding
    the in-flight gradients of the async pipeline."""
    state = {
        "params": params_replicated,
        "opt": jax.vmap(optimizer.init)(params_replicated),
        "step": jnp.zeros((), jnp.int32),
    }
    if sync is not None and sync.compression.scheme != "none":
        state["residuals"] = init_residual(params_replicated)
    # mirror the plan resolver: R=1 has nothing to overlap with, so the
    # step never consumes (or re-emits) a prev_grads buffer there
    R = jax.tree.leaves(params_replicated)[0].shape[0]
    if sync is not None and sync.overlap == "one_step" and R > 1:
        state["prev_grads"] = init_inflight(params_replicated)
    return state


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    *,
    dp: tuple[str, ...] = ("data",),
    clip_norm: float = 1.0,
) -> Callable:
    """Returns step(state, batch) -> (state, metrics); jit outside."""

    def step(state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, dp=dp)
        )(state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(state["step"])
        updates, opt = optimizer.update(grads, state["opt"], state["params"], lr)
        params = apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return step


# ------------------------ decentralized (paper) ------------------------


def consensus_distance(params) -> jax.Array:
    """RMS distance of replicas from their mean (leading axis R) —
    the training-side analogue of the paper's eps accuracy."""
    sq, n = 0.0, 0
    for p in jax.tree.leaves(params):
        pf = p.astype(jnp.float32)
        d = pf - pf.mean(axis=0, keepdims=True)
        sq = sq + jnp.sum(d * d)
        n = n + p.size
    return jnp.sqrt(sq / max(n, 1))


def survivor_consensus_distance(params, live) -> jax.Array:
    """`consensus_distance` restricted to the live replicas of a faulty
    sync step: RMS distance of the live replicas from the *live* mean.
    Dropped replicas neither shift the reference mean nor contribute
    error — degradation is measured over the replicas still training."""
    live_f = live.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(live_f), 1.0)
    sq = 0.0
    n = 0.0
    for p in jax.tree.leaves(params):
        pf = p.astype(jnp.float32)
        w = live_f.reshape((-1,) + (1,) * (pf.ndim - 1))
        mean = jnp.sum(pf * w, axis=0, keepdims=True) / cnt
        d = (pf - mean) * w
        sq = sq + jnp.sum(d * d)
        n = n + cnt * (p.size // p.shape[0])
    return jnp.sqrt(sq / jnp.maximum(n, 1.0))


def _tree_select(cond, on_true, on_false):
    """Leafwise where over two same-structure pytrees (scalar cond)."""
    return jax.tree.map(lambda a, b: jnp.where(cond, a, b), on_true, on_false)


def make_decentralized_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    sync: SyncConfig,
    num_replicas: int,
    *,
    clip_norm: float = 1.0,
    mesh=None,
    replica_axis: str = "replica",
) -> Callable:
    """Step over replicated state: every leaf of params/opt carries a
    leading replica axis R; batch is (R, per_replica, S).

    The sync config is resolved to a static `SyncPlan` here, once; the
    returned step is a pure function of (state, batch) whose `step`
    counter drives the plan's rotation schedule.  With compression on,
    `state` must carry the `residuals` pytree from
    `init_decentralized_state(..., sync=sync)`; with
    `overlap="one_step"` it must also carry `prev_grads` (same
    constructor).  Passing a 1-axis replica `mesh` routes the mix
    through the shard_map executor (`dist.execute_sync_sharded`) so the
    gossip lowers as explicit per-replica collectives."""
    R = num_replicas
    plan = build_sync_plan(sync, R)
    compressed = plan.compression.scheme != "none"
    overlapped = plan.overlapped

    def mix(grads, residuals, step):
        if mesh is not None:
            return execute_sync_sharded(
                plan, grads, residuals, step, mesh=mesh,
                axis_name=replica_axis,
            )
        return execute_sync(plan, grads, residuals, step)

    def step(state, batch):
        if compressed and "residuals" not in state:
            raise ValueError(
                "compressed sync needs error-feedback state: build the train "
                "state with init_decentralized_state(params, opt, sync=sync)"
            )
        if overlapped and "prev_grads" not in state:
            raise ValueError(
                "overlap='one_step' needs the double-buffered in-flight "
                "gradients: build the train state with "
                "init_decentralized_state(params, opt, sync=sync)"
            )
        def total_loss(p):
            # sum of per-replica losses => per-replica grads
            losses = jax.vmap(
                lambda pr, br: loss_fn(pr, cfg, br, dp=None)
            )(p, batch)
            return losses.sum(), losses

        (loss_sum, losses), grads = jax.value_and_grad(
            total_loss, has_aux=True
        )(state["params"])
        # per-replica clipping, then gossip mixing (the paper's averaging)
        gnorm = global_norm(grads)
        grads = jax.tree.map(
            lambda g: g * jnp.minimum(1.0, clip_norm * (R ** 0.5) /
                                      jnp.maximum(gnorm, 1e-9)).astype(g.dtype),
            grads,
        )
        if overlapped:
            # apply the PREVIOUS step's mixed gradients (no data
            # dependency on this step's backward — the sync collectives
            # and the backward are independent dataflow branches);
            # staleness correction: rotation index and learning rate of
            # the step that produced them.  The fresh grads become the
            # new in-flight buffer (async_execute_sync composition).
            mixed, new_residuals = mix(
                state["prev_grads"], state.get("residuals"),
                state["step"] - 1,
            )
            prev_grads = grads
            warm = (state["step"] > 0)
            lr = lr_fn(jnp.maximum(state["step"] - 1, 0))
        else:
            mixed, new_residuals = mix(
                grads, state.get("residuals"), state["step"]
            )
            prev_grads, warm = None, None
            lr = lr_fn(state["step"])
        updates, opt = jax.vmap(
            lambda g, o, p: optimizer.update(g, o, p, lr)
        )(mixed, state["opt"], state["params"])
        params = apply_updates(state["params"], updates)
        if overlapped:
            # warmup step 0: nothing in flight yet — discard the (zero-
            # gradient) update wholesale so optimizer state is untouched
            params = _tree_select(warm, params, state["params"])
            opt = _tree_select(warm, opt, state["opt"])
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        if "residuals" in state:
            new_state["residuals"] = new_residuals
        if overlapped:
            new_state["prev_grads"] = prev_grads
        # degradation metrics: recompute the sync index's fault masks
        # (deterministic in (seed, step), so this matches what the
        # executor injected) and report consensus over survivors only
        if plan.faulty:
            sync_idx = state["step"] - 1 if overlapped else state["step"]
            faults = replica_fault_masks(plan.failures, R, sync_idx)
            surv_err = survivor_consensus_distance(params, faults.live)
            eff_frac = jnp.mean(faults.live.astype(jnp.float32))
            rejected = (
                jnp.sum(faults.byzantine.astype(jnp.float32))
                if plan.robust_consensus else jnp.float32(0.0)
            )
        else:
            surv_err = consensus_distance(params)
            eff_frac = jnp.float32(1.0)
            rejected = jnp.float32(0.0)
        metrics = {
            "loss": losses.mean(),
            "grad_norm": gnorm,
            "lr": lr,
            "consensus_distance": consensus_distance(params),
            # static given shapes — folds to a constant under jit
            "wire_bytes": jnp.float32(plan_wire_bytes(plan, grads)),
            # fraction of this step's sync that ran concurrently with
            # backward compute: 1 on every overlapped step, 0 during
            # warmup and in serialized mode
            "sync_overlap_fraction": (
                warm.astype(jnp.float32) if overlapped else jnp.float32(0.0)
            ),
            # fault-degradation metrics (inert without plan.failures:
            # survivor error == consensus_distance, fraction 1, count 0)
            "survivor_consensus_error": surv_err,
            "effective_replica_fraction": eff_frac,
            "rejected_gradient_count": rejected,
        }
        return new_state, metrics

    return step
