"""Training loop with fault tolerance.

Responsibilities:
  * jit the step with donated state (in-place buffers),
  * checkpoint every `save_every` steps (atomic, keep-N) + auto-resume
    from the latest checkpoint on construction,
  * deterministic data (batch = f(seed, step)) so restarts replay the
    exact stream,
  * failure injection hook (`fail_at_step`) used by the recovery tests,
  * metrics JSONL log.

Straggler mitigation is structural rather than reactive: every gossip
sync strategy uses FIXED mixing rounds (the paper's MultiscaleGossipFI
variant), so no replica ever waits on a data-dependent convergence
test of another replica; combined with deterministic data this keeps
the step fully SPMD with no host-side synchronization points.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import jax
import numpy as np

from .checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["Trainer"]


class Trainer:
    def __init__(
        self,
        step_fn: Callable,
        init_state,
        data,                        # object with .batch_at(step) -> host batch
        *,
        ckpt_dir: Optional[str] = None,
        save_every: int = 50,
        keep_n: int = 3,
        place_batch: Optional[Callable] = None,
        log_path: Optional[str] = None,
        fail_at_step: Optional[int] = None,
    ):
        self._jit_step = jax.jit(step_fn, donate_argnums=(0,))
        self.state = init_state
        self.data = data
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.keep_n = keep_n
        self.place_batch = place_batch or (lambda b: b)
        self.log_path = log_path
        self.fail_at_step = fail_at_step
        self.metrics_history: list[dict] = []
        # cumulative modeled wire traffic of decentralized sync (steps that
        # report `wire_bytes` — see make_decentralized_step); restarts reset
        # the counter, matching its role as a per-run traffic gauge
        self.wire_bytes_total = 0.0
        # running mean of `sync_overlap_fraction` (async one-step overlap:
        # 0 on warmup / serialized steps, 1 on overlapped ones); same
        # per-run semantics as wire_bytes_total
        self._overlap_sum = 0.0
        self._overlap_steps = 0
        # fault-degradation accumulators (steps that report the
        # SyncFailureModel metrics — see make_decentralized_step):
        # total Byzantine gradients rejected by robust aggregation, and
        # a running mean of the live-replica fraction
        self.rejected_gradients_total = 0.0
        self._eff_replica_sum = 0.0
        self._eff_replica_steps = 0
        if ckpt_dir and latest_step(ckpt_dir) is not None:
            self.state, step = restore_checkpoint(ckpt_dir, self.state)
            print(f"[trainer] resumed from step {step}")

    @property
    def step(self) -> int:
        return int(self.state["step"])

    def _log(self, rec: dict) -> None:
        self.metrics_history.append(rec)
        if self.log_path:
            with open(self.log_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    def run(self, num_steps: int) -> list[dict]:
        t_last = time.time()
        while self.step < num_steps:
            s = self.step
            if self.fail_at_step is not None and s == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {s}")
            batch = self.place_batch(self.data.batch_at(s))
            self.state, metrics = self._jit_step(self.state, batch)
            if self.ckpt_dir and (s + 1) % self.save_every == 0:
                save_checkpoint(
                    self.ckpt_dir, self.state, s + 1, keep_n=self.keep_n
                )
            now = time.time()
            rec = {
                "step": s + 1,
                **{k: float(np.asarray(v)) for k, v in metrics.items()},
                "sec_per_step": now - t_last,
            }
            if "wire_bytes" in rec:
                self.wire_bytes_total += rec["wire_bytes"]
                rec["wire_bytes_total"] = self.wire_bytes_total
            if "sync_overlap_fraction" in rec:
                self._overlap_sum += rec["sync_overlap_fraction"]
                self._overlap_steps += 1
                rec["sync_overlap_fraction_mean"] = (
                    self._overlap_sum / self._overlap_steps
                )
            if "rejected_gradient_count" in rec:
                self.rejected_gradients_total += rec["rejected_gradient_count"]
                rec["rejected_gradients_total"] = self.rejected_gradients_total
            if "effective_replica_fraction" in rec:
                self._eff_replica_sum += rec["effective_replica_fraction"]
                self._eff_replica_steps += 1
                rec["effective_replica_fraction_mean"] = (
                    self._eff_replica_sum / self._eff_replica_steps
                )
            t_last = now
            self._log(rec)
        # final checkpoint so a finished run is always resumable
        if self.ckpt_dir:
            save_checkpoint(self.ckpt_dir, self.state, self.step, keep_n=self.keep_n)
        return self.metrics_history
