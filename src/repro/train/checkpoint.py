"""Checkpointing: atomic, mesh-agnostic, elastic.

Design (fault tolerance at 1000+ nodes — DESIGN.md §6):
  * one .npz per checkpoint with path-flattened leaf names + a JSON
    manifest (step, leaf treedef, dtype table, user metadata);
  * writes go to <dir>/tmp.<step> then os.replace -> crash-safe: a
    partially written checkpoint is never visible;
  * restore is ELASTIC: arrays are loaded logically and device_put
    against whatever mesh/shardings the restoring job uses — restarting
    on a different topology (e.g. 256 -> 512 chips) reshards on load;
  * keep_n retention; `latest_step` scans the directory so a restarted
    job auto-resumes without coordination state.

At real multi-pod scale the same interface is backed by per-shard
writes (each host serializes only addressable shards); the single-file
backend here keeps the example/test scale simple.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_SEP = "/"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"[{p.idx}]"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


def save_checkpoint(
    directory: str,
    state,
    step: int,
    *,
    keep_n: int = 3,
    metadata: Optional[dict] = None,
) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(state)

    def to_np(v):
        a = np.asarray(v)
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            # non-native dtypes (bf16): store widened; exact (bf16 c f32)
            a = np.asarray(jax.numpy.asarray(v).astype(jax.numpy.float32))
        return a

    arrays = {k: to_np(v) for k, v in flat.items()}
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"ckpt_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": int(step),
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):  # re-saving the same step: drop the old one
        shutil.rmtree(final)
    os.replace(tmp, final)     # atomic publish
    _prune(directory, keep_n)
    return final


def _prune(directory: str, keep_n: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep_n] if keep_n > 0 else []:
        path = os.path.join(directory, f"ckpt_{s:010d}")
        for fn in os.listdir(path):
            os.remove(os.path.join(path, fn))
        os.rmdir(path)


def list_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d{10})", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    state_like,
    *,
    step: Optional[int] = None,
    shardings=None,
):
    """Restore into the structure of `state_like`.  `shardings` (matching
    pytree of jax.sharding.Sharding, or None) controls placement —
    elastic restore passes the NEW mesh's shardings."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"ckpt_{step:010d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    flat_like = _flatten(state_like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise KeyError(f"checkpoint {path} missing leaves: {sorted(missing)[:5]}")
    shard_flat = _flatten(shardings) if shardings is not None else {}

    leaves_like, treedef = jax.tree_util.tree_flatten(state_like)
    keys = [
        _SEP.join(_path_str(p) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(state_like)[0]
    ]
    out = []
    for key, like in zip(keys, leaves_like):
        want_dtype = like.dtype if hasattr(like, "dtype") else arrays[key].dtype
        arr = jax.numpy.asarray(arrays[key]).astype(want_dtype)
        if key in shard_flat and shard_flat[key] is not None:
            out.append(jax.device_put(arr, shard_flat[key]))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
