"""Declarative failure-scenario matrix for decentralized TRAINING.

Training-stack mirror of `core.scenarios`: where that module replays one
gossip plan over a matrix of wireless failure scenarios, this one runs
one decentralized *training* configuration — same model, optimizer,
initial parameters, synthetic data stream, and sync strategy — under a
matrix of named replica-failure scenarios (`dist.SyncFailureModel`) and
aggregation modes.  Everything about the mixing plan (strategy, levels,
rounds, compression, rotation) is shared across cells; only the
`failures` / `aggregation` fields vary, so degradation is attributable
to the injected faults and the chosen defense alone.

Each cell reports the full metric history of a short end-to-end run
(`make_decentralized_step` metrics incl. the degradation trio:
survivor consensus error, effective replica fraction, rejected-gradient
count) plus summary properties the CI drift gate keys on.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import SyncConfig, SyncFailureModel
from repro.optim.optimizers import Optimizer

from .step import init_decentralized_state, make_decentralized_step

__all__ = [
    "TrainScenario",
    "TrainScenarioResult",
    "train_scenario_matrix",
    "run_train_scenarios",
]


@dataclasses.dataclass(frozen=True)
class TrainScenario:
    """One named cell of the training failure matrix."""

    name: str
    failures: Optional[SyncFailureModel] = None  # None = reliable baseline
    aggregation: str = "mean"
    description: str = ""


def train_scenario_matrix(
    *,
    churn_fraction: float = 0.25,
    straggler_fraction: float = 0.25,
    byzantine_fraction: float = 0.125,
    byzantine_scale: float = 10.0,
    seed: int = 0,
) -> list[TrainScenario]:
    """The default 4-scenario matrix: reliable baseline plus one cell
    per fault family, each paired with its natural defense —
    survivor-weighted mass renormalization for absence faults (churn,
    stragglers), trimmed-mean for adversarial ones (Byzantine)."""
    return [
        TrainScenario(
            "baseline", None, "mean",
            "reliable replicas, plain mixing",
        ),
        TrainScenario(
            "churn",
            SyncFailureModel(churn_fraction=churn_fraction, seed=seed),
            "survivor_weighted",
            f"{churn_fraction:.0%} of replicas absent each sync; "
            "doubly-stochastic mass renormalized over survivors",
        ),
        TrainScenario(
            "straggler",
            SyncFailureModel(straggler_fraction=straggler_fraction, seed=seed),
            "survivor_weighted",
            f"{straggler_fraction:.0%} of replicas miss each sync round",
        ),
        TrainScenario(
            "byzantine",
            SyncFailureModel(
                byzantine_fraction=byzantine_fraction,
                byzantine_scale=byzantine_scale, seed=seed,
            ),
            "trimmed_mean",
            f"{byzantine_fraction:.0%} of replicas transmit corrupted "
            f"gradients (x-{byzantine_scale:g}); trimmed-mean defense",
        ),
    ]


@dataclasses.dataclass
class TrainScenarioResult:
    """One scenario's end-to-end run: the per-step metric history."""

    scenario: TrainScenario
    history: list  # per-step dicts of float metrics

    @property
    def losses(self) -> np.ndarray:
        return np.array([h["loss"] for h in self.history])

    @property
    def final_loss(self) -> float:
        return float(self.losses[-1])

    @property
    def loss_drop(self) -> float:
        """first loss - last loss (> 0 means training progressed)."""
        return float(self.losses[0] - self.losses[-1])

    @property
    def survivor_error_final(self) -> float:
        return float(self.history[-1]["survivor_consensus_error"])

    @property
    def effective_replica_fraction_mean(self) -> float:
        return float(np.mean(
            [h["effective_replica_fraction"] for h in self.history]))

    @property
    def rejected_gradients_total(self) -> float:
        return float(sum(h["rejected_gradient_count"] for h in self.history))


def run_train_scenarios(
    model_cfg,
    optimizer: Optimizer,
    lr_fn: Callable,
    base_sync: SyncConfig,
    num_replicas: int,
    params,
    data,
    scenarios: Optional[Sequence[TrainScenario]] = None,
    *,
    num_steps: int = 6,
    clip_norm: float = 1.0,
    mesh=None,
    replica_axis: str = "replica",
) -> list[TrainScenarioResult]:
    """Run every scenario end-to-end from the SAME initial state.

    params: the base (unreplicated) parameter pytree; it is broadcast to
        the leading replica axis identically for every cell.
    data: object with ``batch_at(step) -> dict`` of host arrays whose
        leading axis is the global batch (``R * per_replica``); batches
        are deterministic in the step, so every cell consumes the exact
        same stream.
    base_sync: the shared mixing configuration; each scenario overrides
        only its `failures` / `aggregation` fields.
    """
    if scenarios is None:
        scenarios = train_scenario_matrix()
    R = num_replicas
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), params
    )
    out = []
    for sc in scenarios:
        sync = dataclasses.replace(
            base_sync, failures=sc.failures, aggregation=sc.aggregation
        )
        state = init_decentralized_state(params_r, optimizer, sync=sync)
        step = jax.jit(make_decentralized_step(
            model_cfg, optimizer, lr_fn, sync, R,
            clip_norm=clip_norm, mesh=mesh, replica_axis=replica_axis,
        ))
        history = []
        for s in range(num_steps):
            b = data.batch_at(s)
            batch = {
                k: jnp.asarray(v).reshape(R, -1, *v.shape[1:])
                for k, v in b.items()
            }
            state, m = step(state, batch)
            history.append({k: float(np.asarray(v)) for k, v in m.items()})
        out.append(TrainScenarioResult(scenario=sc, history=history))
    return out
