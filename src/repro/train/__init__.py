from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .step import (
    consensus_distance, init_decentralized_state, init_train_state,
    make_decentralized_step, make_train_step,
)
from .trainer import Trainer

__all__ = [
    "Trainer", "consensus_distance", "init_decentralized_state",
    "init_train_state", "latest_step", "make_decentralized_step",
    "make_train_step", "restore_checkpoint", "save_checkpoint",
]
