from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .scenarios import (
    TrainScenario, TrainScenarioResult, run_train_scenarios,
    train_scenario_matrix,
)
from .step import (
    consensus_distance, init_decentralized_state, init_train_state,
    make_decentralized_step, make_train_step, survivor_consensus_distance,
)
from .trainer import Trainer

__all__ = [
    "Trainer", "TrainScenario", "TrainScenarioResult", "consensus_distance",
    "init_decentralized_state", "init_train_state", "latest_step",
    "make_decentralized_step", "make_train_step", "restore_checkpoint",
    "run_train_scenarios", "save_checkpoint", "survivor_consensus_distance",
    "train_scenario_matrix",
]
