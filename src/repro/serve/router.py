"""Decentralized request routing from gossiped load estimates.

Every replica is an ingress: a request lands on a uniformly random
replica, which picks the target using ONLY its own gossiped view of the
fleet (`ControlPlane.round().table` — replica r's estimate of every
replica's scalar load).  The policy is power-of-two-choices: sample two
candidate replicas, send to the one the ingress *believes* is less
loaded.  P2C is the classic trick that turns O(log n) max-load into
O(log log n) — and it is exactly as robust to the staleness/approx
error of gossiped estimates as the theory promises, which is what the
fleet benchmark measures against a centralized least-loaded oracle.
"""
from __future__ import annotations

import numpy as np

__all__ = ["PowerOfTwoRouter", "LeastLoadedOracle", "RandomRouter"]


class PowerOfTwoRouter:
    """P2C over per-ingress estimated loads (gossiped, stale, approximate)."""

    name = "p2c_gossip"

    def __init__(self, R: int, seed: int = 0):
        self.R = R
        self.rng = np.random.default_rng(seed)

    def route(self, ingress: int, est_scores: np.ndarray) -> int:
        """est_scores: (R,) the INGRESS replica's estimate table."""
        c1, c2 = self.rng.choice(self.R, size=2, replace=False)
        return int(c1 if est_scores[c1] <= est_scores[c2] else c2)


class LeastLoadedOracle:
    """Centralized scheduler baseline: exact least-loaded over TRUE loads
    (zero control-plane bytes, perfect global state — the upper bound a
    decentralized router is measured against)."""

    name = "oracle"

    def __init__(self, R: int, seed: int = 0):
        self.R = R
        self.rng = np.random.default_rng(seed)

    def route(self, ingress: int, true_scores: np.ndarray) -> int:
        lo = np.flatnonzero(true_scores == true_scores.min())
        return int(self.rng.choice(lo))


class RandomRouter:
    """Uniform random target (the no-information lower bound)."""

    name = "random"

    def __init__(self, R: int, seed: int = 0):
        self.R = R
        self.rng = np.random.default_rng(seed)

    def route(self, ingress: int, scores: np.ndarray) -> int:
        return int(self.rng.integers(self.R))
