"""Batched decode serving.

`make_serve_step(cfg)` builds the single-token step the decode_32k /
long_500k dry-run cells lower; `Generator` drives it for the example
applications (greedy or temperature sampling, batched requests with
per-slot stop handling — a minimal continuous-batching core).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache
from repro.models.config import ModelConfig

__all__ = ["make_serve_step", "Generator"]


def make_serve_step(cfg: ModelConfig, dp=("data",)) -> Callable:
    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, dp=dp)
    return step


@dataclasses.dataclass
class Generator:
    cfg: ModelConfig
    params: dict
    max_len: int = 256
    temperature: float = 0.0
    eos_id: int = 1

    def __post_init__(self):
        step = make_serve_step(self.cfg, dp=None)
        self._step = jax.jit(step)

        def prefill(params, cache, prompts_tb):
            # teacher-force the whole prompt in ONE scanned call instead
            # of P python-dispatched steps; returns last-position logits
            def body(cache, tok):
                logits, cache = step(params, cache, tok)
                return cache, logits

            cache, logits = jax.lax.scan(body, cache, prompts_tb)
            return logits[-1], cache

        self._prefill = jax.jit(prefill)
        self.last_stats: dict = {}

    def generate(
        self,
        prompts: np.ndarray,          # (B, P) int32 prompt tokens
        steps: int,
        seed: int = 0,
        frames: Optional[jax.Array] = None,
    ) -> np.ndarray:
        B, P = prompts.shape
        cache = init_cache(
            self.params, self.cfg, batch=B, max_len=self.max_len, frames=frames,
            dp=None,
        )
        key = jax.random.PRNGKey(seed)
        logits, cache = self._prefill(
            self.params, cache, jnp.asarray(prompts.T)
        )
        out = []
        done = np.zeros(B, bool)
        live_tokens = 0
        tok = self._sample(logits, key)
        for t in range(steps):
            # finished slots emit eos_id forever; only live slots count
            # toward token throughput
            tok_np = np.where(done, self.eos_id, np.asarray(tok))
            live_tokens += int((~done).sum())
            out.append(tok_np)
            done |= tok_np == self.eos_id
            if done.all():
                break
            key, sub = jax.random.split(key)
            logits, cache = self._step(
                self.params, cache, jnp.asarray(tok_np)
            )
            tok = self._sample(logits, sub)
        result = np.stack(out, axis=1)
        self.last_stats = {
            "prompt_len": P,
            "decode_steps": result.shape[1],
            "live_tokens": live_tokens,
            "emitted_tokens": int(result.size),
        }
        return result

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature).astype(jnp.int32)
