"""Batched decode serving.

`make_serve_step(cfg)` builds the single-token step the decode_32k /
long_500k dry-run cells lower; `Generator` drives it for the example
applications (greedy or temperature sampling, batched requests with
per-slot stop handling — a minimal continuous-batching core).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode_step, init_cache
from repro.models.config import ModelConfig

__all__ = ["make_serve_step", "Generator"]


def make_serve_step(cfg: ModelConfig, dp=("data",)) -> Callable:
    def step(params, cache, tokens):
        return decode_step(params, cfg, cache, tokens, dp=dp)
    return step


@dataclasses.dataclass
class Generator:
    cfg: ModelConfig
    params: dict
    max_len: int = 256
    temperature: float = 0.0
    eos_id: int = 1

    def __post_init__(self):
        self._step = jax.jit(make_serve_step(self.cfg, dp=None))

    def generate(
        self,
        prompts: np.ndarray,          # (B, P) int32 prompt tokens
        steps: int,
        seed: int = 0,
        frames: Optional[jax.Array] = None,
    ) -> np.ndarray:
        B, P = prompts.shape
        cache = init_cache(
            self.params, self.cfg, batch=B, max_len=self.max_len, frames=frames,
            dp=None,
        )
        key = jax.random.PRNGKey(seed)
        # prefill by teacher-forcing the prompt through decode steps
        logits = None
        for t in range(P):
            logits, cache = self._step(self.params, cache, jnp.asarray(prompts[:, t]))
        out = []
        done = np.zeros(B, bool)
        tok = self._sample(logits, key)
        for t in range(steps):
            out.append(np.asarray(tok))
            done |= np.asarray(tok) == self.eos_id
            if done.all():
                break
            key, sub = jax.random.split(key)
            logits, cache = self._step(self.params, cache, tok)
            tok = self._sample(logits, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.temperature).astype(jnp.int32)
