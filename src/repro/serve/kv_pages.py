"""Paged KV-cache bookkeeping for continuous batching.

`PageTable` is the host-side allocator (the MaxText `page_manager`
idiom): a fixed pool of `num_pages` physical KV pages of `page_size`
tokens each, handed out to decode slots and reclaimed when a request
retires.  The device never sees the free list — it sees only the dense
`(num_slots, pages_per_slot)` int32 `page_map` (unallocated entries
point at the trash page, index `num_pages`), so the jitted decode step
keeps a static signature while requests come and go.

The device-side pools themselves live in the model layer
(`models.attention.init_paged_kv_cache` / `paged_decode_attention`,
threaded by `models.model.paged_decode_step`); this module is pure
numpy bookkeeping.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["PageTable"]


@dataclasses.dataclass
class PageTable:
    """Slot -> physical-page allocator over a fixed page pool.

    num_pages: physical pages in the pool (the trash page at index
        `num_pages` is implicit and never allocated).
    page_size: tokens per page.
    num_slots: decode slots (the batched step's static batch).
    pages_per_slot: logical pages per slot row; a slot can therefore
        hold at most `pages_per_slot * page_size` tokens.
    """

    num_pages: int
    page_size: int
    num_slots: int
    pages_per_slot: int

    def __post_init__(self):
        if min(self.num_pages, self.page_size, self.num_slots,
               self.pages_per_slot) < 1:
            raise ValueError(
                f"PageTable dims must be >= 1, got {self}"
            )
        self.trash = self.num_pages
        self.page_map = np.full(
            (self.num_slots, self.pages_per_slot), self.trash, np.int32
        )
        self._free = list(range(self.num_pages - 1, -1, -1))  # pop() -> 0,1,..
        self._held = [0] * self.num_slots  # pages held per slot

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def utilization(self) -> float:
        return self.used_pages / self.num_pages

    def pages_needed(self, num_tokens: int) -> int:
        return -(-max(num_tokens, 1) // self.page_size)

    def can_alloc(self, num_tokens: int) -> bool:
        need = self.pages_needed(num_tokens)
        return need <= len(self._free) and need <= self.pages_per_slot

    def alloc(self, slot: int, num_tokens: int) -> None:
        """Reserve pages for `num_tokens` tokens in `slot` (a free slot).

        Allocation is up-front for the request's full budget
        (prompt + max new tokens), so decoding never hits a mid-stream
        out-of-pages condition; callers gate admission on `can_alloc`.
        """
        if self._held[slot]:
            raise ValueError(f"slot {slot} already holds pages")
        need = self.pages_needed(num_tokens)
        if need > self.pages_per_slot:
            raise ValueError(
                f"{num_tokens} tokens need {need} pages > pages_per_slot="
                f"{self.pages_per_slot}"
            )
        if need > len(self._free):
            raise ValueError(
                f"out of pages: need {need}, free {len(self._free)}"
            )
        for p in range(need):
            self.page_map[slot, p] = self._free.pop()
        self._held[slot] = need

    def free(self, slot: int) -> int:
        """Release `slot`'s pages back to the pool; returns pages freed."""
        held = self._held[slot]
        for p in range(held):
            self._free.append(int(self.page_map[slot, p]))
        self.page_map[slot, :] = self.trash
        self._held[slot] = 0
        return held

    def slot_pages(self, slot: int) -> int:
        return self._held[slot]
