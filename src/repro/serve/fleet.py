"""Simulated N-replica serving fleet, gossip-coordinated.

Every replica runs the REAL scheduling stack — `BatchingEngine` over a
`PageTable` (admission, up-front page reservation, per-step retirement)
— with a `SimBackend` standing in for the model, so fleet-scale
behavior (queueing, page pressure, admission latency) is produced by
the production code paths, not a queueing abstraction.

Per tick: requests arrive (Poisson), each lands on a random ingress
replica and is routed by the configured policy; every replica advances
its engine `speed` steps; every `gossip_interval` ticks the control
plane runs one multiscale round and refreshes each replica's estimate
table.  `p2c_gossip` routes from those (stale, approximate) estimates;
`oracle` is the centralized least-loaded scheduler with perfect state
and zero control bytes; `random` is the no-information floor.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .batching import BatchingEngine, SimBackend
from .control_plane import LOAD_FIELDS, ControlPlane
from .kv_pages import PageTable
from .router import LeastLoadedOracle, PowerOfTwoRouter, RandomRouter

__all__ = ["FleetConfig", "FleetResult", "run_fleet", "ROUTERS"]

ROUTERS = ("p2c_gossip", "oracle", "random")


@dataclasses.dataclass
class FleetConfig:
    replicas: int = 16
    slots_per_replica: int = 4
    pages_per_replica: int = 48
    page_size: int = 8
    pages_per_slot: int = 12
    max_prompt_len: int = 16
    ticks: int = 240
    arrival_rate: float = 0.0        # requests/tick; 0 -> near-saturation
    prompt_len: tuple = (4, 16)      # uniform [lo, hi)
    decode_len: tuple = (8, 48)
    router: str = "p2c_gossip"
    gossip_interval: int = 4
    gossip_eps: float = 1e-4
    speeds: Optional[tuple] = None   # per-replica engine steps per tick
    seed: int = 0

    def resolved_rate(self) -> float:
        """Default workload: ~90% of fleet decode capacity, so routing
        quality (not raw capacity) decides throughput."""
        if self.arrival_rate > 0:
            return self.arrival_rate
        speeds = self.resolved_speeds()
        cap = sum(speeds) * self.slots_per_replica  # tokens/tick ceiling
        mean_len = (self.decode_len[0] + self.decode_len[1]) / 2.0
        return 0.9 * cap / mean_len

    def resolved_speeds(self) -> tuple:
        if self.speeds is not None:
            if len(self.speeds) != self.replicas:
                raise ValueError("speeds must have one entry per replica")
            return tuple(int(s) for s in self.speeds)
        # mildly heterogeneous fleet: every 4th replica is 2x fast
        return tuple(2 if r % 4 == 0 else 1 for r in range(self.replicas))


@dataclasses.dataclass
class FleetResult:
    router: str
    ticks: int
    tokens: int
    completed: int
    submitted: int
    throughput: float                # tokens / tick
    admission_latency_mean: float    # ticks, completed requests
    admission_latency_p95: float
    page_utilization_mean: float
    queue_depth_mean: float
    control_rounds: int
    control_messages: int
    control_bytes: int
    bytes_per_round: float
    payload_values: int
    level_messages: Optional[np.ndarray]   # (L,) last round's per-level split


def run_fleet(cfg: FleetConfig) -> FleetResult:
    R = cfg.replicas
    rng = np.random.default_rng(cfg.seed)
    speeds = cfg.resolved_speeds()
    rate = cfg.resolved_rate()

    engines = []
    for r in range(R):
        table = PageTable(
            num_pages=cfg.pages_per_replica, page_size=cfg.page_size,
            num_slots=cfg.slots_per_replica,
            pages_per_slot=cfg.pages_per_slot,
        )
        backend = SimBackend(cfg.slots_per_replica)
        # SimBackend never emits EOS: lifetimes come from max_new_tokens
        engines.append(
            BatchingEngine(backend, table, eos_id=-1, seed=cfg.seed + r)
        )

    if cfg.router == "p2c_gossip":
        router = PowerOfTwoRouter(R, seed=cfg.seed + 101)
        cp = ControlPlane(R, full_view=True, seed=cfg.seed,
                          eps=cfg.gossip_eps)
    elif cfg.router == "oracle":
        router, cp = LeastLoadedOracle(R, seed=cfg.seed + 101), None
    elif cfg.router == "random":
        router, cp = RandomRouter(R, seed=cfg.seed + 101), None
    else:
        raise ValueError(f"unknown router {cfg.router!r}; one of {ROUTERS}")

    # replica r's gossiped estimate of every replica's load score; until
    # the first round completes, everyone assumes a uniformly idle fleet
    est_tables = np.zeros((R, R))
    last_level_messages = None
    submitted = 0
    page_util, queue_depth = [], []

    for tick in range(cfg.ticks):
        # -- gossip round (decentralized router only) --------------------
        if cp is not None and tick % cfg.gossip_interval == 0:
            loads = np.stack([
                [e.load_vector()[f] for f in LOAD_FIELDS] for e in engines
            ])
            scores = np.array([e.load_score() for e in engines])
            rr = cp.round(loads, scores, round_idx=tick)
            est_tables = rr.table
            last_level_messages = rr.level_messages

        # -- arrivals + routing ------------------------------------------
        true_scores = np.array([e.load_score() for e in engines])
        for _ in range(rng.poisson(rate)):
            ingress = int(rng.integers(R))
            plen = int(rng.integers(*cfg.prompt_len))
            dlen = int(rng.integers(*cfg.decode_len))
            if cfg.router == "p2c_gossip":
                target = router.route(ingress, est_tables[ingress])
            else:
                target = router.route(ingress, true_scores)
            engines[target].submit(np.zeros(plen, np.int32), dlen)
            submitted += 1

        # -- serve --------------------------------------------------------
        for r, e in enumerate(engines):
            for _ in range(speeds[r]):
                if not e.idle:
                    e.step()

        page_util.append(np.mean([e.table.utilization for e in engines]))
        queue_depth.append(np.mean([e.queue_depth for e in engines]))

    tokens = sum(e.tokens_generated for e in engines)
    done = [r for e in engines for r in e.completed]
    lat = np.array([r.admission_latency for r in done]) if done else np.array([0.0])
    return FleetResult(
        router=cfg.router,
        ticks=cfg.ticks,
        tokens=tokens,
        completed=len(done),
        submitted=submitted,
        throughput=tokens / max(1, cfg.ticks),
        admission_latency_mean=float(lat.mean()),
        admission_latency_p95=float(np.percentile(lat, 95)),
        page_utilization_mean=float(np.mean(page_util)),
        queue_depth_mean=float(np.mean(queue_depth)),
        control_rounds=cp.rounds_run if cp else 0,
        control_messages=cp.total_messages if cp else 0,
        control_bytes=cp.total_bytes if cp else 0,
        bytes_per_round=(cp.total_bytes / cp.rounds_run) if cp else 0.0,
        payload_values=cp.payload_values if cp else 0,
        level_messages=last_level_messages,
    )
