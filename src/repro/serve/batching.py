"""Continuous batching over a paged KV cache.

The engine replaces `Generator`'s run-to-all-done loop: decode slots are
admitted/retired **per step** — a slot frees its pages the moment its
request hits EOS (or its token budget) and is refilled from the queue,
so the batch stays full under streaming traffic.  All device work goes
through two jitted calls with static signatures (the page table keeps
them shape-stable while requests come and go):

* `prefill` — one `lax.scan` over the padded prompt length teacher-
  forces every just-admitted slot's prompt in a single call (no
  per-token Python dispatch) and samples each slot's first token from
  its own last-prompt-position logits;
* `decode` — one `paged_decode_step` advancing every active slot at its
  own position (`steps` is per-slot; finished/inactive slots write to
  the trash page).

The scheduling core is model-free: `BatchingEngine` drives any
`backend` with `prefill(...)` / `decode(...)` — `ModelBackend` runs the
real paged model, `SimBackend` is the token-stream stub the fleet
simulation (`serve.fleet`) uses to exercise identical admission/paging
logic at N-replica scale.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from .kv_pages import PageTable

__all__ = ["Request", "BatchingEngine", "ModelBackend", "SimBackend"]


@dataclasses.dataclass
class Request:
    """One generation request and its lifecycle timestamps (engine steps)."""

    rid: int
    prompt: np.ndarray            # (plen,) int32
    max_new_tokens: int
    arrived: int = -1
    admitted: int = -1
    finished: int = -1
    slot: int = -1
    tokens: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.finished >= 0

    @property
    def admission_latency(self) -> int:
        return self.admitted - self.arrived


class ModelBackend:
    """Paged decode of a real model: owns the device cache, exposes the
    two jitted entry points the engine schedules."""

    def __init__(self, cfg, params, *, num_slots: int, num_pages: int,
                 page_size: int, max_prompt_len: int,
                 temperature: float = 0.0):
        import jax
        import jax.numpy as jnp

        from repro.models import init_paged_cache, paged_decode_step

        self.cfg = cfg
        self.params = params
        self.temperature = float(temperature)
        self.num_slots = num_slots
        self.max_prompt_len = int(max_prompt_len)
        self.cache = init_paged_cache(cfg, num_slots, num_pages, page_size)
        self._jnp = jnp
        self._jax = jax

        def sample(logits, key):
            if self.temperature <= 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, logits / self.temperature
            ).astype(jnp.int32)

        def decode_fn(params, cache, tokens, page_map, steps, active, key):
            logits, cache = paged_decode_step(
                params, cfg, cache, tokens, page_map, steps, active
            )
            return sample(logits, key), cache

        def prefill_fn(params, cache, prompts, plens, page_map, admit, key):
            # prompts: (B, Pmax) int32 front-aligned, padded with 0
            def body(carry, inp):
                cache, t = carry
                tok = inp                              # (B,)
                wmask = admit & (t < plens)
                steps = jnp.broadcast_to(t, plens.shape)
                logits, cache = paged_decode_step(
                    params, cfg, cache, tok, page_map, steps, wmask
                )
                return (cache, t + 1), logits

            (cache, _), logits = jax.lax.scan(
                body, (cache, jnp.zeros((), jnp.int32)),
                jnp.transpose(prompts),                # (Pmax, B)
            )
            # each admitted slot samples from its own prompt-final logits
            last = jnp.take_along_axis(
                logits, (jnp.clip(plens - 1, 0, None))[None, :, None], axis=0
            )[0]                                       # (B, V)
            return sample(last, key), cache

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)

    def warmup(self, table: PageTable) -> float:
        """Compile both entry points against dummy inputs; returns
        seconds spent (reported as `jit_warmup_s` so tok/s excludes
        compile)."""
        import time

        jnp = self._jnp
        B = self.num_slots
        t0 = time.perf_counter()
        zero_map = jnp.asarray(table.page_map)
        toks, cache = self._prefill(
            self.params, self.cache,
            jnp.zeros((B, self.max_prompt_len), jnp.int32),
            jnp.zeros((B,), jnp.int32), zero_map,
            jnp.zeros((B,), bool), self._jax.random.PRNGKey(0),
        )
        toks, cache = self._decode(
            self.params, cache, toks, zero_map,
            jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool),
            self._jax.random.PRNGKey(0),
        )
        toks.block_until_ready()
        # dummy state is discarded: masks were all-False so self.cache
        # would be unchanged anyway, but keep the pristine one
        return time.perf_counter() - t0

    def prefill(self, prompts, plens, page_map, admit_mask, key_seed):
        jnp = self._jnp
        tok, self.cache = self._prefill(
            self.params, self.cache, jnp.asarray(prompts),
            jnp.asarray(plens), jnp.asarray(page_map),
            jnp.asarray(admit_mask), self._jax.random.PRNGKey(key_seed),
        )
        return np.asarray(tok)

    def decode(self, tokens, steps, page_map, active, key_seed):
        jnp = self._jnp
        tok, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(page_map), jnp.asarray(steps),
            jnp.asarray(active), self._jax.random.PRNGKey(key_seed),
        )
        return np.asarray(tok)


class SimBackend:
    """Deterministic token-stream stub (no model, no device work): every
    active slot emits token 2 forever, so request lifetimes are governed
    purely by `max_new_tokens`.  Lets the fleet simulation run the real
    admission / page-allocation / retirement logic at N-replica scale."""

    def __init__(self, num_slots: int, fill_token: int = 2):
        self.num_slots = num_slots
        self.fill = np.int32(fill_token)

    def prefill(self, prompts, plens, page_map, admit_mask, key_seed):
        return np.full(self.num_slots, self.fill, np.int32)

    def decode(self, tokens, steps, page_map, active, key_seed):
        return np.full(self.num_slots, self.fill, np.int32)


class BatchingEngine:
    """Admit -> prefill -> decode -> retire, one call per serving step.

    Pages for a request's full budget (prompt + max_new_tokens) are
    reserved at admission (`PageTable.alloc`), so decode never runs out
    of pages mid-stream; admission is head-of-line blocked on page/slot
    availability, which is exactly the backpressure signal the gossip
    control plane exports (`load_vector`).
    """

    TOKS_WINDOW = 16  # steps of tok/s history for the load vector

    def __init__(self, backend, table: PageTable, *, eos_id: int = 1,
                 seed: int = 0):
        if getattr(backend, "num_slots", table.num_slots) != table.num_slots:
            raise ValueError("backend/table num_slots mismatch")
        self.backend = backend
        self.table = table
        self.eos_id = int(eos_id)
        self.seed = int(seed)
        self.max_prompt_len = getattr(
            backend, "max_prompt_len",
            table.pages_per_slot * table.page_size,
        )
        S = table.num_slots
        self.slot_req: list[Optional[Request]] = [None] * S
        self.steps = np.zeros(S, np.int32)
        self.cur_tok = np.zeros(S, np.int32)
        self.queue: deque[Request] = deque()
        self.t = 0
        self._next_rid = 0
        self.completed: list[Request] = []
        self.tokens_generated = 0
        self._recent = deque(maxlen=self.TOKS_WINDOW)

    # ------------------------------ intake ------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int) -> Request:
        prompt = np.asarray(prompt, np.int32).ravel()
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if len(prompt) > self.max_prompt_len:
            raise ValueError(
                f"prompt length {len(prompt)} > max_prompt_len "
                f"{self.max_prompt_len}"
            )
        budget = len(prompt) + int(max_new_tokens)
        if self.table.pages_needed(budget) > self.table.pages_per_slot:
            raise ValueError(
                f"request budget {budget} tokens exceeds slot capacity "
                f"{self.table.pages_per_slot * self.table.page_size}"
            )
        req = Request(self._next_rid, prompt, int(max_new_tokens),
                      arrived=self.t)
        self._next_rid += 1
        self.queue.append(req)
        return req

    # ---------------------------- load vector ---------------------------

    @property
    def active_slots(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def load_vector(self) -> dict:
        """The control-plane payload: local-only observables."""
        recent = float(np.mean(self._recent)) if self._recent else 0.0
        return {
            "queue_depth": float(self.queue_depth),
            "active_slots": float(self.active_slots),
            "free_pages": float(self.table.free_pages),
            "tok_s": recent,
        }

    def load_score(self) -> float:
        """Scalar routing load: outstanding work normalized by capacity."""
        return (self.queue_depth + self.active_slots) / max(
            1, self.table.num_slots
        )

    @property
    def idle(self) -> bool:
        return self.active_slots == 0 and not self.queue

    # ------------------------------ stepping -----------------------------

    def _emit(self, slot: int, tok: int) -> None:
        req = self.slot_req[slot]
        req.tokens.append(int(tok))
        self.tokens_generated += 1
        self.cur_tok[slot] = tok
        if int(tok) == self.eos_id or len(req.tokens) >= req.max_new_tokens:
            req.finished = self.t
            self.completed.append(req)
            self.table.free(slot)
            self.slot_req[slot] = None
            self.steps[slot] = 0

    def step(self) -> dict:
        """One serving step: admit from the queue into free slots, batch-
        prefill the admissions, run one decode step for all active slots,
        retire finished ones.  Returns per-step event counts."""
        S = self.table.num_slots
        # -- admit (head-of-line) ----------------------------------------
        admitted: list[int] = []
        for slot in range(S):
            if not self.queue or self.slot_req[slot] is not None:
                continue
            req = self.queue[0]
            budget = len(req.prompt) + req.max_new_tokens
            if not self.table.can_alloc(budget):
                break
            self.queue.popleft()
            self.table.alloc(slot, budget)
            req.slot, req.admitted = slot, self.t
            self.slot_req[slot] = req
            self.steps[slot] = 0
            admitted.append(slot)

        # -- prefill admissions in one scanned call ----------------------
        if admitted:
            prompts = np.zeros((S, self.max_prompt_len), np.int32)
            plens = np.zeros(S, np.int32)
            admit_mask = np.zeros(S, bool)
            for slot in admitted:
                p = self.slot_req[slot].prompt
                prompts[slot, : len(p)] = p
                plens[slot] = len(p)
                admit_mask[slot] = True
            first = self.backend.prefill(
                prompts, plens, self.table.page_map, admit_mask,
                self._key(),
            )
            for slot in admitted:
                self.steps[slot] = plens[slot]
                self._emit(slot, first[slot])

        # -- decode every still-active slot ------------------------------
        active = np.array([r is not None for r in self.slot_req])
        decoded = int(active.sum())
        if decoded:
            tok = self.backend.decode(
                self.cur_tok, self.steps, self.table.page_map, active,
                self._key(),
            )
            self.steps[active] += 1
            for slot in np.nonzero(active)[0]:
                self._emit(int(slot), tok[slot])

        emitted = len(admitted) + decoded
        self._recent.append(emitted)
        self.t += 1
        return {
            "admitted": len(admitted),
            "decoded": decoded,
            "emitted": emitted,
            "active": self.active_slots,
            "queue": self.queue_depth,
            "page_utilization": self.table.utilization,
        }

    def _key(self) -> int:
        # one stream per engine step phase; deterministic in (seed, t)
        return self.seed * 1_000_003 + self.t

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drive until queue + slots drain (or `max_steps`); returns the
        completed requests in completion order."""
        for _ in range(max_steps):
            if self.idle:
                break
            self.step()
        return self.completed
