from .engine import Generator, make_serve_step

__all__ = ["Generator", "make_serve_step"]
