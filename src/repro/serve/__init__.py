from .batching import BatchingEngine, ModelBackend, Request, SimBackend
from .control_plane import LOAD_FIELDS, ControlPlane, RoundResult
from .engine import Generator, make_serve_step
from .fleet import ROUTERS, FleetConfig, FleetResult, run_fleet
from .kv_pages import PageTable
from .router import LeastLoadedOracle, PowerOfTwoRouter, RandomRouter

__all__ = [
    "Generator",
    "make_serve_step",
    "PageTable",
    "Request",
    "BatchingEngine",
    "ModelBackend",
    "SimBackend",
    "ControlPlane",
    "RoundResult",
    "LOAD_FIELDS",
    "PowerOfTwoRouter",
    "LeastLoadedOracle",
    "RandomRouter",
    "FleetConfig",
    "FleetResult",
    "run_fleet",
    "ROUTERS",
]
