"""Multiscale-gossip control plane for a serving fleet.

Each replica holds a local load vector (queue depth, active slots, free
pages, recent tok/s — `BatchingEngine.load_vector`).  Replicas average
these vectors over the paper's n^(2/3) hierarchy so every replica
converges to fleet-wide estimates WITHOUT a centralized scheduler: the
replica set is embedded as a random geometric graph (replicas within
radio/rack range gossip directly, distant ones via the overlay routes —
the Geographic-Gossip idiom), the hierarchy depth comes from
`dist.topology.suggest_levels`, and each control round is one pass of
the plan/execute simulation core (`core.build_plan` /
`core.execute_plan`) with its presampled exchange schedule.

The whole payload rides ONE schedule: all vector fields are executed as
"trials" of `execute_plan` sharing a single round seed, in the paper's
fixed-iterations mode (`fixed_ticks_scale`, §VI) so termination is
data-independent — every field is mixed by the identical exchange
sequence, i.e. exactly one packet per exchange carries the full vector.
Message counts therefore price the round in transmissions, and bytes =
messages x payload values x `bytes_per_value` (the Nokleby et al.
point: consensus cost in bytes, not messages).

With `full_view=True` the payload additionally carries a per-replica
load table seeded as R * score_j at replica j (mean = score_j), so
after a round every replica holds an estimate of EVERY replica's scalar
load — the input to power-of-two-choices routing (`serve.router`).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import (
    ExecOptions,
    build_plan,
    execute_plan,
    random_geometric_graph,
)
from repro.dist.topology import suggest_levels

__all__ = ["LOAD_FIELDS", "RoundResult", "ControlPlane"]

LOAD_FIELDS = ("queue_depth", "active_slots", "free_pages", "tok_s")


@dataclasses.dataclass
class RoundResult:
    """Per-replica estimates + cost accounting of one control round."""

    summary: np.ndarray            # (R, F) each replica's fleet-mean estimate
    table: Optional[np.ndarray]    # (R, R) replica r's estimate of score_j
    messages: int                  # single-hop transmissions this round
    control_bytes: int             # messages * payload_values * bytes_per_value
    level_messages: np.ndarray     # (L,) per hierarchy level
    level_ticks: np.ndarray        # (L,) gossip ticks per level
    payload_values: int


class ControlPlane:
    """Fleet-wide load averaging over the multiscale hierarchy.

    R replicas, hierarchy depth `len(suggest_levels(R))`, one
    `HierarchyPlan` built once and reused every round (the compiled
    executor is cached inside the plan, so steady-state rounds are a
    single device call).
    """

    def __init__(self, R: int, *, full_view: bool = True, seed: int = 0,
                 eps: float = 1e-4, bytes_per_value: int = 4,
                 fixed_ticks_scale: float = 1.0, backend: str = "lax"):
        if R < 2:
            raise ValueError(f"control plane needs >= 2 replicas, got {R}")
        self.R = R
        self.full_view = bool(full_view)
        self.seed = int(seed)
        self.eps = float(eps)
        self.bytes_per_value = int(bytes_per_value)
        if fixed_ticks_scale <= 0:
            # eps-oracle termination is data-dependent: different payload
            # fields would stop at different ticks and the one-packet-per-
            # exchange byte accounting would be wrong
            raise ValueError("control plane requires fixed_ticks_scale > 0")
        self.fixed_ticks_scale = float(fixed_ticks_scale)
        self.backend = backend
        self.levels = suggest_levels(R)

        # replica deployment: a connected RGG over the unit square
        graph = None
        for attempt in range(32):
            g = random_geometric_graph(R, seed=seed + 1000 * attempt)
            if g.is_connected():
                graph = g
                break
        if graph is None:
            raise RuntimeError(f"no connected RGG deployment for R={R}")
        self.graph = graph
        try:
            self.plan = build_plan(graph, k=len(self.levels), seed=seed)
        except Exception:
            # tiny fleets where the suggest_levels depth over-partitions
            # the geometric deployment fall back to the plan's own rule
            self.plan = build_plan(graph, seed=seed)

        self.rounds_run = 0
        self.total_messages = 0
        self.total_bytes = 0

    @property
    def payload_values(self) -> int:
        return len(LOAD_FIELDS) + (self.R if self.full_view else 0)

    def round(self, loads: np.ndarray, scores: Optional[np.ndarray] = None,
              round_idx: Optional[int] = None) -> RoundResult:
        """One multiscale control round.

        loads: (R, F) local load vectors, F == len(LOAD_FIELDS).
        scores: (R,) scalar routing loads (required when full_view).
        round_idx: drives the round's exchange randomness (defaults to
            the internal round counter) — one seed per round, shared by
            every payload field.
        """
        loads = np.asarray(loads, np.float64)
        if loads.shape != (self.R, len(LOAD_FIELDS)):
            raise ValueError(
                f"loads must be ({self.R}, {len(LOAD_FIELDS)}), "
                f"got {loads.shape}"
            )
        fields = [loads[:, f] for f in range(loads.shape[1])]
        if self.full_view:
            if scores is None:
                raise ValueError("full_view=True needs per-replica scores")
            scores = np.asarray(scores, np.float64).ravel()
            # field F+j holds R * score_j at replica j (mean == score_j)
            fields.extend(
                self.R * scores[j] * np.eye(self.R)[j] for j in range(self.R)
            )
        x0 = np.stack(fields).astype(np.float32)          # (T, R)
        if round_idx is None:
            round_idx = self.rounds_run
        # ONE presampled schedule for the whole payload: every field
        # rides the same exchanges (same seed -> same schedule), i.e. a
        # single packet per exchange carries payload_values floats
        seed = self.seed * 7_919 + int(round_idx)
        T = x0.shape[0]
        # weighted ratio-consensus: unequal cell sizes otherwise bias the
        # promoted averages (spiky table fields are the worst case)
        res = execute_plan(
            self.plan, x0, eps=self.eps, seeds=[seed] * T,
            fixed_ticks_scale=self.fixed_ticks_scale, weighted=True,
            options=ExecOptions(backend=self.backend),
        )
        messages = int(res.messages[0])
        assert int(res.messages.min()) == int(res.messages.max()), (
            "payload fields must share one exchange schedule"
        )
        nbytes = messages * self.payload_values * self.bytes_per_value

        F = len(LOAD_FIELDS)
        summary = np.asarray(res.x_final[:F]).T            # (R, F)
        table = (
            np.asarray(res.x_final[F:]).T if self.full_view else None
        )                                                  # (R, R)
        self.rounds_run += 1
        self.total_messages += messages
        self.total_bytes += nbytes
        return RoundResult(
            summary=summary,
            table=table,
            messages=messages,
            control_bytes=nbytes,
            level_messages=np.asarray(res.level_messages[0], np.int64),
            level_ticks=np.asarray(res.level_ticks[0], np.int64),
            payload_values=self.payload_values,
        )
