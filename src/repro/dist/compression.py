"""Gradient compression with error feedback for gossip synchronization.

Gossip strategies trade exactness for message cost; compression trades
wire bytes for a bounded, *recoverable* error: whatever a round does not
send is kept in a per-replica residual and re-injected next round
(error feedback, Seide et al. / Karimireddy et al.), so compressed
averaging still moves all gradient mass eventually.

Schemes
-------
``none``   identity (and the fast path: returns its inputs untouched).
``topk``   per replica, keep the k = max(1, frac * D) largest-magnitude
           entries of the (gradient + residual) accumulator; the sent
           tensor plus the new residual reconstructs the accumulator
           bitwise (sent entries are exact copies, the rest exact
           leftovers).
``int8``   symmetric per-leaf quantization to 127 bins: |error| <=
           max|g| / 127 per entry; wire cost 1 byte vs 4 (fraction
           0.25).

`compress` returns the *as-transmitted* dense tensors (what the peer
would reconstruct) so the mixing math stays dtype-uniform and jittable;
`decompress` is the explicit wire-decoding hook (identity for these
dense simulations, kept so call sites are already shaped for packed
formats).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "CompressionConfig",
    "compress",
    "decompress",
    "init_residual",
    "wire_fraction",
]

SCHEMES = ("none", "topk", "int8")


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"
    topk_fraction: float = 0.25

    def __post_init__(self):
        if self.scheme not in SCHEMES:
            raise ValueError(
                f"unknown scheme {self.scheme!r}; expected one of {SCHEMES}"
            )
        if not 0.0 < self.topk_fraction <= 1.0:
            raise ValueError(
                f"topk_fraction must be in (0, 1], got {self.topk_fraction}"
            )


def init_residual(grads: Any) -> Any:
    """Zero error-feedback residual matching the gradient pytree."""
    return jax.tree.map(jnp.zeros_like, grads)


def compress(grads: Any, residual: Any, cfg: CompressionConfig) -> tuple[Any, Any]:
    """(payload, new_residual) with payload + new_residual == grads + residual
    reconstructing the accumulator BITWISE for both schemes — topk entries
    are exact copies or exact leftovers, and int8's per-entry subtraction
    acc - dequant is Sterbenz-exact (dequant/2 <= acc <= 2*dequant whenever
    the quantized level is nonzero; entries that quantize to zero leave the
    accumulator itself as residual) — so no gradient mass is ever created
    or destroyed by a sync, only deferred.  Leaves carry a leading replica
    axis; compression decisions are made per replica (each replica
    transmits independently)."""
    if cfg.scheme == "none":
        return grads, residual

    def sent_of(g, r):
        acc = g + r
        if cfg.scheme == "topk":
            return _topk_rows(acc, cfg.topk_fraction)
        return _int8_roundtrip(acc)  # int8

    payload = jax.tree.map(sent_of, grads, residual)
    new_res = jax.tree.map(lambda g, r, p: (g + r) - p, grads, residual, payload)
    return payload, new_res


def decompress(payload: Any, cfg: CompressionConfig) -> Any:
    """Wire-decoding hook; dense simulated payloads decode to themselves."""
    del cfg
    return payload


def wire_fraction(cfg: CompressionConfig) -> float:
    """Bytes on the wire relative to dense float32.

    topk ships (value, index) pairs — 2x per kept entry, capped at dense
    cost (a sender would fall back to dense past the break-even point);
    int8 ships one byte per entry plus a scalar scale (amortized away).
    """
    if cfg.scheme == "none":
        return 1.0
    if cfg.scheme == "int8":
        return 0.25
    return min(1.0, 2.0 * cfg.topk_fraction)


def _topk_rows(acc: jax.Array, fraction: float) -> jax.Array:
    """Keep the k largest-|.| entries per replica row; zero the rest.

    Ties at the threshold keep every tied entry, hence nnz can exceed k
    by the tie count (tests tolerate k+1); kept entries are bitwise
    copies of the accumulator so the residual decomposition is exact.
    """
    R = acc.shape[0]
    flat = acc.reshape(R, -1)
    d = flat.shape[1]
    k = max(1, int(fraction * d))
    mag = jnp.abs(flat)
    kth = lax.top_k(mag, k)[0][:, -1]
    mask = mag >= kth[:, None]
    return jnp.where(mask, flat, 0.0).reshape(acc.shape).astype(acc.dtype)


def _int8_roundtrip(acc: jax.Array) -> jax.Array:
    """Symmetric per-replica int8 quantize/dequantize: q = round(x/s)
    with s = max|x_r|/127 computed per replica row (a sender only knows
    its own gradient), so |dequant - x| <= s/2 <= max|x_r|/127."""
    row_axes = tuple(range(1, acc.ndim))
    scale = jnp.max(jnp.abs(acc), axis=row_axes, keepdims=True) / 127.0
    safe = jnp.maximum(scale, jnp.finfo(acc.dtype).tiny)
    q = jnp.clip(jnp.round(acc / safe), -127, 127).astype(jnp.int8)
    return jnp.where(scale > 0, q.astype(acc.dtype) * safe, jnp.zeros_like(acc))
