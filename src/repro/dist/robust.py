"""Robust aggregation modes for decentralized gradient sync.

Plain gossip mixing averages whatever arrives; one Byzantine replica
transmitting ``-scale * g`` can therefore drag every honest replica's
mixed gradient arbitrarily far (the mass-distortion failure the paper's
§VI-C scenarios model at the packet level).  This module provides the
aggregation modes `SyncConfig.aggregation` selects from:

* ``"mean"`` — today's behavior, the strategy's own mixing untouched.
* ``"trimmed_mean"`` — per-coordinate sort over replicas, discard the
  ``k_trim`` smallest and largest live values, average the rest.  With
  ``k_trim >= #byzantine`` every surviving value is bracketed by honest
  values per coordinate, which is what bounds the aggregated norm.
* ``"coordinate_median"`` — per-coordinate median over live replicas
  (the maximally trimmed special case).
* ``"survivor_weighted"`` — keeps the plan's mixing strategy but runs
  it as a weight-channel pair ``fn(w * x) / fn(w)`` with ``w = live``:
  the doubly-stochastic mass that dropped replicas would have carried
  is renormalized over survivors instead of diluting the average with
  zeros (mass conservation over the survivor set — the push-sum /
  path-averaging correction of Benezit et al. specialized to static
  per-step masks).  All mixing strategies here are linear maps with
  row sums 1, so with no failures ``fn(w) == 1`` exactly and the
  division is a bitwise no-op.

The trimming modes exploit that `dist.failures` injects **exactly
counted** fault sets: the number of dropped replicas and the trim width
are static Python ints, so the masked statistics compile to static
slices of one sort — no dynamic shapes under jit.  Dropped rows are
filled with ``-inf`` so the ascending sort parks them below every live
value; slicing then starts above them.

Trimmed mean and median are consensus operators (every live replica
gets the same aggregate), so they replace the strategy's mixing
entirely and are invariant to the rotation permutation; the executors
skip rotation for them.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .failures import SyncFailureModel, fault_counts

__all__ = [
    "AGGREGATIONS",
    "masked_coordinate_median",
    "masked_trimmed_mean",
    "resolve_trim",
    "robust_reduce",
    "survivor_weighted_fn",
    "tree_robust_reduce",
]

AGGREGATIONS = ("mean", "trimmed_mean", "coordinate_median",
                "survivor_weighted")


def resolve_trim(
    failures: Optional[SyncFailureModel], R: int
) -> tuple[int, int]:
    """Static (k_drop, k_trim) for the trimming aggregators.

    k_drop is the exact number of dropped (churned + straggler)
    replicas per step; k_trim defaults to the exact Byzantine count
    (the smallest width that provably brackets every corrupted value),
    or 1 when no model / no Byzantine replicas are declared but at
    least 3 live values remain (cheap outlier insurance, matching the
    usual trimmed-mean default).
    """
    if failures is None:
        kc = ks = kb = 0
    else:
        kc, ks, kb = fault_counts(failures, R)
    k_drop = kc + ks
    live = R - k_drop
    k_trim = kb if kb > 0 else (1 if live >= 3 else 0)
    return k_drop, k_trim


def _sorted_live(x: jax.Array, dropped: jax.Array) -> jax.Array:
    """Sort replicas per coordinate with dropped rows parked at the
    bottom (they become -inf, which sorts below any live value)."""
    neg_inf = jnp.array(-jnp.inf, x.dtype)
    mask = dropped.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.sort(jnp.where(mask, neg_inf, x), axis=0)


def masked_trimmed_mean(
    x: jax.Array, dropped: jax.Array, k_drop: int, k_trim: int
) -> jax.Array:
    """Per-coordinate mean of the live values with the k_trim smallest
    and largest discarded; returns the (1, ...) consensus row."""
    R = x.shape[0]
    if R - k_drop - 2 * k_trim < 1:
        raise ValueError(
            f"trimmed_mean needs at least one value after dropping "
            f"{k_drop} and trimming 2*{k_trim} of {R} replicas")
    s = _sorted_live(x, dropped)
    return jnp.mean(s[k_drop + k_trim: R - k_trim], axis=0, keepdims=True)


def masked_coordinate_median(
    x: jax.Array, dropped: jax.Array, k_drop: int
) -> jax.Array:
    """Per-coordinate median over the live replicas; returns the
    (1, ...) consensus row."""
    R = x.shape[0]
    live = R - k_drop
    if live < 1:
        raise ValueError("coordinate_median needs at least one live replica")
    s = _sorted_live(x, dropped)
    lo = s[k_drop + (live - 1) // 2]
    hi = s[k_drop + live // 2]
    return ((lo + hi) / 2)[None]


def survivor_weighted_fn(
    fn: Callable[[jax.Array], jax.Array], live: jax.Array
) -> Callable[[jax.Array], jax.Array]:
    """Wrap a linear mixing map as its survivor-renormalized version.

    Values travel as ``(w * x, w)`` pairs with ``w = live``; the mixed
    value is ``fn(w * x) / fn(w)`` where the survivor mass ``fn(w)`` is
    clamped away from zero (a replica whose whole in-neighborhood
    dropped divides by ~0 mass; it is dropped-adjacent and gets ~0
    output, then masked to exactly 0 by the caller's live mask).
    """
    def mixed(x: jax.Array) -> jax.Array:
        w = live.astype(x.dtype).reshape((-1,) + (1,) * (x.ndim - 1))
        num = fn(w * x)
        den = fn(jnp.broadcast_to(w, x.shape))
        tiny = jnp.array(jnp.finfo(x.dtype).tiny, x.dtype)
        return num / jnp.maximum(den, tiny)

    return mixed


def robust_reduce(
    aggregation: str,
    x: jax.Array,
    dropped: jax.Array,
    k_drop: int,
    k_trim: int,
) -> jax.Array:
    """Dispatch the consensus-style aggregators on a dense (R, ...)
    leaf, broadcasting the consensus row back to every live replica
    (dropped replicas get zero — no update)."""
    if aggregation == "trimmed_mean":
        agg = masked_trimmed_mean(x, dropped, k_drop, k_trim)
    elif aggregation == "coordinate_median":
        agg = masked_coordinate_median(x, dropped, k_drop)
    else:
        raise ValueError(f"unknown robust reduce {aggregation!r}")
    out = jnp.broadcast_to(agg, x.shape)
    mask = dropped.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(mask, jnp.zeros_like(out), out)


def tree_robust_reduce(
    aggregation: str, tree: Any, dropped: jax.Array, k_drop: int, k_trim: int
) -> Any:
    return jax.tree.map(
        lambda x: robust_reduce(aggregation, x, dropped, k_drop, k_trim), tree
    )
