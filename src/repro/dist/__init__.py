"""repro.dist — decentralized gradient synchronization (the paper's
multiscale gossip applied to data-parallel training replicas).

Public surface:
  SyncConfig / build_sync_plan  static plan resolution (plan/execute split)
  SyncPlan / execute_sync       compiled compress->rotate->mix executor
  async_execute_sync            one-step-delayed (overlapped) pipeline stage
  execute_sync_sharded          the same mix as explicit shard_map collectives
  sync_gradients                one-shot strategy-dispatched mixing
  suggest_levels                the n^(2/3) recursive-partition rule
  rotation_schedule             step-indexed randomized-cell permutations
  compression                   error-feedback gradient compression
  SyncFailureModel              per-step churn/straggler/Byzantine injection
  AGGREGATIONS / robust         fault-tolerant aggregation modes
"""
from .async_sync import async_execute_sync, execute_sync_sharded, init_inflight
from .compression import (
    CompressionConfig, compress, decompress, init_residual, wire_fraction,
)
from .failures import (
    ReplicaFaults, SyncFailureModel, fault_counts, replica_fault_masks,
)
from .gossip_sync import STRATEGIES, SyncConfig, sync_gradients
from .plan import (
    AGGREGATIONS, OVERLAP_MODES, SyncPlan, build_sync_plan, plan_wire_bytes,
    tree_payload_bytes,
)
from .robust import (
    masked_coordinate_median, masked_trimmed_mean, resolve_trim,
    survivor_weighted_fn, tree_robust_reduce,
)
from .gossip_sync import execute_sync
from .topology import (
    complete_matrix, default_rounds, hierarchy_matrix, is_doubly_stochastic,
    ring_matrix, rotation_schedule, suggest_levels,
)

__all__ = [
    "AGGREGATIONS",
    "OVERLAP_MODES",
    "ReplicaFaults",
    "SyncConfig",
    "SyncFailureModel",
    "SyncPlan",
    "fault_counts",
    "masked_coordinate_median",
    "masked_trimmed_mean",
    "replica_fault_masks",
    "resolve_trim",
    "survivor_weighted_fn",
    "tree_robust_reduce",
    "async_execute_sync",
    "build_sync_plan",
    "execute_sync",
    "execute_sync_sharded",
    "init_inflight",
    "plan_wire_bytes",
    "tree_payload_bytes",
    "sync_gradients",
    "STRATEGIES",
    "suggest_levels",
    "rotation_schedule",
    "ring_matrix",
    "complete_matrix",
    "hierarchy_matrix",
    "default_rounds",
    "is_doubly_stochastic",
    "CompressionConfig",
    "compress",
    "decompress",
    "init_residual",
    "wire_fraction",
]
