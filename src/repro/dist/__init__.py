"""repro.dist — decentralized gradient synchronization (the paper's
multiscale gossip applied to data-parallel training replicas).

Public surface:
  SyncConfig / sync_gradients  strategy-dispatched replica-axis mixing
  suggest_levels               the n^(2/3) recursive-partition rule
  compression                  error-feedback gradient compression
"""
from .compression import (
    CompressionConfig, compress, decompress, init_residual, wire_fraction,
)
from .gossip_sync import STRATEGIES, SyncConfig, sync_gradients
from .topology import (
    complete_matrix, default_rounds, hierarchy_matrix, is_doubly_stochastic,
    ring_matrix, suggest_levels,
)

__all__ = [
    "SyncConfig",
    "sync_gradients",
    "STRATEGIES",
    "suggest_levels",
    "ring_matrix",
    "complete_matrix",
    "hierarchy_matrix",
    "default_rounds",
    "is_doubly_stochastic",
    "CompressionConfig",
    "compress",
    "decompress",
    "init_residual",
    "wire_fraction",
]
