"""Gradient synchronization strategies over a replica axis.

The paper's multiscale gossip (Algorithm 1), transplanted from wireless
sensor networks to decentralized data-parallel training: R parameter
replicas hold per-replica gradients (leading axis R on every pytree
leaf) and `sync_gradients` mixes them according to a `SyncConfig`.

Strategies
----------
``allreduce``
    Exact global mean — the dense baseline every byte of which crosses
    the network diameter (one global all-reduce per leaf).
``hierarchical``
    Exact grouped fusion over the `levels` hierarchy: cell means at the
    finest scale, then means-of-means up to the root, broadcast back.
    Bitwise the same fixed point as allreduce, but lowering emits
    grouped collectives whose cross-pod share shrinks to the top-level
    fusion only.
``ring``
    Flat randomized-gossip analogue: `rounds` applications of the
    doubly-stochastic ring operator x <- (x + roll(x,+1) + roll(x,-1))/3
    along the replica axis.  Preserves the replica mean exactly; replica
    disagreement contracts by the ring's second eigenvalue per round
    (the paper's slow baseline — many cheap neighbor exchanges).
``multiscale``
    Algorithm 1 on the replica set.  Bottom-up over the recursive cells
    from `suggest_levels`: ring mixing inside every cell of a level in
    parallel, then promotion of one representative per cell to the next
    coarser level; after the coarsest level mixes, values disseminate
    back down the hierarchy (every replica adopts its top-level cell's
    representative value).  ``exact_fusion=True`` selects the paper's
    mass-weighted variant (§VII) where every fusion is the exact
    weighted cell mean, so the disseminated value is the global replica
    mean exactly; with the uniform occupancy this module enforces it
    evaluates as the hierarchical grouped-mean ladder.

Every strategy is a pure jittable function of the gradient pytree: on a
host-replicated array it is plain arithmetic; under a sharded
``("replica",)`` mesh the same code lowers to real collectives
(all-reduce for fusions, collective-permute for ring rolls), which
`launch.hlo_analysis.collective_bytes` classifies intra- vs cross-pod.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .topology import default_rounds, suggest_levels

__all__ = ["SyncConfig", "sync_gradients", "STRATEGIES"]

STRATEGIES = ("allreduce", "hierarchical", "ring", "multiscale")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """Static (hashable) description of one synchronization strategy.

    levels: branching factors coarsest-first, product == R; () defers to
        `suggest_levels(R)` at call time (ignored by allreduce/ring).
    rounds: per-level mixing rounds.  For `ring` a single entry is the
        number of global ring rounds; for `multiscale` either one entry
        shared by all levels or one per level; () picks
        `default_rounds(cell_size)` per level.
    exact_fusion: multiscale only — mass-weighted exact fusion that
        preserves the replica mean bitwise at every scale.
    """

    strategy: str = "allreduce"
    levels: tuple[int, ...] = ()
    rounds: tuple[int, ...] = ()
    exact_fusion: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; expected one of {STRATEGIES}"
            )
        object.__setattr__(self, "levels", tuple(int(l) for l in self.levels))
        object.__setattr__(self, "rounds", tuple(int(r) for r in self.rounds))
        if any(l < 1 for l in self.levels):
            raise ValueError(f"levels must be positive, got {self.levels}")
        if any(r < 0 for r in self.rounds):
            raise ValueError(f"rounds must be >= 0, got {self.rounds}")

    def resolved_levels(self, R: int) -> tuple[int, ...]:
        levels = self.levels or suggest_levels(R)
        prod = 1
        for l in levels:
            prod *= l
        if prod != R:
            raise ValueError(
                f"levels {levels} factor {prod} replicas but R={R}"
            )
        return levels

    def resolved_rounds(self, levels: tuple[int, ...]) -> tuple[int, ...]:
        if not self.rounds:
            return tuple(default_rounds(l) for l in levels)
        if len(self.rounds) == 1:
            return self.rounds * len(levels)
        if len(self.rounds) != len(levels):
            raise ValueError(
                f"rounds {self.rounds} does not match levels {levels}"
            )
        return self.rounds


def sync_gradients(grads: Any, cfg: SyncConfig, R: int) -> Any:
    """Mix a per-replica gradient pytree (leading axis R on every leaf).

    Returns a pytree of the same structure/shapes.  Exact strategies
    leave every replica holding the global mean; gossip strategies bound
    the replica disagreement by the configured mixing rounds (the
    paper's eps) while staying inside the convex hull of the inputs.
    """
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    leaves = jax.tree.leaves(grads)
    for leaf in leaves:
        if leaf.ndim < 1 or leaf.shape[0] != R:
            raise ValueError(
                f"every gradient leaf needs leading replica axis {R}, "
                f"got shape {leaf.shape}"
            )
    if R == 1:
        return grads

    if cfg.strategy == "allreduce":
        fn = lambda g: _allreduce(g)
    elif cfg.strategy == "hierarchical":
        levels = cfg.resolved_levels(R)
        fn = lambda g: _hierarchical(g, levels)
    elif cfg.strategy == "ring":
        rounds = cfg.rounds[0] if cfg.rounds else 2 * R
        fn = lambda g: _ring(g, rounds)
    else:  # multiscale
        levels = cfg.resolved_levels(R)
        rounds = cfg.resolved_rounds(levels)
        fn = lambda g: _multiscale(g, levels, rounds, cfg.exact_fusion)
    return jax.tree.map(fn, grads)


# ------------------------------ strategies ------------------------------


def _allreduce(g: jax.Array) -> jax.Array:
    """Global mean over the replica axis, broadcast back to every replica."""
    return jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape)


def _hierarchical(g: jax.Array, levels: tuple[int, ...]) -> jax.Array:
    """Grouped means finest-to-coarsest then broadcast back down.

    With uniform cell sizes (levels factor R exactly) the mean-of-means
    equals the global mean, so the result matches allreduce to float
    accuracy while lowering as a ladder of small-group collectives.
    """
    shape = g.shape
    x = g.reshape(levels + shape[1:])
    for ax in range(len(levels) - 1, -1, -1):
        x = jnp.mean(x, axis=ax, keepdims=True)
    return jnp.broadcast_to(x, levels + shape[1:]).reshape(shape)


def _ring_round(x: jax.Array) -> jax.Array:
    """One application of the doubly-stochastic ring operator on axis 0."""
    return (x + jnp.roll(x, 1, axis=0) + jnp.roll(x, -1, axis=0)) / 3.0


def _ring(g: jax.Array, rounds: int) -> jax.Array:
    """Flat neighbor gossip: `rounds` synchronized ring exchanges.

    Symmetric + doubly stochastic => the replica mean is invariant and
    disagreement contracts geometrically.  Under a replica-sharded mesh
    each roll is one collective-permute, so the lowered module makes the
    paper's point: flat gossip is chatty."""
    return lax.fori_loop(0, rounds, lambda _, x: _ring_round(x), g)


def _mix_level(x: jax.Array, axis: int, rounds: int) -> jax.Array:
    """Ring-mix all cells of one level in parallel along `axis`."""
    if x.shape[axis] == 1:
        return x
    moved = jnp.moveaxis(x, axis, 0)
    mixed = lax.fori_loop(0, rounds, lambda _, v: _ring_round(v), moved)
    return jnp.moveaxis(mixed, 0, axis)


def _multiscale(
    g: jax.Array,
    levels: tuple[int, ...],
    rounds: tuple[int, ...],
    exact_fusion: bool,
) -> jax.Array:
    """Algorithm 1 over the replica hierarchy.

    Axis layout after reshape: axis j hosts level-(j+1) cells; the last
    axis is the finest scale.  Bottom-up pass mixes within cells then
    promotes one representative per cell; top-level values disseminate
    back down by broadcast (the paper's n-message down-pass).
    """
    shape = g.shape
    payload = shape[1:]
    k = len(levels)
    if exact_fusion:
        # Mass-weighted variant: values travel as (w*x, w) pairs and every
        # fusion is the exact weighted cell mean.  resolved_levels enforces
        # uniform occupancy (prod(levels) == R), under which the weighted
        # fusion is identically the grouped-mean ladder — delegate rather
        # than carry a uniform weight channel; revisit when cells can be
        # partially occupied (time-varying replica topologies).
        return _hierarchical(g, levels)

    x = g.reshape(levels + payload)

    # Plain Algorithm 1: per-cell ring gossip, representative promotion.
    for ax in range(k - 1, 0, -1):
        x = _mix_level(x, ax, rounds[ax])
        # representative = cell member 0 after mixing (approx. cell mean)
        x = lax.index_in_dim(x, 0, axis=ax, keepdims=True)
    # coarsest level: representatives gossip on the top ring
    x = _mix_level(x, 0, rounds[0])
    # down-pass: every replica adopts its top-level cell's value
    return jnp.broadcast_to(x, levels + payload).reshape(shape)
