"""Gradient synchronization strategies over a replica axis.

The paper's multiscale gossip (Algorithm 1), transplanted from wireless
sensor networks to decentralized data-parallel training: R parameter
replicas hold per-replica gradients (leading axis R on every pytree
leaf) mixed according to a static `SyncPlan` (`dist/plan.py`).

Plan/execute split (mirror of `core/plan.py` / `core/engine.py`): the
hierarchy, rounds, rotation schedule, and compression config are
resolved once by `build_sync_plan(SyncConfig, R)`; the compiled
`execute_sync(plan, grads, residuals, step)` then threads
compress -> rotate -> mix -> scatter-back with per-replica
error-feedback residuals through every strategy, and is the single
seam future async / shard_map overlap plugs into.  `sync_gradients` is
the one-shot convenience wrapper (no residual state across calls).

Strategies
----------
``allreduce``
    Exact global mean — the dense baseline every byte of which crosses
    the network diameter (one global all-reduce per leaf).
``hierarchical``
    Exact grouped fusion over the `levels` hierarchy: cell means at the
    finest scale, then means-of-means up to the root, broadcast back.
    Bitwise the same fixed point as allreduce, but lowering emits
    grouped collectives whose cross-pod share shrinks to the top-level
    fusion only.
``ring``
    Flat randomized-gossip analogue: `rounds` applications of the
    doubly-stochastic ring operator x <- (x + roll(x,+1) + roll(x,-1))/3
    along the replica axis.  Preserves the replica mean exactly; replica
    disagreement contracts by the ring's second eigenvalue per round
    (the paper's slow baseline — many cheap neighbor exchanges).
``multiscale``
    Algorithm 1 on the replica set.  Bottom-up over the recursive cells
    from `suggest_levels`: ring mixing inside every cell of a level in
    parallel, then promotion of one representative per cell to the next
    coarser level; after the coarsest level mixes, values disseminate
    back down the hierarchy (every replica adopts its top-level cell's
    representative value).  ``exact_fusion=True`` selects the paper's
    mass-weighted variant (§VII) where every fusion is the exact
    weighted cell mean, so the disseminated value is the global replica
    mean exactly; with the uniform occupancy this module enforces it
    evaluates as the hierarchical grouped-mean ladder.

Cross-cutting plan features (gossip strategies):

* **rotation** — `rotation_period > 0` cycles a precomputed table of
  replica permutations by sync step (the paper's randomized cells), so
  ring neighbors / cell membership change every step.  Conjugating a
  doubly-stochastic mix by a permutation is still doubly stochastic:
  the replica mean is untouched and exact_fusion stays exact.
* **compression** — a non-``none`` `CompressionConfig` mixes the
  as-transmitted payloads from `dist.compression` (error feedback:
  unsent mass stays in per-replica residuals and is re-injected next
  sync), so gossip competes on wire *bytes*, not just message counts.

Every strategy is a pure jittable function of the gradient pytree: on a
host-replicated array it is plain arithmetic; under a sharded
``("replica",)`` mesh the same code lowers to real collectives
(all-reduce for fusions, collective-permute for ring rolls), which
`launch.hlo_analysis.collective_bytes` classifies intra- vs cross-pod.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .compression import compress, decompress, init_residual
from .failures import apply_payload_faults, replica_fault_masks
from .plan import STRATEGIES, SyncConfig, SyncPlan, build_sync_plan
from .robust import resolve_trim, survivor_weighted_fn, tree_robust_reduce

__all__ = [
    "SyncConfig",
    "SyncPlan",
    "build_sync_plan",
    "execute_sync",
    "sync_gradients",
    "STRATEGIES",
]


def execute_sync(
    plan: SyncPlan,
    grads: Any,
    residuals: Optional[Any] = None,
    step: Any = 0,
) -> tuple[Any, Any]:
    """Run one synchronization under a static plan.

    grads: pytree with leading replica axis `plan.R` on every leaf.
    residuals: error-feedback state (same pytree; required state when
        `plan.compression` is active — pass what the previous call
        returned, zeros via `compression.init_residual` at step 0).
        With compression off it is threaded through untouched.
    step: scalar sync index (traced or concrete) driving the rotation
        schedule; ignored by static plans.

    Returns (mixed_grads, new_residuals).  Jit with `plan` static (it is
    hashable); the compiled executor serves every step of a run.
    """
    R = plan.R
    leaves = jax.tree.leaves(grads)
    for leaf in leaves:
        if leaf.ndim < 1 or leaf.shape[0] != R:
            raise ValueError(
                f"every gradient leaf needs leading replica axis {R}, "
                f"got shape {leaf.shape}"
            )
    if R == 1:
        return grads, residuals

    if plan.compression.scheme != "none":
        if residuals is None:
            residuals = init_residual(grads)
        payload, new_residuals = compress(grads, residuals, plan.compression)
        payload = decompress(payload, plan.compression)
    else:
        payload, new_residuals = grads, residuals

    # Fault injection: dropped replicas transmit nothing (with EF
    # compression their whole accumulator stays in their residual —
    # bitwise mass conservation), Byzantine replicas transmit corrupted
    # payloads.  plan.failures is None (or inert) on the reliable path,
    # which stays bitwise-identical to a plan without the field.
    faults = None
    if plan.faulty:
        faults = replica_fault_masks(plan.failures, R, step)
        if plan.compression.scheme != "none":
            payload, new_residuals = apply_payload_faults(
                payload, new_residuals, grads, residuals,
                faults.dropped, faults.byzantine,
                plan.failures.byzantine_scale,
            )
        else:
            payload, _ = apply_payload_faults(
                payload, None, None, None,
                faults.dropped, faults.byzantine,
                plan.failures.byzantine_scale,
            )

    if plan.robust_consensus:
        # Consensus-style robust reduction replaces the strategy's own
        # mixing (and is invariant to the rotation permutation).
        k_drop, k_trim = resolve_trim(plan.failures, R)
        dropped = (
            faults.dropped if faults is not None
            else jnp.zeros((R,), bool)
        )
        mixed = tree_robust_reduce(
            plan.aggregation, payload, dropped, k_drop, k_trim
        )
        return mixed, new_residuals

    if plan.strategy == "allreduce":
        fn = _allreduce
    elif plan.strategy == "hierarchical":
        fn = lambda g: _hierarchical(g, plan.levels)
    elif plan.strategy == "ring":
        fn = lambda g: _ring(g, plan.rounds[0])
    else:  # multiscale
        fn = lambda g: _multiscale(
            g, plan.levels, plan.rounds, plan.exact_fusion
        )
    if plan.rotated:
        fn = _rotate(fn, plan, step)
    if faults is not None and plan.aggregation == "survivor_weighted":
        # weight-channel renormalization over live replicas, applied to
        # the (possibly rotation-conjugated) linear mixing operator
        fn = survivor_weighted_fn(fn, faults.live)
    mixed = jax.tree.map(fn, payload)
    if faults is not None:
        live = faults.live
        mixed = jax.tree.map(
            lambda m: jnp.where(
                live.reshape((R,) + (1,) * (m.ndim - 1)),
                m, jnp.zeros_like(m),
            ),
            mixed,
        )
    return mixed, new_residuals


def sync_gradients(grads: Any, cfg: SyncConfig, R: int) -> Any:
    """One-shot mix of a per-replica gradient pytree (leading axis R).

    Convenience wrapper over `build_sync_plan` + `execute_sync` for call
    sites without persistent state: residuals start at zero and the new
    residuals are dropped, so error-feedback compression only
    accumulates across calls when you hold the state yourself (the
    decentralized train step does).  Returns a pytree of the same
    structure/shapes.  Exact strategies leave every replica holding the
    global mean; gossip strategies bound the replica disagreement by
    the configured mixing rounds (the paper's eps) while staying inside
    the convex hull of the inputs.
    """
    mixed, _ = execute_sync(build_sync_plan(cfg, R), grads)
    return mixed


# ------------------------------ strategies ------------------------------


def _rotate(fn, plan: SyncPlan, step) -> Any:
    """Conjugate a mixing operator by the step's rotation permutation.

    Slot s of the mixed array holds replica perm[s]; the inverse table
    scatters slot values back to their home replicas, so the wrapped
    operator acts on a freshly shuffled cell assignment every step while
    output replica order stays fixed.
    """
    perms = jnp.asarray(plan.rotation, jnp.int32)
    invs = jnp.asarray(plan.rotation_inv, jnp.int32)
    idx = jnp.mod(jnp.asarray(step, jnp.int32), perms.shape[0])
    perm, inv = perms[idx], invs[idx]
    return lambda g: jnp.take(fn(jnp.take(g, perm, axis=0)), inv, axis=0)


def _allreduce(g: jax.Array) -> jax.Array:
    """Global mean over the replica axis, broadcast back to every replica."""
    return jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape)


def _hierarchical(g: jax.Array, levels: tuple[int, ...]) -> jax.Array:
    """Grouped means finest-to-coarsest then broadcast back down.

    With uniform cell sizes (levels factor R exactly) the mean-of-means
    equals the global mean, so the result matches allreduce to float
    accuracy while lowering as a ladder of small-group collectives.
    """
    shape = g.shape
    x = g.reshape(levels + shape[1:])
    for ax in range(len(levels) - 1, -1, -1):
        x = jnp.mean(x, axis=ax, keepdims=True)
    return jnp.broadcast_to(x, levels + shape[1:]).reshape(shape)


def _ring_round(x: jax.Array) -> jax.Array:
    """One application of the doubly-stochastic ring operator on axis 0."""
    return (x + jnp.roll(x, 1, axis=0) + jnp.roll(x, -1, axis=0)) / 3.0


def _ring(g: jax.Array, rounds: int) -> jax.Array:
    """Flat neighbor gossip: `rounds` synchronized ring exchanges.

    Symmetric + doubly stochastic => the replica mean is invariant and
    disagreement contracts geometrically.  Under a replica-sharded mesh
    each roll is one collective-permute, so the lowered module makes the
    paper's point: flat gossip is chatty."""
    return lax.fori_loop(0, rounds, lambda _, x: _ring_round(x), g)


def _mix_level(x: jax.Array, axis: int, rounds: int) -> jax.Array:
    """Ring-mix all cells of one level in parallel along `axis`."""
    if x.shape[axis] == 1:
        return x
    moved = jnp.moveaxis(x, axis, 0)
    mixed = lax.fori_loop(0, rounds, lambda _, v: _ring_round(v), moved)
    return jnp.moveaxis(mixed, 0, axis)


def _multiscale(
    g: jax.Array,
    levels: tuple[int, ...],
    rounds: tuple[int, ...],
    exact_fusion: bool,
) -> jax.Array:
    """Algorithm 1 over the replica hierarchy.

    Axis layout after reshape: axis j hosts level-(j+1) cells; the last
    axis is the finest scale.  Bottom-up pass mixes within cells then
    promotes one representative per cell; top-level values disseminate
    back down by broadcast (the paper's n-message down-pass).
    """
    shape = g.shape
    payload = shape[1:]
    k = len(levels)
    if exact_fusion:
        # Mass-weighted variant: values travel as (w*x, w) pairs and every
        # fusion is the exact weighted cell mean.  resolved_levels enforces
        # uniform occupancy (prod(levels) == R), under which the weighted
        # fusion is identically the grouped-mean ladder — delegate rather
        # than carry a uniform weight channel; revisit when cells can be
        # partially occupied (time-varying replica topologies).
        return _hierarchical(g, levels)

    x = g.reshape(levels + payload)

    # Plain Algorithm 1: per-cell ring gossip, representative promotion.
    for ax in range(k - 1, 0, -1):
        x = _mix_level(x, ax, rounds[ax])
        # representative = cell member 0 after mixing (approx. cell mean)
        x = lax.index_in_dim(x, 0, axis=ax, keepdims=True)
    # coarsest level: representatives gossip on the top ring
    x = _mix_level(x, 0, rounds[0])
    # down-pass: every replica adopts its top-level cell's value
    return jnp.broadcast_to(x, levels + payload).reshape(shape)
