"""Replica-failure injection for decentralized gradient sync.

The training-stack twin of `core.medium.FailureModel`: the paper prices
multiscale gossip on an unreliable wireless medium where packets drop
mid-exchange; in decentralized training the analogous event is a
*replica* that disappears mid-sync — preempted, partitioned, or slow
enough to miss the round — or one that ships a corrupted gradient.
`SyncFailureModel` is the static, hashable description of that surface;
it rides `SyncConfig` → `SyncPlan` like every other sync knob, so one
compiled executor serves the whole (possibly failing) run.

Per-step fault sets are drawn deterministically from ``(seed, step)``
with **exact disjoint counts** (one permutation per step, sliced into
churned / straggler / Byzantine ranks).  Exactness matters twice: the
set sizes are static, which is what lets the robust aggregators in
`dist.robust` trim with static shapes under jit, and the same
``(seed, step)`` pair reproduces the same faults in the dense,
overlapped, and shard_map executors (the dense-vs-sharded parity tests
rely on it).

Semantics per sync step:

* **churned / straggler replicas** are absent: their payload does not
  travel and they receive nothing (their mixed gradient is zero — the
  step applies no update to them).  The two families act identically on
  a single sync; they are distinguished so scenario matrices can name
  them (churn models a replica that is *gone*, stragglers one that is
  merely late and rejoins next step).  With error-feedback compression
  on, a dropped replica's whole accumulator ``grads + residual`` stays
  in its residual — bitwise, nothing is lost — and re-enters the mix
  when it rejoins: that is the EF-residual recovery story.
* **Byzantine replicas** stay in the round but transmit an adversarial
  payload (sign-flipped and scaled by ``byzantine_scale``); defending
  against it is the job of the robust aggregation modes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ReplicaFaults",
    "SyncFailureModel",
    "apply_payload_faults",
    "fault_counts",
    "replica_fault_masks",
]


@dataclasses.dataclass(frozen=True)
class SyncFailureModel:
    """Static (hashable) per-step replica fault injection.

    churn_fraction: fraction of replicas absent from each sync step
        (gone: no payload sent, none received).
    straggler_fraction: fraction of replicas that miss the sync round
        (late: same per-step effect as churn, named separately for
        scenario matrices).
    byzantine_fraction: fraction of replicas transmitting an
        adversarial payload (sign-flipped, scaled).
    byzantine_scale: magnitude of the corruption; the transmitted
        payload is ``-byzantine_scale * honest_payload``.
    seed: fault-injection RNG seed — per-step sets are deterministic in
        ``(seed, step)`` and independent of the gossip/rotation seeds.

    The three sets are disjoint by construction and exactly sized
    (``round(fraction * R)`` replicas each), so the counts are static
    under jit.  `build_sync_plan` validates that at least one honest
    replica survives.
    """

    churn_fraction: float = 0.0
    straggler_fraction: float = 0.0
    byzantine_fraction: float = 0.0
    byzantine_scale: float = 10.0
    seed: int = 0

    def __post_init__(self):
        for name in ("churn_fraction", "straggler_fraction",
                     "byzantine_fraction"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v}")
        if self.byzantine_scale < 0:
            raise ValueError(
                f"byzantine_scale must be >= 0, got {self.byzantine_scale}")

    @property
    def active(self) -> bool:
        """True when any fault family injects at least a nonzero rate."""
        return (
            self.churn_fraction > 0
            or self.straggler_fraction > 0
            or self.byzantine_fraction > 0
        )


class ReplicaFaults(NamedTuple):
    """Per-step (R,) boolean fault masks; `dropped` = churned|straggler,
    `live` is its complement (Byzantine replicas are live)."""

    churned: jax.Array
    straggler: jax.Array
    byzantine: jax.Array
    dropped: jax.Array
    live: jax.Array


def fault_counts(model: SyncFailureModel, R: int) -> tuple[int, int, int]:
    """Static (k_churn, k_straggler, k_byzantine) set sizes for R
    replicas — `round(fraction * R)` each, matching
    `core.medium.failure_sets`' count convention."""
    return (
        int(round(model.churn_fraction * R)),
        int(round(model.straggler_fraction * R)),
        int(round(model.byzantine_fraction * R)),
    )


def replica_fault_masks(
    model: SyncFailureModel, R: int, step: Any
) -> ReplicaFaults:
    """Draw the step's fault sets (jittable, deterministic in
    ``(model.seed, step)``).

    One replica permutation is drawn per step; ranks ``[0, kc)`` churn,
    ``[kc, kc+ks)`` straggle, ``[kc+ks, kc+ks+kb)`` turn Byzantine.
    Disjoint, exactly sized, and the same arrays on every program of a
    shard_map body (all inputs are replicated).
    """
    kc, ks, kb = fault_counts(model, R)
    key = jax.random.fold_in(
        jax.random.PRNGKey(model.seed), jnp.asarray(step, jnp.int32)
    )
    perm = jax.random.permutation(key, R)
    # rank[i] = position of replica i in the permutation
    rank = jnp.zeros(R, jnp.int32).at[perm].set(jnp.arange(R, dtype=jnp.int32))
    churned = rank < kc
    straggler = (rank >= kc) & (rank < kc + ks)
    byzantine = (rank >= kc + ks) & (rank < kc + ks + kb)
    dropped = churned | straggler
    return ReplicaFaults(
        churned=churned, straggler=straggler, byzantine=byzantine,
        dropped=dropped, live=~dropped,
    )


def _bcast(mask: jax.Array, leaf: jax.Array) -> jax.Array:
    """Right-pad a replica mask with singleton axes to broadcast over a
    gradient leaf (works for the dense (R,) mask and the shard_map
    per-program scalar alike)."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - mask.ndim))


def apply_payload_faults(
    payload: Any,
    new_residuals: Optional[Any],
    grads: Any,
    residuals: Optional[Any],
    dropped: jax.Array,
    byzantine: jax.Array,
    byzantine_scale: float,
) -> tuple[Any, Optional[Any]]:
    """Inject the step's faults into the as-transmitted payload.

    Dropped replicas transmit nothing: their payload rows become zero
    and — when error-feedback residuals are carried — their residual
    becomes the full accumulator ``grads + residuals`` (computed
    directly, so ``payload + residual == grads + residuals`` holds
    BITWISE for dropped rows exactly as `dist.compression.compress`
    guarantees it for live ones: zero payload, exact-copy residual).
    Byzantine replicas then overwrite their (live) rows with the
    sign-flipped scaled payload; their own residual bookkeeping is left
    untouched — an adversary's ledger is its own problem, and the
    conservation invariant is only ever claimed for honest replicas.

    `dropped` / `byzantine` may be (R,) masks (dense executor) or
    per-program scalars (shard_map body).
    """
    payload = jax.tree.map(
        lambda p: jnp.where(_bcast(dropped, p), jnp.zeros_like(p), p), payload
    )
    if new_residuals is not None:
        new_residuals = jax.tree.map(
            lambda nr, g, r: jnp.where(_bcast(dropped, nr), g + r, nr),
            new_residuals, grads, residuals,
        )
    scale = jnp.float32(byzantine_scale)
    payload = jax.tree.map(
        lambda p: jnp.where(
            _bcast(byzantine, p), (-scale).astype(p.dtype) * p, p
        ),
        payload,
    )
    return payload, new_residuals
