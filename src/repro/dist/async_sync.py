"""Asynchronous (overlapped) gradient synchronization.

The paper's analysis rests on an asynchronous time model: nodes gossip
without a global clock, and because most messages travel short
distances, communication proceeds concurrently with local work.  The
training-stack transplant of that idea is **one-step-delayed gradient
averaging** (`SyncConfig(overlap="one_step")`): step `t` applies the
*previous* step's mixed gradients while step `t`'s fresh gradients are
handed to the gossip executor — the mix has **no data dependency on the
current backward pass**, so the compiler is free to schedule the sync
collectives concurrently with backward compute.  The train state
carries a double-buffered `prev_grads` pytree (the in-flight
gradients); the error-feedback residual buffer rides along exactly as
in the serialized path, just one step late, so EF accounting stays
bitwise-conserving (`payload + residual` still reconstructs the
accumulator — see `dist.compression`).

Staleness correction: the delayed gradients are mixed under the
rotation index and applied under the learning rate of the step that
*produced* them (`step - 1`), so the overlapped trajectory is exactly
the serialized trajectory delayed by one step whenever the gradient
stream itself is step-independent — that is the equivalence contract
`tests/test_async_sync.py` pins down.  Warmup: at step 0 there is no
delayed gradient yet; the buffer starts at zeros, the mix is a no-op,
and the train step discards the (zero) update.

Two executors:

`async_execute_sync(plan, grads, prev_grads, residuals, step)`
    The functional pipeline stage: mixes `prev_grads` (rotation index
    `step - 1`), returns the mixed result, the new in-flight buffer
    (= `grads`), and the updated residuals.

`execute_sync_sharded(plan, grads, residuals, step, mesh=...)`
    The same mixing semantics expressed as explicit per-replica
    collectives under `jax.experimental.shard_map`: the replica axis is
    laid out over a mesh shaped like `plan.levels`, per-cell ring
    gossip is `ppermute` along one mesh axis, grouped fusion is `pmean`
    along one mesh axis, and dissemination is a masked-`psum`
    broadcast.  Unlike the GSPMD lowering of the dense executor, the
    collectives here are scheduling-explicit, which is what lets XLA
    interleave them with an independent backward dataflow branch.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .compression import compress, decompress, init_residual
from .failures import apply_payload_faults, replica_fault_masks
from .gossip_sync import execute_sync
from .plan import SyncPlan
from .robust import (
    masked_coordinate_median,
    masked_trimmed_mean,
    resolve_trim,
    survivor_weighted_fn,
)

__all__ = [
    "async_execute_sync",
    "execute_sync_sharded",
    "init_inflight",
]


def init_inflight(grads_like: Any) -> Any:
    """Zero in-flight gradient buffer (the second half of the double
    buffer) matching the gradient pytree."""
    return jax.tree.map(jnp.zeros_like, grads_like)


def async_execute_sync(
    plan: SyncPlan,
    grads: Any,
    prev_grads: Any,
    residuals: Optional[Any] = None,
    step: Any = 0,
    *,
    mesh: Optional[Mesh] = None,
    axis_name: str = "replica",
) -> tuple[Any, Any, Any]:
    """One stage of the overlapped sync pipeline.

    grads: the current step's fresh (clipped) gradients — NOT mixed yet;
        they become the new in-flight buffer.
    prev_grads: the previous step's gradients (in flight since last
        step; zeros at step 0).
    residuals / step: threaded to `execute_sync` as usual; the rotation
        schedule is indexed at `step - 1`, the sync index of the step
        that produced `prev_grads`.
    mesh: when given, the mix runs through `execute_sync_sharded` so the
        gossip lowers as explicit shard_map collectives.

    Returns (applied, new_prev_grads, new_residuals) where `applied` is
    `mix(prev_grads)` and `new_prev_grads` is `grads`.  `applied` has no
    data dependency on `grads`, which is the whole point: under jit the
    current backward and the previous step's gossip are independent
    dataflow branches.
    """
    sync_step = jnp.asarray(step, jnp.int32) - 1
    if mesh is not None:
        applied, new_residuals = execute_sync_sharded(
            plan, prev_grads, residuals, sync_step,
            mesh=mesh, axis_name=axis_name,
        )
    else:
        applied, new_residuals = execute_sync(
            plan, prev_grads, residuals, sync_step
        )
    return applied, grads, new_residuals


# ------------------------- shard_map executor -------------------------
#
# Axis layout: the replica axis is reshaped over a mesh of shape
# `plan.levels` (one named axis per hierarchy level, coarsest first), so
# level-l cells are exactly the programs sharing all mesh coordinates
# except axis l.  Gossip strategies then read as:
#   ring within a cell  -> ppermute +-1 along that level's axis
#   grouped fusion      -> pmean along that level's axis
#   dissemination       -> masked psum along the finer axes
# Flat strategies (allreduce / ring) use a single-axis mesh.

_AXIS_FMT = "gossip{}"


def _level_mesh(plan: SyncPlan, mesh: Mesh, axis_name: str) -> tuple[Mesh, tuple[str, ...]]:
    """Reshape the caller's replica axis into one mesh axis per level."""
    if axis_name not in mesh.shape:
        raise ValueError(
            f"mesh {mesh.shape} has no axis {axis_name!r} to shard replicas over"
        )
    if mesh.shape[axis_name] != plan.R:
        raise ValueError(
            f"mesh axis {axis_name!r} has {mesh.shape[axis_name]} devices but "
            f"the plan serves R={plan.R} replicas"
        )
    if len(mesh.shape) != 1:
        raise ValueError(
            f"execute_sync_sharded wants a dedicated 1-axis replica mesh, "
            f"got {dict(mesh.shape)}"
        )
    shape = plan.levels if plan.strategy in ("hierarchical", "multiscale") \
        else (plan.R,)
    names = tuple(_AXIS_FMT.format(i) for i in range(len(shape)))
    return Mesh(mesh.devices.reshape(shape), names), names


def _ring_pairs(L: int, shift: int) -> list[tuple[int, int]]:
    """(src, dst) collective-permute pairs of a ring shift along one axis."""
    return [((i + shift) % L, i) for i in range(L)]


def _shard_ring_round(x, axis: str, L: int):
    """One doubly-stochastic ring round along a mesh axis — the
    collective form of gossip_sync._ring_round (same value order, so the
    result matches the dense roll-based round to f32 accuracy)."""
    up = lax.ppermute(x, axis, _ring_pairs(L, 1))
    dn = lax.ppermute(x, axis, _ring_pairs(L, -1))
    return (x + up + dn) / 3.0


def _shard_mix_axis(x, axis: str, L: int, rounds: int):
    if L == 1:
        return x
    return lax.fori_loop(
        0, rounds, lambda _, v: _shard_ring_round(v, axis, L), x
    )


def _shard_bcast_from_zero(x, axis: str):
    """Every program along `axis` adopts the value at index 0 (the
    representative slot) — dissemination as a masked-psum broadcast."""
    keep = (lax.axis_index(axis) == 0).astype(x.dtype)
    return lax.psum(x * keep, axis)


def _shard_strategy(plan: SyncPlan, names: tuple[str, ...]):
    """Per-program mixing body for one leaf (local shape (1, *payload))."""
    levels = plan.levels

    if plan.strategy == "allreduce":
        return lambda x: lax.pmean(x, names)

    if plan.strategy == "hierarchical" or (
        plan.strategy == "multiscale" and plan.exact_fusion
    ):
        # grouped-mean ladder: cell means at the finest scale, then
        # means-of-means up — uniform occupancy makes each pmean over a
        # coarser axis exactly the fusion of that level's cell means
        def ladder(x):
            for ax in reversed(names):
                x = lax.pmean(x, ax)
            return x
        return ladder

    if plan.strategy == "ring":
        return lambda x: _shard_mix_axis(x, names[0], plan.R, plan.rounds[0])

    # plain multiscale (Algorithm 1): per-cell ring gossip bottom-up;
    # programs whose finer coordinates are nonzero compute dead values
    # past their own level — dissemination overwrites every slot from
    # the representative plane, so no masking is needed
    def multiscale(x):
        for ax in range(len(levels) - 1, 0, -1):
            x = _shard_mix_axis(x, names[ax], levels[ax], plan.rounds[ax])
            # promotion is positional: the representative (cell member 0)
            # already lives on the axis-index-0 plane
        x = _shard_mix_axis(x, names[0], levels[0], plan.rounds[0])
        # down-pass: broadcast the representative value along the finer
        # axes in coarse-to-fine order (each pass extends the set of
        # coordinates holding their top-level cell's value)
        for ax in names[1:]:
            x = _shard_bcast_from_zero(x, ax)
        return x

    return multiscale


def _shard_rotate(fn, plan: SyncPlan, names: tuple[str, ...], step):
    """Rotation conjugation in collective form: route each program's
    value to its rotated slot, mix, route back.  `jnp.take(g, perm)` of
    the dense executor (slot s reads replica perm[s]) becomes ppermute
    pairs (perm[s] -> s); the scatter-back inverts them.  The step index
    picks the branch via lax.switch (ppermute pairs must be static)."""
    def branch(perm):
        fwd = [(int(perm[s]), s) for s in range(plan.R)]
        bwd = [(s, int(perm[s])) for s in range(plan.R)]
        def run(x):
            x = lax.ppermute(x, names, fwd)
            x = fn(x)
            return lax.ppermute(x, names, bwd)
        return run

    branches = [branch(p) for p in plan.rotation]
    idx = jnp.mod(jnp.asarray(step, jnp.int32), len(branches))
    return lambda x: lax.switch(idx, branches, x)


def execute_sync_sharded(
    plan: SyncPlan,
    grads: Any,
    residuals: Optional[Any] = None,
    step: Any = 0,
    *,
    mesh: Mesh,
    axis_name: str = "replica",
) -> tuple[Any, Any]:
    """`execute_sync` semantics as explicit shard_map collectives.

    grads: pytree with leading replica axis `plan.R`, sharded (or
        shardable) over `mesh`'s `axis_name`.  Compression happens
        per-program (each replica compresses its own row, exactly the
        per-replica semantics of the dense path); the mix lowers to
        ppermute / pmean / psum along the level axes.

    Returns (mixed_grads, new_residuals) like `execute_sync`.  Values
    match the dense executor to f32 accuracy (identical exchange
    sequences; fusion reductions may reassociate).
    """
    if plan.R == 1:
        return grads, residuals
    inner, names = _level_mesh(plan, mesh, axis_name)

    mix = _shard_strategy(plan, names)
    compressed = plan.compression.scheme != "none"
    if compressed and residuals is None:
        residuals = init_residual(grads)

    faulty = plan.faulty
    robust = plan.robust_consensus
    if robust:
        k_drop, k_trim = resolve_trim(plan.failures, plan.R)
    shape = tuple(inner.shape[n] for n in names)

    def _program_rid():
        # dense replica index of this program: the replica axis was
        # reshaped over `shape` row-major, so rid is the row-major
        # linearization of the program's mesh coordinates
        rid = jnp.int32(0)
        stride = 1
        for name, L in zip(reversed(names), reversed(shape)):
            rid = rid + lax.axis_index(name) * stride
            stride *= L
        return rid

    def _mix_body(payload, g, r, new_r, s):
        """Shared fault-injection + aggregation tail of both bodies.

        The fault masks are recomputed identically on every program
        (all inputs replicated, same (seed, step) fold as the dense
        executor), then indexed at this program's replica id — so the
        injected faults match the dense path bitwise for the same seed.
        """
        if faulty:
            faults = replica_fault_masks(plan.failures, plan.R, s)
            rid = _program_rid()
            dropped_i = faults.dropped[rid]
            byz_i = faults.byzantine[rid]
            live_i = faults.live[rid]
            if compressed:
                payload, new_r = apply_payload_faults(
                    payload, new_r, g, r, dropped_i, byz_i,
                    plan.failures.byzantine_scale,
                )
            else:
                payload, _ = apply_payload_faults(
                    payload, None, None, None, dropped_i, byz_i,
                    plan.failures.byzantine_scale,
                )

        if robust:
            dropped_full = (
                faults.dropped if faulty else jnp.zeros((plan.R,), bool)
            )

            def robust_fn(x):
                # gather the whole replica axis (names-order row-major
                # matches the dense replica ordering), reduce once —
                # the aggregate is a consensus value, identical on
                # every program
                full = lax.all_gather(x, names, axis=0, tiled=True)
                if plan.aggregation == "trimmed_mean":
                    agg = masked_trimmed_mean(
                        full, dropped_full, k_drop, k_trim
                    )
                else:
                    agg = masked_coordinate_median(full, dropped_full, k_drop)
                if faulty:
                    agg = jnp.where(dropped_i, jnp.zeros_like(agg), agg)
                return agg

            return jax.tree.map(robust_fn, payload), new_r

        fn = _shard_rotate(mix, plan, names, s) if plan.rotated else mix
        if faulty and plan.aggregation == "survivor_weighted":
            fn = survivor_weighted_fn(fn, live_i)
        out = jax.tree.map(fn, payload)
        if faulty:
            out = jax.tree.map(
                lambda m: jnp.where(live_i, m, jnp.zeros_like(m)), out
            )
        return out, new_r

    spec = P(names)      # leading replica axis over every level axis
    sspec = P()          # step index is replicated

    if compressed:
        def body(g, r, s):
            payload, new_r = compress(g, r, plan.compression)
            payload = decompress(payload, plan.compression)
            return _mix_body(payload, g, r, new_r, s)

        return shard_map(
            body, mesh=inner, in_specs=(spec, spec, sspec),
            out_specs=(spec, spec), check_rep=False,
        )(grads, residuals, jnp.asarray(step, jnp.int32))

    def body(g, s):
        mixed, _ = _mix_body(g, g, None, None, s)
        return mixed

    mixed = shard_map(
        body, mesh=inner, in_specs=(spec, sspec), out_specs=spec,
        check_rep=False,
    )(grads, jnp.asarray(step, jnp.int32))
    return mixed, residuals
