"""Replica-set topology for decentralized gradient synchronization.

The paper's recursive-partition rule (Thm 1 discussion): subnetworks at
scale j contain O(n^((2/3)^j)) nodes, i.e. a network of size m is split
into ~m^(1/3) cells of ~m^(2/3) nodes each, recursively, until cells are
small enough to mix cheaply.  `suggest_levels` transplants that rule to
the replica set of a decentralized data-parallel trainer: it returns a
branching-factor tuple ``(l_1, ..., l_k)`` with ``prod(l_i) == R`` where
``l_1`` is the number of top-level cells and ``l_k`` is the size of the
finest cells.  For R = 32 this yields ``(4, 2, 4)``; for R = 512 the
hierarchy is >= 3 levels deep (the Theta(log log n) depth growth).

The mixing-matrix builders return dense doubly-stochastic matrices used
by analysis/tests and by the reference (host-side) mixing paths.  The
jittable strategies in `gossip_sync` apply the same operators
structurally (rolls / grouped means) so that sharded lowering emits real
collectives instead of an R x R matmul.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "suggest_levels",
    "ring_matrix",
    "complete_matrix",
    "hierarchy_matrix",
    "default_rounds",
    "is_doubly_stochastic",
    "rotation_schedule",
]

# Cells of <= _CELL_MAX replicas mix in O(1) rounds; recursion stops here
# (the paper's base case where a cell's induced subgraph is near-complete).
_CELL_MAX = 4


def suggest_levels(R: int, cell_max: int = _CELL_MAX) -> tuple[int, ...]:
    """Factor the replica count R following the paper's n^(2/3) rule.

    At every step a group of m replicas is split into b cells of m/b
    replicas, with b the divisor of m closest to m^(1/3) (so cells hold
    ~m^(2/3) replicas).  Recursion stops once cells fit in `cell_max`.
    Returns branching factors coarsest-first; their product is exactly R.
    """
    if R < 1:
        raise ValueError(f"replica count must be >= 1, got {R}")
    levels: list[int] = []
    m = R
    while m > cell_max:
        target = m ** (1.0 / 3.0)
        divisors = [d for d in range(2, m) if m % d == 0]
        if not divisors:  # prime group: one flat cell, nothing to split
            break
        b = min(divisors, key=lambda d: (abs(d - target), d))
        levels.append(b)
        m //= b
    levels.append(m)
    return tuple(levels)


def ring_matrix(m: int, self_weight: float = 1.0 / 3.0) -> np.ndarray:
    """Symmetric doubly-stochastic ring: each node averages with its two
    ring neighbors.  W = self_weight * I + (1-self_weight)/2 * (S + S^T).
    Second-largest eigenvalue modulus governs the per-round contraction
    of replica disagreement (Boyd et al.)."""
    if m < 1:
        raise ValueError(f"ring size must be >= 1, got {m}")
    if not 0.0 < self_weight < 1.0:
        raise ValueError(f"self_weight must be in (0, 1), got {self_weight}")
    if m == 1:
        return np.ones((1, 1))
    w = np.eye(m) * self_weight
    side = (1.0 - self_weight) / 2.0
    for i in range(m):
        w[i, (i + 1) % m] += side
        w[i, (i - 1) % m] += side
    return w


def complete_matrix(m: int) -> np.ndarray:
    """One-shot exact fusion: W = J/m (the all-reduce operator as a
    doubly-stochastic matrix; spectral gap 1)."""
    if m < 1:
        raise ValueError(f"cell size must be >= 1, got {m}")
    return np.full((m, m), 1.0 / m)


def hierarchy_matrix(
    levels: tuple[int, ...], rounds_per_level: tuple[int, ...] | None = None
) -> np.ndarray:
    """Effective R x R operator of one bottom-up multiscale sweep.

    Per level (finest to coarsest) the within-cell ring matrix is applied
    `rounds` times on every cell in parallel; the result is the Kronecker
    composition of level operators.  Useful to reason about the spectral
    gap of a `gossip_sync` multiscale configuration without lowering it.
    """
    R = int(np.prod(levels))
    if rounds_per_level is None:
        rounds_per_level = tuple(default_rounds(l) for l in levels)
    if len(rounds_per_level) != len(levels):
        raise ValueError(
            f"rounds_per_level {rounds_per_level} does not match levels {levels}"
        )
    op = np.eye(R)
    # finest level acts on contiguous blocks of size l_k; coarser levels on
    # strided groups — expressed as I_{pre} (x) W^rounds (x) I_{post}
    for ax in range(len(levels) - 1, -1, -1):
        pre = int(np.prod(levels[:ax], dtype=int)) if ax else 1
        post = int(np.prod(levels[ax + 1:], dtype=int))
        w = np.linalg.matrix_power(ring_matrix(levels[ax]), rounds_per_level[ax])
        lvl_op = np.kron(np.kron(np.eye(pre), w), np.eye(post))
        op = lvl_op @ op
    return op


def rotation_schedule(
    R: int, period: int, seed: int = 0
) -> tuple[np.ndarray, np.ndarray]:
    """The paper's randomized cells (§IV) on the replica set: a table of
    `period` replica permutations cycled by sync step, so cell membership
    (and ring neighbors) changes every step and no straggler is pinned to
    one cell.  Deterministic in (seed, step): step t uses row t % period.

    Returns (perms, invs), both (period, R) int32 with
    ``invs[t, perms[t, s]] == s`` — mixing runs in permuted order and the
    inverse scatters values back to their home replicas.
    """
    if R < 1:
        raise ValueError(f"replica count must be >= 1, got {R}")
    if period < 1:
        raise ValueError(f"rotation period must be >= 1, got {period}")
    rng = np.random.default_rng(seed)
    perms = np.stack([rng.permutation(R) for _ in range(period)]).astype(np.int32)
    invs = np.argsort(perms, axis=1).astype(np.int32)
    return perms, invs


def default_rounds(cell_size: int) -> int:
    """Mixing rounds for a ring of `cell_size` nodes sized so the slowest
    mode contracts below ~1e-3: the ring's second eigenvalue is
    (1 + 2 cos(2 pi / m)) / 3, so ~4m rounds suffice for the small cells
    the n^(2/3) rule produces."""
    return max(4, 4 * cell_size)


def is_doubly_stochastic(w: np.ndarray, atol: float = 1e-8) -> bool:
    return bool(
        np.all(w >= -atol)
        and np.allclose(w.sum(axis=0), 1.0, atol=atol)
        and np.allclose(w.sum(axis=1), 1.0, atol=atol)
    )
