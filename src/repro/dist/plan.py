"""SyncPlan — ahead-of-time planning for decentralized gradient sync.

Mirror of the simulation core's plan/execute split (`core/plan.py` /
`core/engine.py`) on the training stack: everything about a
synchronization strategy that does not depend on gradient *values* is
resolved once, host-side, into a static hashable `SyncPlan` —

* the replica hierarchy (branching factors from `suggest_levels`, or
  the user's `levels`) and per-level mixing rounds,
* the step-indexed **cell-rotation schedule** (the paper's randomized
  cells §IV transplanted to replicas: a precomputed table of replica
  permutations cycled by step, so a slow straggler is not pinned to
  one cell and its neighbors change every sync),
* the `CompressionConfig` for error-feedback compressed payloads,
* the wire-byte accounting model used by metrics and benchmarks.

`build_sync_plan(cfg, R)` validates the whole configuration with clear
errors at construction time (length mismatches and non-product-R level
tuples used to surface as reshape errors deep inside jit); the plan is
then consumed by the compiled `gossip_sync.execute_sync(plan, grads,
residuals, step)` — one plan serves every step of a training run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from .compression import CompressionConfig, wire_fraction
from .failures import SyncFailureModel, fault_counts
from .robust import AGGREGATIONS, resolve_trim
from .topology import default_rounds, rotation_schedule, suggest_levels

__all__ = [
    "AGGREGATIONS",
    "OVERLAP_MODES",
    "SyncConfig",
    "SyncPlan",
    "build_sync_plan",
    "plan_wire_bytes",
    "tree_payload_bytes",
]

STRATEGIES = ("allreduce", "hierarchical", "ring", "multiscale")
_GOSSIP = ("ring", "multiscale")  # strategies whose topology can rotate
OVERLAP_MODES = ("none", "one_step")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """Static (hashable) description of one synchronization strategy.

    levels: branching factors coarsest-first, product == R; () defers to
        `suggest_levels(R)` at plan time (ignored by allreduce/ring).
    rounds: per-level mixing rounds.  For `ring` a single entry is the
        number of global ring rounds; for `multiscale` either one entry
        shared by all levels or one per level; () picks
        `default_rounds(cell_size)` per level.
    exact_fusion: multiscale only — mass-weighted exact fusion that
        preserves the replica mean bitwise at every scale.
    compression: error-feedback payload compression (a
        `CompressionConfig`, or its scheme name as a string).
    rotation_period: > 0 enables the randomized-cell schedule on gossip
        strategies: a table of `rotation_period` replica permutations is
        drawn from `rotation_seed` and cycled by sync step.  0 (default)
        keeps the static assignment — exact strategies are unaffected
        either way.
    overlap: "none" (default) runs sync strictly after the backward
        pass; "one_step" selects one-step-delayed averaging (the paper's
        asynchronous time model applied to step pipelining): each step
        applies the PREVIOUS step's mixed gradients while the current
        step's gossip has no data dependency on the backward and can
        execute concurrently.  The train state then carries a
        double-buffered `prev_grads` pytree (see `dist.async_sync`).
    failures: optional `SyncFailureModel` injecting per-step replica
        churn, stragglers, and Byzantine payloads into every executor
        (see `dist.failures`).  None (default) is the reliable path,
        bitwise-identical to a plan without the field.
    aggregation: how per-replica payloads are combined under (possible)
        faults — one of `dist.robust.AGGREGATIONS`.  "mean" (default)
        is the strategy's own mixing; "trimmed_mean" /
        "coordinate_median" are per-coordinate robust consensus
        reductions (Byzantine defense); "survivor_weighted" keeps the
        strategy but renormalizes doubly-stochastic mass over live
        replicas (churn defense).
    """

    strategy: str = "allreduce"
    levels: tuple[int, ...] = ()
    rounds: tuple[int, ...] = ()
    exact_fusion: bool = False
    compression: CompressionConfig = CompressionConfig()
    rotation_period: int = 0
    rotation_seed: int = 0
    overlap: str = "none"
    failures: Optional[SyncFailureModel] = None
    aggregation: str = "mean"

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}; expected one of {STRATEGIES}"
            )
        object.__setattr__(self, "levels", tuple(int(l) for l in self.levels))
        object.__setattr__(self, "rounds", tuple(int(r) for r in self.rounds))
        if isinstance(self.compression, str):
            object.__setattr__(
                self, "compression", CompressionConfig(self.compression)
            )
        if any(l < 1 for l in self.levels):
            raise ValueError(f"levels must be positive, got {self.levels}")
        if any(r < 0 for r in self.rounds):
            raise ValueError(f"rounds must be >= 0, got {self.rounds}")
        if self.strategy == "ring" and len(self.rounds) > 1:
            raise ValueError(
                f"ring takes a single global round count, got rounds={self.rounds}"
            )
        if (
            self.levels
            and len(self.rounds) > 1
            and len(self.rounds) != len(self.levels)
        ):
            raise ValueError(
                f"rounds {self.rounds} has {len(self.rounds)} entries but levels "
                f"{self.levels} has {len(self.levels)}; pass one round count per "
                f"level, a single shared entry, or () for defaults"
            )
        if self.rotation_period < 0:
            raise ValueError(
                f"rotation_period must be >= 0, got {self.rotation_period}"
            )
        if self.overlap not in OVERLAP_MODES:
            raise ValueError(
                f"unknown overlap mode {self.overlap!r}; expected one of "
                f"{OVERLAP_MODES}"
            )
        if self.failures is not None and not isinstance(
            self.failures, SyncFailureModel
        ):
            raise ValueError(
                f"failures must be a SyncFailureModel or None, "
                f"got {self.failures!r}"
            )
        if self.aggregation not in AGGREGATIONS:
            raise ValueError(
                f"unknown aggregation {self.aggregation!r}; expected one of "
                f"{AGGREGATIONS}"
            )

    def resolved_levels(self, R: int) -> tuple[int, ...]:
        levels = self.levels or suggest_levels(R)
        prod = 1
        for l in levels:
            prod *= l
        if prod != R:
            raise ValueError(
                f"levels {levels} factor {prod} replicas but R={R}; levels must "
                f"multiply out to the replica count exactly"
            )
        return levels

    def resolved_rounds(self, levels: tuple[int, ...]) -> tuple[int, ...]:
        if not self.rounds:
            return tuple(default_rounds(l) for l in levels)
        if len(self.rounds) == 1:
            return self.rounds * len(levels)
        if len(self.rounds) != len(levels):
            # reachable when levels were deferred to suggest_levels(R);
            # explicit levels fail the same check in __post_init__
            raise ValueError(
                f"rounds {self.rounds} has {len(self.rounds)} entries but "
                f"levels {levels} has {len(levels)}; pass one round count per "
                f"level, a single shared entry, or () for defaults"
            )
        return self.rounds


@dataclasses.dataclass(frozen=True)
class SyncPlan:
    """Fully resolved, hashable synchronization plan for R replicas.

    Built by `build_sync_plan`; static under jit so one compiled
    `execute_sync` serves every training step.  `rotation` /
    `rotation_inv` are the permutation table of the randomized-cell
    schedule (None when rotation is off): sync step `t` mixes under
    replica order `rotation[t % P]` and scatters back through
    `rotation_inv[t % P]`.
    """

    strategy: str
    R: int
    levels: tuple[int, ...]
    rounds: tuple[int, ...]
    exact_fusion: bool
    compression: CompressionConfig
    rotation: Optional[tuple[tuple[int, ...], ...]] = None
    rotation_inv: Optional[tuple[tuple[int, ...], ...]] = None
    overlap: str = "none"
    failures: Optional[SyncFailureModel] = None
    aggregation: str = "mean"

    @property
    def rotated(self) -> bool:
        return self.rotation is not None

    @property
    def faulty(self) -> bool:
        """True when the plan injects at least one fault per step."""
        return self.failures is not None and self.failures.active

    @property
    def robust_consensus(self) -> bool:
        """True for the consensus-style robust reductions that replace
        the strategy's own mixing (rotation is a no-op for them)."""
        return self.aggregation in ("trimmed_mean", "coordinate_median")

    @property
    def overlapped(self) -> bool:
        return self.overlap == "one_step"

    @property
    def transmissions(self) -> int:
        """Per-sync payload sends under the point-to-point accounting model.

        Counts how many times the (possibly compressed) per-replica
        payload crosses a link per sync — the training-side analogue of
        the paper's message complexity.  Model: allreduce is the
        bandwidth-optimal ring (2(R-1) sends); hierarchical sends each
        active node's value up its fusion ladder and mirrors it down;
        ring gossip sends to both neighbors every round; multiscale
        pays per-cell ring rounds at every level plus the n-message
        dissemination down-pass (representative promotion is local).
        """
        R = self.R
        if R <= 1:
            return 0
        if self.strategy == "allreduce":
            return 2 * (R - 1)
        if self.strategy == "hierarchical" or (
            self.strategy == "multiscale" and self.exact_fusion
        ):
            # exact fusion evaluates as the grouped-mean ladder (§VII with
            # uniform occupancy) — same fusion traffic as `hierarchical`
            total, active = 0, R
            for l in reversed(self.levels):
                total += active
                active //= l
            return 2 * total
        if self.strategy == "ring":
            return 2 * R * self.rounds[0]
        total, active = 0, R
        for ax in range(len(self.levels) - 1, 0, -1):
            total += 2 * active * self.rounds[ax]
            active //= self.levels[ax]
        total += 2 * active * self.rounds[0]
        return total + R  # dissemination down-pass


def build_sync_plan(cfg: SyncConfig, R: int) -> SyncPlan:
    """Resolve a `SyncConfig` against a replica count into a `SyncPlan`.

    All configuration errors (level products, round counts, rotation
    parameters) surface here with actionable messages instead of as
    shape errors inside a traced `execute_sync`.
    """
    if R < 1:
        raise ValueError(f"R must be >= 1, got {R}")
    if cfg.strategy in ("hierarchical", "multiscale"):
        levels = cfg.resolved_levels(R)
        rounds = cfg.resolved_rounds(levels)
    elif cfg.strategy == "ring":
        levels = ()
        rounds = (cfg.rounds[0] if cfg.rounds else 2 * R,)
    else:  # allreduce
        levels, rounds = (), ()

    rotation = rotation_inv = None
    if cfg.rotation_period > 0 and cfg.strategy in _GOSSIP and R > 1:
        perms, invs = rotation_schedule(R, cfg.rotation_period, cfg.rotation_seed)
        rotation = tuple(tuple(int(i) for i in p) for p in perms)
        rotation_inv = tuple(tuple(int(i) for i in p) for p in invs)

    if cfg.failures is not None:
        kc, ks, kb = fault_counts(cfg.failures, R)
        if kc + ks + kb >= R:
            raise ValueError(
                f"failure fractions leave no honest live replica: "
                f"churn {kc} + stragglers {ks} + byzantine {kb} >= R={R}"
            )
    if cfg.aggregation == "trimmed_mean":
        k_drop, k_trim = resolve_trim(cfg.failures, R)
        if R > 1 and R - k_drop - 2 * k_trim < 1:
            raise ValueError(
                f"trimmed_mean infeasible: dropping {k_drop} and trimming "
                f"2*{k_trim} of R={R} replicas leaves no value; lower the "
                f"failure fractions or use coordinate_median"
            )

    return SyncPlan(
        strategy=cfg.strategy,
        R=R,
        levels=levels,
        rounds=rounds,
        exact_fusion=cfg.exact_fusion,
        compression=cfg.compression,
        rotation=rotation,
        rotation_inv=rotation_inv,
        # one replica has nothing to overlap with — resolve to serialized
        overlap=cfg.overlap if R > 1 else "none",
        failures=cfg.failures,
        aggregation=cfg.aggregation,
    )


def tree_payload_bytes(grads: Any) -> int:
    """Dense per-replica payload bytes of a replicated gradient pytree
    (leading axis = replica; shape-only, safe on tracers/abstract values)."""
    import jax

    total = 0
    for leaf in jax.tree.leaves(grads):
        per_replica = 1
        for d in leaf.shape[1:]:
            per_replica *= d
        total += per_replica * leaf.dtype.itemsize
    return total


def plan_wire_bytes(plan: SyncPlan, grads: Any) -> float:
    """Modeled wire bytes of one sync: payload bytes x transmissions x
    the compression scheme's `wire_fraction`.  Static given shapes, so
    it folds to a constant inside a jitted train step."""
    return float(
        tree_payload_bytes(grads)
        * plan.transmissions
        * wire_fraction(plan.compression)
    )
