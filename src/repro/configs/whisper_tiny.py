"""whisper-tiny [audio]: enc-dec, conv frontend stubbed as precomputed
frame embeddings.  4 encoder + 4 decoder layers, d=384, 6H (kv=6),
d_ff=1536, vocab=51865.  [arXiv:2212.04356; unverified]

Deviation (DESIGN.md §8): decoder uses RoPE instead of learned absolute
positions so the 32k stress shapes are well-defined; encoder keeps
whisper's sinusoidal positions.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    encoder_layers=4,
    encoder_seq=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_kind="gelu",
    block_unit=("attn",),
    frontend="audio_frames",
    tie_embeddings=True,
    optimizer="adamw",
)
