from .registry import ARCH_IDS, SHAPES, get_config, reduce_config

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "reduce_config"]
