"""gemma-7b [dense]: GeGLU, head_dim=256 (wider than d_model/heads),
16H (kv=16 — MHA on 7b; MQA is the 2b variant), 28L, d=3072,
d_ff=24576, vocab=256000, scaled embeddings.  [arXiv:2403.08295; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    mlp_kind="geglu",
    scale_embeddings=True,
    tie_embeddings=True,
    optimizer="adamw",
)
