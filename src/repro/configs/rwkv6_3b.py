"""rwkv6-3b [ssm] "Finch": attention-free, data-dependent decay wkv +
squared-ReLU channel mix.  32L, d=2560 (40 heads x 64), d_ff=8960,
vocab=65536.  [arXiv:2404.05892; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # informational; time-mix uses rwkv_head_dim
    d_ff=8960,
    vocab_size=65_536,
    block_unit=("rwkv",),
    rwkv_head_dim=64,
    tie_embeddings=False,
    optimizer="adamw",
)
