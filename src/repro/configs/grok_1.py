"""grok-1-314b [moe]: 8 experts top-2, attention logit softcap 30.
64L, d=6144, 48H (kv=8, head_dim=128), per-expert d_ff=32768,
vocab=131072.  [hf:xai-org/grok-1; unverified]

Memory policy: Adafactor training state (DESIGN.md §6).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    mlp_kind="swiglu",
    num_experts=8,
    experts_per_token=2,
    attn_logit_softcap=30.0,
    tie_embeddings=False,
    optimizer="adafactor",
)
