"""yi-6b [dense]: llama-architecture GQA.  32L, d=4096, 32H (kv=4,
head_dim=128), d_ff=11008, vocab=64000.  [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    mlp_kind="swiglu",
    rope_theta=5_000_000.0,
    tie_embeddings=False,
    optimizer="adamw",
)
