"""gemma2-27b [dense]: alternating local/global attention, logit
softcapping (attn 50, final 30), post-sublayer norms, query scale
(d_model/num_heads)^-0.5.  46L, d=4608, 32H (kv=16, head_dim=128),
d_ff=36864, vocab=256000, window=4096.  [arXiv:2408.00118; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    mlp_kind="geglu",
    block_unit=("local", "attn"),
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_norms=True,
    query_scale=(4608 / 32) ** -0.5,
    scale_embeddings=True,
    tie_embeddings=True,
    optimizer="adamw",
)
