"""Architecture registry + assigned input shapes + reduced smoke configs.

Shapes (assignment): seq_len x global_batch.  decode_* / long_* lower
`serve_step` (one token against a seq_len KV cache); long_500k requires
sub-quadratic sequence mixing and is skipped for pure full-attention
archs (`ModelConfig.supports_long_context`), recorded per-cell in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCH_IDS", "SHAPES", "get_config", "reduce_config", "cell_is_runnable"]

ARCH_IDS = (
    "whisper-tiny",
    "recurrentgemma-9b",
    "yi-6b",
    "gemma-7b",
    "gemma2-27b",
    "llama3.2-3b",
    "llama4-maverick-400b-a17b",
    "grok-1-314b",
    "qwen2-vl-72b",
    "rwkv6-3b",
)

_MODULE_OF = {
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "yi-6b": "yi_6b",
    "gemma-7b": "gemma_7b",
    "gemma2-27b": "gemma2_27b",
    "llama3.2-3b": "llama3_2_3b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "grok-1-314b": "grok_1",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "rwkv6-3b": "rwkv6_3b",
}

# name -> (seq_len, global_batch, mode)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch_id]}")
    return mod.CONFIG


def cell_is_runnable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Skip rules from the assignment; returns (runnable, reason)."""
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return False, "full attention is quadratic at 524k context (skip rule)"
    return True, ""


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests: small width/depth,
    few experts, tiny vocab, short windows."""
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=max(len(cfg.block_unit), 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.kv_heads, 2) if cfg.num_kv_heads else None,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=16 if cfg.window else None,
        rwkv_head_dim=16,
        remat=False,
    )
    if cfg.num_experts:
        changes.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=24)
    if cfg.mrope_sections:
        changes.update(mrope_sections=(2, 3, 3))  # sums to head_dim//2
    if cfg.query_scale:
        changes.update(query_scale=(64 / 4) ** -0.5)
    return dataclasses.replace(cfg, **changes)
