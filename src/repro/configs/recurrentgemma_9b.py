"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 pattern
(two recurrent blocks per local-attention block).  38L, d=4096, 16H
(MQA kv=1, head_dim=256), d_ff=12288, vocab=256000, window=2048.
[arXiv:2402.19427; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    mlp_kind="geglu",
    block_unit=("rglru", "rglru", "local"),
    window=2048,
    scale_embeddings=True,
    tie_embeddings=True,
    optimizer="adamw",
)
