"""llama4-maverick-400b-a17b [moe]: MoE 128 experts top-1 (early
fusion).  48L, d=5120, 40H (kv=8, head_dim=128), per-expert d_ff=8192,
vocab=202048.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Memory policy (DESIGN.md §6): per the assigned numbers this config has
~780B parameters; training state uses momentum-free factored Adafactor
so the single-pod (256-chip) train cell fits 16 GB/chip HBM.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    mlp_kind="swiglu",
    num_experts=128,
    experts_per_token=1,
    tie_embeddings=False,
    optimizer="adafactor",
)
