"""qwen2-vl-72b [vlm]: M-RoPE (3-section rotary: temporal/height/width),
dynamic resolution vision tower STUBBED — input_specs provides token
ids plus (B, S, 3) multimodal position ids.  80L, d=8192, 64H (kv=8,
head_dim=128), d_ff=29568, vocab=152064.  [arXiv:2409.12191; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    mlp_kind="swiglu",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_patches",
    tie_embeddings=False,
    optimizer="adamw",
)
