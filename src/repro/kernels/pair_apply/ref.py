"""Pure-jnp oracle for the pair-apply kernel: the sequential
pair-average recursion over a presampled exchange schedule.

This is the value half of the legacy per-tick gossip scan with the
sampling stripped out — same gathers, same 0.5 * (xi + xj), same
conditional writes in the same order — so it is bitwise-identical to
the historical path and serves as both the lax-backend hot loop and
the Pallas kernel's parity oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pair_apply_ref"]


def pair_apply_ref(x, i, j, upd_i, upd_j):
    """Apply a presampled pair list to batched cell state.

    Args:
      x: (B, C, V) node values.
      i, j: (T, B) int32 exchange pairs (j already clipped to >= 0).
      upd_i, upd_j: (T, B) bool — whether the initiator / partner row
        actually updates at that tick (schedule validity, per-chunk
        done freeze, and per-hop loss outcomes already folded in).
    Returns (B, C, V) state after the T ticks, in order.
    """
    B, C, V = x.shape
    bidx = jnp.arange(B)
    slots = jnp.arange(C)[None, :]

    def tick(x, sched):
        it, jt, ui, uj = sched
        xi = x[bidx, it]
        xj = x[bidx, jt]
        avg = 0.5 * (xi + xj)
        # row writes as one-hot masked selects, not scatters: the written
        # value is the identical float either way (no arithmetic on the
        # pass-through lanes), but XLA compiles a select orders of
        # magnitude faster than a scatter and vectorizes it better on
        # CPU.  Partner row first, then initiator (the legacy order).
        oh_j = (slots == jt[:, None]) & uj[:, None]
        oh_i = (slots == it[:, None]) & ui[:, None]
        x = jnp.where(oh_j[..., None], avg[:, None, :], x)
        x = jnp.where(oh_i[..., None], avg[:, None, :], x)
        return x, None

    x, _ = jax.lax.scan(tick, x, (i, j, upd_i, upd_j))
    return x
