"""Public op for applying presampled gossip schedules: alignment
padding, schedule layout, and the Pallas-vs-oracle dispatch.

`use_pallas=False` (or any non-TPU engine run) takes the jnp oracle —
the same scan the lax backend uses, bitwise-identical to the kernel's
f32 op sequence, so backend choice never changes simulation results.
The Pallas kernel itself is validated in interpret mode by the kernel
tests and runs for real only on TPU hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import pair_apply_pallas
from .ref import pair_apply_ref

__all__ = ["pair_apply"]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def pair_apply(
    x: jax.Array,
    i: jax.Array,
    j: jax.Array,
    upd_i: jax.Array,
    upd_j: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    """Walk a (T, B) presampled exchange schedule over (B, C, V) state.

    See `ref.pair_apply_ref` for argument semantics.  Inputs may be
    unaligned; the Pallas path pads C to 8 sublanes / V to 128 lanes,
    transposes the schedule to graph-major SMEM layout, and crops the
    result back.
    """
    if not use_pallas:
        return pair_apply_ref(x, i, j, upd_i, upd_j)
    B, C, V = x.shape
    Cp, Vp = _round_up(C, 8), _round_up(V, 128)
    xp = jnp.pad(x, ((0, 0), (0, Cp - C), (0, Vp - V)))
    sched = (
        i.T.astype(jnp.int32),
        j.T.astype(jnp.int32),
        upd_i.T.astype(jnp.int32),
        upd_j.T.astype(jnp.int32),
    )
    y = pair_apply_pallas(xp, *sched, interpret=interpret)
    return y[:, :C, :V]
