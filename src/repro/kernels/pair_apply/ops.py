"""Public op for applying presampled gossip schedules: alignment
padding, cell-block tiling, schedule layout, and the Pallas-vs-oracle
dispatch.

`use_pallas=False` (or any non-TPU engine run) takes the jnp oracle —
the same scan the lax backend uses, bitwise-identical to the kernel's
f32 op sequence, so backend choice never changes simulation results.
The Pallas kernel itself is validated in interpret mode by the kernel
tests and runs for real only on TPU hosts.

`block_b` controls how many cells are resident per grid step (see
kernel.py).  The default sizes the block so the state tile stays
within ~512 KiB of VMEM and the four schedule tiles within ~128 KiB of
SMEM — large-n levels stream through in blocks, tiny fig3-scale levels
still run as a single block.  Results are bitwise-independent of the
block size (cells never interact).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import pair_apply_pallas
from .ref import pair_apply_ref

__all__ = ["pair_apply"]

_VMEM_BLOCK_BYTES = 512 * 1024
_SMEM_BLOCK_BYTES = 128 * 1024


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def _auto_block(B: int, Cp: int, Vp: int, T: int) -> int:
    vmem_cap = max(1, _VMEM_BLOCK_BYTES // (Cp * Vp * 4))
    smem_cap = max(1, _SMEM_BLOCK_BYTES // (4 * T * 4))
    return max(1, min(B, vmem_cap, smem_cap))


@functools.partial(
    jax.jit, static_argnames=("use_pallas", "interpret", "block_b")
)
def pair_apply(
    x: jax.Array,
    i: jax.Array,
    j: jax.Array,
    upd_i: jax.Array,
    upd_j: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: bool = False,
    block_b: Optional[int] = None,
) -> jax.Array:
    """Walk a (T, B) presampled exchange schedule over (B, C, V) state.

    See `ref.pair_apply_ref` for argument semantics.  Inputs may be
    unaligned; the Pallas path pads C to 8 sublanes / V to 128 lanes,
    pads B up to a `block_b` multiple (padded cells get an all-masked
    schedule, i.e. pure pass-through), transposes the schedule to
    graph-major SMEM layout, and crops the result back.
    """
    if not use_pallas:
        return pair_apply_ref(x, i, j, upd_i, upd_j)
    B, C, V = x.shape
    T = i.shape[0]
    Cp, Vp = _round_up(C, 8), _round_up(V, 128)
    bb = block_b if block_b is not None else _auto_block(B, Cp, Vp, T)
    bb = max(1, min(bb, B))
    Bp = _round_up(B, bb)
    xp = jnp.pad(x, ((0, Bp - B), (0, Cp - C), (0, Vp - V)))

    def prep(a):  # (T, B) -> graph-major (Bp, T) int32
        return jnp.pad(a.astype(jnp.int32), ((0, 0), (0, Bp - B))).T

    y = pair_apply_pallas(
        xp, prep(i), prep(j), prep(upd_i), prep(upd_j),
        block_b=bb, interpret=interpret,
    )
    return y[:B, :C, :V]
