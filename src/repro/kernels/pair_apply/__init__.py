from .kernel import pair_apply_pallas
from .ops import pair_apply
from .ref import pair_apply_ref

__all__ = ["pair_apply", "pair_apply_pallas", "pair_apply_ref"]
