"""Pallas TPU kernel: walk a presampled gossip schedule in VMEM.

The simulation hot path applies a `check_every`-tick presampled pair
list to the (B, C, V) cell state.  Doing that with XLA scatters keeps
the state in HBM and round-trips it twice per tick; here cell state is
loaded into VMEM once per kernel call and the whole schedule is walked
on-chip — two dynamic row slices, one VPU average, and two dynamic row
updates per tick, with the final state written back once.

State residence is TILED: the grid runs over blocks of `block_b` cells,
so only one ``(block_b, C_pad, V_pad)`` state block and its
``(block_b, T)`` schedule slice are resident at a time — large-n levels
(tens of thousands of cells) stream through VMEM instead of assuming
the whole batch fits.  The schedule rides in as blocked SMEM inputs
(NOT whole-array scalar prefetch, which would have to hold all ``B*T``
indices in SMEM at once and overflows at large B); the loop's dynamic
row indices must live in SMEM on TPU.

Per-program working set: ``block_b * C_pad * V_pad * 4`` bytes of VMEM
for each of x/out plus ``4 * block_b * T`` int32 SMEM words — the
caller (ops.pair_apply) sizes `block_b` to keep both far inside budget.

Arithmetic per cell is the exact f32 op sequence of the jnp oracle
(`ref.pair_apply_ref`) and cells never interact, so the kernel is
bitwise-interchangeable with the lax backend for every block size
rather than merely allclose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pair_apply_pallas"]


def _pair_apply_kernel(
    i_ref, j_ref, ui_ref, uj_ref, x_ref, o_ref, *, ticks: int, cells: int
):
    def cell_body(l, _):
        x0 = pl.load(
            x_ref, (pl.dslice(l, 1), slice(None), slice(None))
        )[0].astype(jnp.float32)                 # (C_pad, V_pad)

        def body(t, x):
            it = i_ref[l, t]
            jt = j_ref[l, t]
            xi = jax.lax.dynamic_slice_in_dim(x, it, 1, 0)   # (1, V_pad)
            xj = jax.lax.dynamic_slice_in_dim(x, jt, 1, 0)
            avg = 0.5 * (xi + xj)
            # partner row first, then initiator — the oracle's write order
            x = jax.lax.dynamic_update_slice_in_dim(
                x, jnp.where(uj_ref[l, t] > 0, avg, xj), jt, 0
            )
            x = jax.lax.dynamic_update_slice_in_dim(
                x, jnp.where(ui_ref[l, t] > 0, avg, xi), it, 0
            )
            return x

        y = jax.lax.fori_loop(0, ticks, body, x0)
        pl.store(
            o_ref, (pl.dslice(l, 1), slice(None), slice(None)),
            y[None].astype(o_ref.dtype),
        )
        return 0

    jax.lax.fori_loop(0, cells, cell_body, 0)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def pair_apply_pallas(
    x: jax.Array,
    i: jax.Array,
    j: jax.Array,
    upd_i: jax.Array,
    upd_j: jax.Array,
    *,
    block_b: int,
    interpret: bool = False,
) -> jax.Array:
    """Apply a (B, T) presampled schedule to (B, C_pad, V_pad) state,
    `block_b` cells per grid step.

    The caller (ops.pair_apply) is responsible for MXU/lane alignment
    (C_pad multiple of 8, V_pad multiple of 128), for padding B up to a
    `block_b` multiple (padded cells carry an all-masked schedule, so
    their rows pass through untouched), and for transposing the
    schedule to graph-major (B, T) int32.
    """
    B, C, V = x.shape
    T = i.shape[1]
    assert i.shape == j.shape == upd_i.shape == upd_j.shape == (B, T)
    assert B % block_b == 0, (B, block_b)
    sched_spec = pl.BlockSpec(
        (block_b, T), lambda g: (g, 0), memory_space=pltpu.SMEM
    )
    return pl.pallas_call(
        functools.partial(_pair_apply_kernel, ticks=T, cells=block_b),
        grid=(B // block_b,),
        in_specs=[
            sched_spec, sched_spec, sched_spec, sched_spec,
            pl.BlockSpec((block_b, C, V), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, C, V), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(i, j, upd_i, upd_j, x)
