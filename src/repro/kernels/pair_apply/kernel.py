"""Pallas TPU kernel: walk a presampled gossip schedule in VMEM.

The simulation hot path applies a `check_every`-tick presampled pair
list to the (B, C, V) cell state.  Doing that with XLA scatters keeps
the state in HBM and round-trips it twice per tick; here each cell's
state is loaded into VMEM once per kernel call and the whole schedule
is walked on-chip — two dynamic row slices, one VPU average, and two
dynamic row updates per tick, with the final state written back once.

The schedule (i, j, update flags, shaped (B, T)) rides in as scalar
prefetch so it lands in SMEM, where the loop's dynamic row indices
must live on TPU.

Per-program VMEM working set: x/y (C_pad, V_pad) f32 each — the
hierarchy's per-cell matrices are tiny (C up to a few dozen, padded to
8 sublanes x 128 lanes), far inside the ~16 MiB v5e budget.

Arithmetic is the exact f32 op sequence of the jnp oracle
(`ref.pair_apply_ref`), so the kernel is bitwise-interchangeable with
the lax backend rather than merely allclose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pair_apply_pallas"]


def _pair_apply_kernel(i_ref, j_ref, ui_ref, uj_ref, x_ref, o_ref, *, ticks: int):
    b = pl.program_id(0)
    x = x_ref[0].astype(jnp.float32)      # (C_pad, V_pad)

    def body(t, x):
        it = i_ref[b, t]
        jt = j_ref[b, t]
        xi = jax.lax.dynamic_slice_in_dim(x, it, 1, 0)   # (1, V_pad)
        xj = jax.lax.dynamic_slice_in_dim(x, jt, 1, 0)
        avg = 0.5 * (xi + xj)
        # partner row first, then initiator — the oracle's write order
        x = jax.lax.dynamic_update_slice_in_dim(
            x, jnp.where(uj_ref[b, t] > 0, avg, xj), jt, 0
        )
        x = jax.lax.dynamic_update_slice_in_dim(
            x, jnp.where(ui_ref[b, t] > 0, avg, xi), it, 0
        )
        return x

    o_ref[0] = jax.lax.fori_loop(0, ticks, body, x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_apply_pallas(
    x: jax.Array,
    i: jax.Array,
    j: jax.Array,
    upd_i: jax.Array,
    upd_j: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Apply a (B, T) presampled schedule to (B, C_pad, V_pad) state.

    The caller (ops.pair_apply) is responsible for MXU/lane alignment
    (C_pad multiple of 8, V_pad multiple of 128) and for transposing
    the schedule to graph-major (B, T) int32.
    """
    B, C, V = x.shape
    T = i.shape[1]
    assert i.shape == j.shape == upd_i.shape == upd_j.shape == (B, T)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B,),
        in_specs=[pl.BlockSpec((1, C, V), lambda b, *_: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, C, V), lambda b, *_: (b, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_pair_apply_kernel, ticks=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(i, j, upd_i, upd_j, x)
