"""Pallas TPU kernel: batched per-cell gossip mixing.

TPU-native adaptation of the paper's inner loop (DESIGN.md §3): one
synchronous gossip round at one scale is `x_cell <- W_cell @ x_cell`
for every cell in parallel, with W_cell a doubly-stochastic mixing
matrix.  `rounds` applications are fused in VMEM so the cell state is
read from HBM once per kernel call instead of once per round —
arithmetic intensity grows linearly with `rounds`.

Grid: (B cells, d/block_d value tiles).  Per-program VMEM working set:
  W (m, m) + x/y (m, block_d) each, fp32 accumulation.
With m <= 256 and block_d = 512 this is ~1.3 MiB, comfortably inside
the ~16 MiB v5e VMEM budget; m and block_d are MXU-aligned (multiples
of 8/128) by the ops.py wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["cell_mixing_pallas"]


def _mixing_kernel(w_ref, x_ref, o_ref, *, rounds: int):
    w = w_ref[0].astype(jnp.float32)   # (m, m)
    x = x_ref[0].astype(jnp.float32)   # (m, block_d)

    def body(_, acc):
        return jnp.dot(w, acc, preferred_element_type=jnp.float32)

    y = jax.lax.fori_loop(0, rounds, body, x)
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rounds", "block_d", "interpret"))
def cell_mixing_pallas(
    w: jax.Array,
    x: jax.Array,
    *,
    rounds: int = 1,
    block_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """y[b] = W[b]^rounds @ x[b]  for all cells b.

    Args:
      w: (B, m, m) mixing matrices (rows/cols of padding must be
         identity-extended by the caller — see ops.pad_mixing).
      x: (B, m, d) cell node values.
      rounds: number of fused gossip rounds.
      block_d: value-dimension tile (multiple of 128).
    """
    B, m, d = x.shape
    assert w.shape == (B, m, m), (w.shape, x.shape)
    assert d % block_d == 0, f"d={d} must be a multiple of block_d={block_d}"
    grid = (B, d // block_d)
    return pl.pallas_call(
        functools.partial(_mixing_kernel, rounds=rounds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, m), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, m, block_d), lambda b, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, m, block_d), lambda b, j: (b, 0, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(w, x)
