"""Pure-jnp oracle for the cell mixing kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cell_mixing_ref"]


def cell_mixing_ref(w: jax.Array, x: jax.Array, *, rounds: int = 1) -> jax.Array:
    """y[b] = W[b]^rounds @ x[b], accumulated in fp32."""
    y = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    for _ in range(rounds):
        y = jnp.einsum("bij,bjd->bid", wf, y)
    return y.astype(x.dtype)
