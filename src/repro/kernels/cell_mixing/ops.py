"""Public ops for batched cell mixing: padding helpers, mixing-matrix
construction (Metropolis-Hastings weights — symmetric doubly stochastic,
the standard synchronous-gossip mixing choice), and the jitted entry
point that dispatches Pallas vs the jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import cell_mixing_pallas
from .ref import cell_mixing_ref

__all__ = ["mixing_matrix", "pad_mixing", "cell_mixing"]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def mixing_matrix(
    neighbors: np.ndarray, degrees: np.ndarray, n_nodes: np.ndarray
) -> np.ndarray:
    """Batched Metropolis-Hastings mixing matrices from padded adjacency.

    W_ij = 1 / (1 + max(d_i, d_j)) for edges, W_ii = 1 - sum_j W_ij,
    identity on padding rows — symmetric, doubly stochastic, with the
    same fixed point (the average) as asynchronous pairwise gossip.
    """
    B, C, D = neighbors.shape
    w = np.zeros((B, C, C), np.float32)
    for b in range(B):
        for i in range(int(n_nodes[b])):
            for s in range(int(degrees[b, i])):
                j = int(neighbors[b, i, s])
                w[b, i, j] = 1.0 / (1.0 + max(degrees[b, i], degrees[b, j]))
        row = w[b].sum(axis=1)
        np.fill_diagonal(w[b], 1.0 - row)
    return w


def pad_mixing(w: jax.Array | np.ndarray, x: jax.Array | np.ndarray,
               m_mult: int = 8, d_mult: int = 128):
    """Pad (B, m, m) W with identity and (B, m, d) x with zeros so m is a
    multiple of `m_mult` and d of `d_mult` (MXU/lane alignment)."""
    B, m, d = x.shape
    mp, dp = _round_up(m, m_mult), _round_up(d, d_mult)
    if mp != m:
        w = jnp.pad(jnp.asarray(w), ((0, 0), (0, mp - m), (0, mp - m)))
        eye_pad = jnp.zeros((B, mp, mp), w.dtype).at[
            :, jnp.arange(m, mp), jnp.arange(m, mp)
        ].set(1.0)
        w = w + eye_pad
        x = jnp.pad(jnp.asarray(x), ((0, 0), (0, mp - m), (0, 0)))
    if dp != d:
        x = jnp.pad(jnp.asarray(x), ((0, 0), (0, 0), (0, dp - d)))
    return w, x, (m, d)


@functools.partial(
    jax.jit, static_argnames=("rounds", "use_pallas", "interpret", "block_d")
)
def cell_mixing(
    w: jax.Array,
    x: jax.Array,
    *,
    rounds: int = 1,
    use_pallas: bool = True,
    interpret: bool = False,
    block_d: int = 512,
) -> jax.Array:
    """Apply `rounds` synchronous gossip rounds per cell: W[b]^R @ x[b].

    Inputs may be unaligned; they are identity/zero padded, mixed, and
    cropped back.  `use_pallas=False` selects the pure-jnp oracle (used
    for the XLA lowering path on non-TPU hosts).
    """
    wp, xp, (m, d) = pad_mixing(w, x)
    if use_pallas:
        bd = min(block_d, xp.shape[2])
        y = cell_mixing_pallas(wp, xp, rounds=rounds, block_d=bd, interpret=interpret)
    else:
        y = cell_mixing_ref(wp, xp, rounds=rounds)
    return y[:, :m, :d]
