from .kernel import cell_mixing_pallas
from .ops import cell_mixing, mixing_matrix, pad_mixing
from .ref import cell_mixing_ref

__all__ = [
    "cell_mixing",
    "cell_mixing_pallas",
    "cell_mixing_ref",
    "mixing_matrix",
    "pad_mixing",
]
