# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Kernel subpackages aside, this __init__ carries only the
# version-portability shims the kernels share.


def pallas_compiler_params():
    """The Pallas TPU CompilerParams class under its version-portable
    name: `pltpu.CompilerParams` (jax >= 0.5) or `TPUCompilerParams`
    (0.4.x).  Imported lazily so merely importing repro.kernels never
    touches jax."""
    from jax.experimental.pallas import tpu as pltpu

    return getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
