"""Jitted entry point for flash attention with padding + dispatch.

`use_pallas=True` targets the TPU kernel (validated under
interpret=True on CPU); `use_pallas=False` uses the jnp oracle — the
model code instead uses `repro.models.attention.chunked_attention` as
its XLA path for long sequences (same math, lax.scan over KV blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention"]


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale",
        "block_q", "block_k", "use_pallas", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    use_pallas: bool = True,
    interpret: bool = False,
) -> jax.Array:
    if not use_pallas:
        return attention_ref(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    bq, bk = min(block_q, _round_up(Sq, 128)), min(block_k, _round_up(Sk, 128))
    sq_p, sk_p = _round_up(Sq, bq), _round_up(Sk, bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - Sk), (0, 0)))
    # kv_len masking inside the kernel hides the padded KV tail; padded
    # query rows compute garbage that is cropped here
    out = flash_attention_pallas(
        qp, kp, vp,
        causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=bq, block_k=bk, kv_len=Sk, interpret=interpret,
    )
    return out[:, :, :Sq, :]
