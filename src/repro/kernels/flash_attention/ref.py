"""Pure-jnp oracle for flash attention (full softmax, fp32)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kf)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_idx = jnp.arange(Sq)[:, None]
    k_idx = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window is not None:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
