"""Pallas TPU kernel: blocked flash attention (online softmax).

Supports the attention variants the assigned architectures need:
  * causal masking (decoder LMs)
  * sliding-window locality (gemma2 local layers, recurrentgemma)
  * logit soft-capping (gemma2)
  * GQA: Hq query heads read Hq/Hkv-grouped KV heads via the BlockSpec
    index map — KV blocks are never materialized per-query-head.

Grid: (B, Hq, Sq/bq, Sk/bk); the innermost axis streams KV blocks while
(m, l, acc) running statistics live in VMEM scratch, so scores are
never materialized in HBM — the O(S^2) term exists only as compute.
VMEM per program (bq=bk=512, D=128, fp32): q/k/v blocks ~0.8 MiB +
acc/stats ~0.5 MiB, well inside the v5e 16 MiB budget.

Fully-masked KV blocks (beyond the causal frontier or outside the
window) skip their FLOPs via pl.when; a production grid would also skip
their DMAs (noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compiler_params

_CompilerParams = pallas_compiler_params()

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, softcap, block_q, block_k, kv_len, num_k_blocks,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    q_idx = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_idx = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: entirely above the causal diagonal / outside window
    run = jnp.asarray(True)
    if causal:
        run = run & (k_start <= q_start + block_q - 1)
    if window is not None:
        run = run & (k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = k_idx < kv_len
        if causal:
            mask &= k_idx <= q_idx
        if window is not None:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]                                # (bq, 128)
        m_cur = jnp.max(s, axis=1, keepdims=True)          # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)                 # broadcast
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, :1])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha[:, :1] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...][:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale",
        "block_q", "block_k", "kv_len", "interpret",
    ),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    kv_len: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0.

    Sq/Sk must be multiples of block_q/block_k (ops.py pads); `kv_len`
    is the true (pre-padding) KV length masked inside the kernel.
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, Dv = v.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    if kv_len is None:
        kv_len = Sk
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    kernel = functools.partial(
        _flash_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, kv_len=kv_len, num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
