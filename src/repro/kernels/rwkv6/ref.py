"""Pure-jnp oracle for the RWKV-6 wkv recurrence (lax.scan, fp32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rwkv6_ref"]


def rwkv6_ref(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    s0: jax.Array | None = None, return_state: bool = False,
):
    """r/k/v/w: (BH, T, N); u: (BH, N). Returns (BH, T, N) [, final state]."""
    BH, T, N = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # each (BH, N)
        kv = kt[:, :, None] * vt[:, None, :]              # (BH, N, N)
        y = jnp.sum(
            (s + uf[:, :, None] * kv) * rt[:, :, None], axis=1
        )                                                  # (BH, N)
        s = wt[:, :, None] * s + kv
        return s, y

    if s0 is None:
        s0 = jnp.zeros((BH, N, N), jnp.float32)
    s_fin, ys = jax.lax.scan(
        step,
        s0,
        (
            rf.transpose(1, 0, 2),
            kf.transpose(1, 0, 2),
            vf.transpose(1, 0, 2),
            wf.transpose(1, 0, 2),
        ),
    )
    out = ys.transpose(1, 0, 2).astype(r.dtype)
    if return_state:
        return out, s_fin
    return out
