"""Jitted entry point for the RWKV-6 wkv kernel with padding/dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import rwkv6_pallas
from .ref import rwkv6_ref

__all__ = ["rwkv6_wkv"]


@functools.partial(
    jax.jit, static_argnames=("block_t", "use_pallas", "interpret", "unroll")
)
def rwkv6_wkv(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    block_t: int = 256,
    use_pallas: bool = True,
    interpret: bool = False,
    unroll: bool = False,
) -> jax.Array:
    """RWKV-6 wkv over (BH, T, N) streams; u is the (BH, N) bonus."""
    if not use_pallas:
        # time-chunked + remat: a bare scan saves the (BH, N, N) state
        # at EVERY timestep for backward — 134 GiB/chip on the rwkv6-3b
        # train cell (§Perf M11); chunking saves it per chunk only
        BH, T, N = r.shape
        bt = min(block_t, T)
        if T <= bt or T % bt != 0:
            return rwkv6_ref(r, k, v, w, u)
        n = T // bt

        def chunk(s, inp):
            rc, kc, vc, wc = inp
            out, s_new = rwkv6_ref(rc, kc, vc, wc, u, s0=s, return_state=True)
            return s_new, out

        split = lambda a: a.reshape(BH, n, bt, N).swapaxes(0, 1)
        s0 = jnp.zeros((BH, N, N), jnp.float32)
        _, ys = jax.lax.scan(
            jax.checkpoint(chunk), s0, (split(r), split(k), split(v), split(w)),
            unroll=True if unroll else 1,
        )
        return ys.swapaxes(0, 1).reshape(BH, T, N)
    BH, T, N = r.shape
    bt = min(block_t, T)
    pad = (-T) % bt
    if pad:
        # padded steps: w=1 (no decay), k=0 (no state write) — outputs
        # beyond T are cropped
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    out = rwkv6_pallas(r, k, v, w, u, block_t=bt, interpret=interpret)
    return out[:, :T, :]
