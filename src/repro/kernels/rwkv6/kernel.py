"""Pallas TPU kernel: RWKV-6 (Finch) wkv recurrence.

Per head of width N, with data-dependent per-channel decay w_t and a
current-token bonus u:

    y_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

The (N, N) state S stays resident in VMEM scratch for the whole
sequence; time is streamed in blocks along the innermost ("arbitrary")
grid axis, so HBM traffic is O(T*N) instead of the O(T*N^2) a naive
scan materializing states would need.

Grid: (B*H, T/bt).  VMEM per program (N=64..128, bt=256, fp32):
state N^2 + 4 input blocks bt*N + out bt*N ~ 0.4-0.7 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compiler_params

_CompilerParams = pallas_compiler_params()

__all__ = ["rwkv6_pallas"]


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *, block_t):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)          # (N,) current-token bonus

    def step(t, _):
        r = r_ref[0, t].astype(jnp.float32)   # (N,)
        k = k_ref[0, t].astype(jnp.float32)
        v = v_ref[0, t].astype(jnp.float32)
        w = w_ref[0, t].astype(jnp.float32)
        s = s_ref[...]                        # (N, N) keys x values
        kv = k[:, None] * v[None, :]          # (N, N)
        y = jnp.sum((s + u[:, None] * kv) * r[:, None], axis=0)
        o_ref[0, t] = y.astype(o_ref.dtype)
        s_ref[...] = w[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, block_t, step, 0)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    block_t: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """r/k/v/w: (BH, T, N); u: (BH, N) per-head bonus. Returns (BH, T, N).

    T must be a multiple of block_t (ops.py pads with w=1, k=0 so padded
    steps neither decay nor write the state).
    """
    BH, T, N = r.shape
    assert T % block_t == 0, (T, block_t)
    grid = (BH, T // block_t)
    blk = pl.BlockSpec((1, block_t, N), lambda b, t: (b, t, 0))
    return pl.pallas_call(
        functools.partial(_wkv_kernel, block_t=block_t),
        grid=grid,
        in_specs=[blk, blk, blk, blk, pl.BlockSpec((1, N), lambda b, t: (b, 0))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((BH, T, N), r.dtype),
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u)
