from .kernel import rwkv6_pallas
from .ops import rwkv6_wkv
from .ref import rwkv6_ref

__all__ = ["rwkv6_pallas", "rwkv6_ref", "rwkv6_wkv"]
