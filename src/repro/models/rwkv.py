"""RWKV-6 "Finch" block (rwkv6-3b): attention-free time mix with
data-dependent per-channel decay + squared-ReLU channel mix.

Time-mix (per head of width N):
    y_t = (S_{t-1} + (u * k_t) v_t^T)^T r_t,   S_t = diag(w_t) S_{t-1} + k_t v_t^T
with w_t = exp(-exp(w0 + tanh(x_w A) B)) — the defining Finch feature
(data-dependent decay, paper arXiv:2404.05892).  r/k/v/g use static
token-shift lerps; the decay path carries the low-rank data-dependent
delta.  The wkv recurrence lowers through `repro.kernels.rwkv6` (lax.scan
oracle on non-TPU hosts, Pallas kernel on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import P_, dense

__all__ = [
    "rwkv_params", "rwkv_time_mix", "rwkv_channel_mix",
    "rwkv_time_mix_decode", "rwkv_channel_mix_decode", "init_rwkv_state",
]

_DECAY_LORA = 64


def rwkv_params(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "time": {
            "mu_r": P_((D,), P("model"), init="normal", scale=0.2),
            "mu_k": P_((D,), P("model"), init="normal", scale=0.2),
            "mu_v": P_((D,), P("model"), init="normal", scale=0.2),
            "mu_g": P_((D,), P("model"), init="normal", scale=0.2),
            "mu_w": P_((D,), P("model"), init="normal", scale=0.2),
            "wr": P_((D, D), P("data", "model")),
            "wk": P_((D, D), P("data", "model")),
            "wv": P_((D, D), P("data", "model")),
            "wg": P_((D, D), P("data", "model")),
            "w0": P_((D,), P("model"), init="normal", scale=0.5),
            "wa": P_((D, _DECAY_LORA), P("data", None), scale=0.5),
            "wb": P_((_DECAY_LORA, D), P(None, "model"), scale=0.5),
            "u": P_((H, N), P("model", None), init="normal", scale=0.2),
            "ln_scale": P_((D,), P("model"), init="ones", dtype="float32"),
            "wo": P_((D, D), P("model", "data")),
        },
        "channel": {
            "mu_k": P_((D,), P("model"), init="normal", scale=0.2),
            "mu_r": P_((D,), P("model"), init="normal", scale=0.2),
            "wk": P_((D, F), P("data", "model")),
            "wv": P_((F, D), P("model", "data")),
            "wr": P_((D, D), P("data", "model")),
        },
    }


def _shift(x, prev=None):
    """Token shift: x_{t-1} (zeros / `prev` at t=0). x: (B,S,D)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _decay(p, xw):
    lora = jnp.einsum(
        "bsd,dk->bsk", jnp.tanh(jnp.einsum("bsd,dk->bsk", xw, p["wa"])), p["wb"]
    )
    return jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32)))


def _group_norm(y, scale, H, N, eps=1e-5):
    """Per-head layernorm of the wkv output (B,S,H,N)."""
    yf = y.astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu) * jax.lax.rsqrt(var + eps)
    return (yn.reshape(*y.shape[:2], H * N) * scale).astype(y.dtype)


def rwkv_time_mix(
    p: dict, cfg: ModelConfig, x: jax.Array, *, use_pallas: bool = False
) -> jax.Array:
    from repro.kernels.rwkv6 import rwkv6_wkv

    B, S, D = x.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    sx = _shift(x) - x
    xr = x + sx * p["mu_r"]
    xk = x + sx * p["mu_k"]
    xv = x + sx * p["mu_v"]
    xg = x + sx * p["mu_g"]
    xw = (x + sx * p["mu_w"]).astype(jnp.float32)
    r = dense(xr, p["wr"])
    k = dense(xk, p["wk"])
    v = dense(xv, p["wv"])
    g = jax.nn.silu(dense(xg, p["wg"]))
    w = _decay(p, xw)                                       # (B,S,D) in (0,1)

    def to_bh(a):  # (B,S,D) -> (B*H, S, N)
        return a.reshape(B, S, H, N).transpose(0, 2, 1, 3).reshape(B * H, S, N)

    u = jnp.broadcast_to(p["u"][None], (B, H, N)).reshape(B * H, N)
    # the decay stays fp32: bf16-rounding w compounds through the state
    y = rwkv6_wkv(
        to_bh(r), to_bh(k), to_bh(v), to_bh(w), u.astype(r.dtype),
        use_pallas=use_pallas, unroll=cfg.scan_unroll,
    )                                                        # (B*H, S, N)
    y = y.reshape(B, H, S, N).transpose(0, 2, 1, 3)          # (B,S,H,N)
    y = _group_norm(y, p["ln_scale"], H, N)
    return dense(y * g, p["wo"])


def rwkv_channel_mix(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    sx = _shift(x) - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"])))
    return jax.nn.sigmoid(dense(xr, p["wr"])) * dense(k, p["wv"])


# ------------------------------ decode --------------------------------


def init_rwkv_state(cfg: ModelConfig, batch: int) -> dict:
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "tm_prev": jnp.zeros((batch, 1, cfg.d_model), dt),
        "cm_prev": jnp.zeros((batch, 1, cfg.d_model), dt),
        "wkv": jnp.zeros((batch * H, N, N), jnp.float32),
    }


def rwkv_time_mix_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """x: (B, 1, D); O(1) state update."""
    B, _, D = x.shape
    H, N = cfg.rwkv_heads, cfg.rwkv_head_dim
    sx = state["tm_prev"] - x
    xr, xk, xv, xg = (x + sx * p[m] for m in ("mu_r", "mu_k", "mu_v", "mu_g"))
    xw = (x + sx * p["mu_w"]).astype(jnp.float32)
    r = dense(xr, p["wr"]).reshape(B * H, N)
    k = dense(xk, p["wk"]).reshape(B * H, N).astype(jnp.float32)
    v = dense(xv, p["wv"]).reshape(B * H, N).astype(jnp.float32)
    g = jax.nn.silu(dense(xg, p["wg"]))
    w = _decay(p, xw).reshape(B * H, N)
    u = jnp.broadcast_to(p["u"][None], (B, H, N)).reshape(B * H, N).astype(jnp.float32)
    s = state["wkv"]                                        # (BH, N, N)
    kv = k[:, :, None] * v[:, None, :]
    y = jnp.einsum("bnm,bn->bm", s + u[:, :, None] * kv, r.astype(jnp.float32))
    s_new = w[:, :, None] * s + kv
    y = y.reshape(B, 1, H, N).astype(x.dtype)
    y = _group_norm(y, p["ln_scale"], H, N)
    out = dense((y * g).astype(x.dtype), p["wo"])
    return out, {**state, "tm_prev": x, "wkv": s_new}


def rwkv_channel_mix_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    sx = state["cm_prev"] - x
    xk = x + sx * p["mu_k"]
    xr = x + sx * p["mu_r"]
    k = jnp.square(jax.nn.relu(dense(xk, p["wk"])))
    out = jax.nn.sigmoid(dense(xr, p["wr"])) * dense(k, p["wv"])
    return out, {**state, "cm_prev": x}
