"""Parameter descriptors and basic layers (pure JAX, no framework dep).

A model is declared once as a tree of `P_` descriptors (shape +
PartitionSpec + init); the same tree materializes real params
(`init_tree`), abstract params for the dry-run (`abstract_tree`), and
the sharding tree (`spec_tree`).  Sharding uses two logical mesh axes:
"data" (FSDP/ZeRO shard axis) and "model" (tensor-parallel axis); the
multi-pod "pod" axis replicates params and enters only through input
batch sharding and gradient synchronization (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "P_", "init_tree", "abstract_tree", "spec_tree", "count_params",
    "rms_norm", "layer_norm", "rope", "mrope", "mlp",
    "dense", "constrain_act", "DTYPES",
]

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class P_:
    """Parameter descriptor: shape, partition spec, init kind."""

    shape: tuple[int, ...]
    spec: P = P()
    init: str = "fan_in"     # fan_in | zeros | ones | normal | embed
    scale: float = 1.0
    dtype: Optional[str] = None  # override model dtype (e.g. fp32 norms)

    def initialize(self, key, default_dtype):
        dt = DTYPES[self.dtype] if self.dtype else default_dtype
        if self.init == "zeros":
            return jnp.zeros(self.shape, dt)
        if self.init == "ones":
            return jnp.ones(self.shape, dt)
        if self.init == "embed":
            sd = 1.0
        elif self.init == "normal":
            sd = self.scale
        else:  # fan_in
            fan_in = self.shape[0] if len(self.shape) >= 2 else max(self.shape[-1], 1)
            if len(self.shape) == 3:  # (heads, in, out) style or (E, in, out)
                fan_in = self.shape[1]
            sd = self.scale / math.sqrt(fan_in)
        return (jax.random.normal(key, self.shape, jnp.float32) * sd).astype(dt)

    def abstract(self, default_dtype):
        dt = DTYPES[self.dtype] if self.dtype else default_dtype
        return jax.ShapeDtypeStruct(self.shape, dt)


def _is_leaf(x):
    return isinstance(x, P_)


def init_tree(tree, key, dtype):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))
    vals = [l.initialize(k, dtype) for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(tree, dtype):
    return jax.tree.map(lambda l: l.abstract(dtype), tree, is_leaf=_is_leaf)


def spec_tree(tree):
    return jax.tree.map(lambda l: l.spec, tree, is_leaf=_is_leaf)


def count_params(tree) -> int:
    return sum(
        int(math.prod(l.shape))
        for l in jax.tree.leaves(tree, is_leaf=_is_leaf)
    )


# ----------------------------- layers ---------------------------------


def current_mesh():
    """Version-portable mesh-in-context lookup, mirroring
    `launch.mesh.set_mesh`: prefer the abstract mesh installed by
    `jax.set_mesh`/`use_mesh` when one is actually set, else the
    physical thread-resources mesh that `with mesh:` (0.4.x fallback)
    sets — so a non-empty mesh is found on every jax version rather
    than an empty abstract mesh shadowing an active physical one.
    Callers tolerate None / an empty mesh (constraints become no-ops)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    try:  # private fallback; jax has relocated thread_resources before
        from jax._src import mesh as _mesh_lib

        return _mesh_lib.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None


def constrain_act(x, dp, axis: int = -1):
    """Shard an activation's last dim over "model" (and dim 0 over dp)
    when a mesh is in context and the dims divide; no-op otherwise."""
    if dp is None:
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.shape:
        return x
    spec = [None] * x.ndim
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    if x.shape[0] % dp_size == 0:
        spec[0] = dp
    if x.shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def rms_norm(x, scale, eps):
    """Stats in fp32; the (B,S,D) tensor itself stays in model dtype.
    The mean-square reduces through a dot with fp32 accumulation, so no
    fp32 copy of x ever materializes (§Perf M5/M9)."""
    sq = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    )
    var = (sq / x.shape[-1])[..., None]
    factor = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * factor * (1.0 + scale).astype(x.dtype)


def layer_norm(x, scale, bias, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu)
    factor = jax.lax.rsqrt(var + eps)
    out = (x - mu.astype(x.dtype)) * factor.astype(x.dtype)
    return out * scale.astype(x.dtype) + bias.astype(x.dtype)


def dense(x, w):
    """x: (..., in), w: (in, out) in the model dtype.  No forced fp32
    output: the MXU accumulates in fp32 regardless, and a forced
    preferred_element_type=f32 materializes an fp32 copy of every
    activation in the lowered module (§Perf M5)."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
    )


def _rope_angles(positions, dims, theta):
    """positions: (..., S) int; returns cos/sin (..., S, dims//2) fp32."""
    half = dims // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def rope(x, positions, theta):
    """x: (B, H, S, D); positions: (B, S). Rotates pairs (even, odd)."""
    B, H, S, D = x.shape
    cos, sin = _rope_angles(positions, D, theta)     # (B, S, D/2)
    cos, sin = cos[:, None], sin[:, None]            # (B, 1, S, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope(x, positions, theta, sections):
    """Multimodal RoPE (qwen2-vl): positions (B, S, 3) = (t, h, w) ids;
    the D/2 rotary frequencies are split into 3 sections, each rotated
    by its own position stream."""
    B, H, S, D = x.shape
    half = D // 2
    assert sum(sections) == half, (sections, half)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # pick the position stream per frequency index
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half
    )                                                 # (half,)
    pos = positions.astype(jnp.float32)               # (B, S, 3)
    pos_per_freq = jnp.take_along_axis(
        pos[..., None, :], sec_id[None, None, :, None].astype(jnp.int32), axis=-1
    )[..., 0]                                         # (B, S, half)
    ang = pos_per_freq * freq
    cos, sin = jnp.cos(ang)[:, None], jnp.sin(ang)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------- MLP -----------------------------------


def mlp_params(d_model: int, d_ff: int, kind: str) -> dict:
    if kind in ("swiglu", "geglu"):
        return {
            "wi": P_((d_model, d_ff), P("data", "model")),
            "wg": P_((d_model, d_ff), P("data", "model")),
            "wo": P_((d_ff, d_model), P("model", "data")),
        }
    return {  # plain gelu (whisper)
        "wi": P_((d_model, d_ff), P("data", "model")),
        "wo": P_((d_ff, d_model), P("model", "data")),
    }


def mlp(x, params, kind: str):
    if kind == "swiglu":
        return dense(jax.nn.silu(dense(x, params["wg"])) * dense(x, params["wi"]),
                     params["wo"])
    if kind == "geglu":
        return dense(
            jax.nn.gelu(dense(x, params["wg"]), approximate=True)
            * dense(x, params["wi"]),
            params["wo"],
        )
    return dense(jax.nn.gelu(dense(x, params["wi"]), approximate=True), params["wo"])
