"""Unified transformer covering the 10 assigned architectures.

The decoder stack is expressed as scan-over-layers per homogeneous
pattern group (cfg.scan_groups) with per-group stacked parameters —
compile-time stays flat in depth, remat wraps each pattern unit, and
the residual stream is sharding-constrained at unit boundaries to
P(dp, None, "model") so saved activations are fully sharded (DESIGN §6).

Entry points:
  Transformer(cfg)           — descriptor tree, init/abstract/specs
  forward(params, cfg, batch)      — logits (train / prefill)
  loss_fn(params, cfg, batch)      — mean next-token CE
  init_cache / decode_step         — single-token serving
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import (
    attention, attn_params, decode_attention, init_kv_cache,
    init_paged_kv_cache, paged_decode_attention,
)
from .config import ModelConfig
from .layers import (
    P_, abstract_tree, count_params, current_mesh, dense, init_tree,
    layer_norm, mlp, mlp_params, rms_norm, spec_tree, DTYPES,
)
from .moe import moe_ffn, moe_params
from .rglru import (
    init_rglru_state, rglru_block, rglru_decode, rglru_params,
)
from .rwkv import (
    init_rwkv_state, rwkv_channel_mix, rwkv_channel_mix_decode, rwkv_params,
    rwkv_time_mix, rwkv_time_mix_decode,
)

__all__ = [
    "Transformer", "forward", "loss_fn", "init_cache", "decode_step",
    "init_paged_cache", "paged_decode_step",
]

DP_DEFAULT = ("data",)


# --------------------------- parameter tree ---------------------------


def _norm_params(cfg: ModelConfig, kind: str) -> dict:
    if kind == "rwkv":  # LayerNorm with bias
        return {
            "scale": P_((cfg.d_model,), P("model"), init="ones", dtype="float32"),
            "bias": P_((cfg.d_model,), P("model"), init="zeros", dtype="float32"),
        }
    return {"scale": P_((cfg.d_model,), P("model"), init="zeros", dtype="float32")}


def _apply_norm(p: dict, cfg: ModelConfig, x, kind: str):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def block_params(cfg: ModelConfig, kind: str, *, cross: bool = False,
                 model_axis: int = 16) -> dict:
    d: dict = {"ln1": _norm_params(cfg, kind), "ln2": _norm_params(cfg, kind)}
    if kind in ("attn", "local"):
        d["attn"] = attn_params(cfg)
        if cross:
            d["xattn"] = attn_params(cfg, cross=True)
            d["lnx"] = _norm_params(cfg, kind)
        if cfg.num_experts:
            d["moe"] = moe_params(cfg, model_axis)
        else:
            d["mlp"] = mlp_params(cfg.d_model, cfg.d_ff, cfg.mlp_kind)
        if cfg.post_norms:
            d["post1"] = _norm_params(cfg, kind)
            d["post2"] = _norm_params(cfg, kind)
    elif kind == "rglru":
        d["rglru"] = rglru_params(cfg)
        d["mlp"] = mlp_params(cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    elif kind == "rwkv":
        d.update(rwkv_params(cfg))
    else:
        raise ValueError(kind)
    return d


def _stack_descr(tree, repeats: int):
    def f(l: P_) -> P_:
        return P_(
            (repeats,) + l.shape, P(None, *l.spec), l.init, l.scale, l.dtype
        )
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, P_))


def model_params(cfg: ModelConfig, model_axis: int = 16) -> dict:
    V, D = cfg.vocab_size, cfg.d_model
    tree: dict = {
        "embed": P_((V, D), P("model", "data"), init="embed"),
        "final_norm": _norm_params(cfg, "attn"),
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = P_((D, V), P("data", "model"))
    groups = []
    for unit, repeats in cfg.scan_groups():
        unit_tree = {
            f"b{i}": block_params(
                cfg, kind, cross=cfg.encoder_layers > 0, model_axis=model_axis
            )
            for i, kind in enumerate(unit)
        }
        groups.append(_stack_descr(unit_tree, repeats))
    tree["groups"] = groups
    if cfg.encoder_layers:
        enc_unit = {"b0": block_params(cfg, "attn", model_axis=model_axis)}
        tree["encoder"] = {
            "blocks": _stack_descr(enc_unit, cfg.encoder_layers),
            "final_norm": _norm_params(cfg, "attn"),
        }
    return tree


# ------------------------------ forward -------------------------------


def _constrain(x, dp):
    if dp is None:                       # decentralized per-replica mode
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty:       # single-device smoke tests
        return x
    spec = (
        P(dp, None, "model")
        if "model" in mesh.shape and x.shape[-1] % mesh.shape["model"] == 0
        else P(dp)
    )
    return jax.lax.with_sharding_constraint(x, spec)


def _block_forward(p, cfg: ModelConfig, kind: str, x, positions, *,
                   memory=None, causal=True, dp=DP_DEFAULT):
    if kind in ("attn", "local"):
        h = attention(
            p["attn"], cfg, _apply_norm(p["ln1"], cfg, x, kind), positions,
            kind=kind, causal=causal, dp=dp,
        )
        if cfg.post_norms:
            h = _apply_norm(p["post1"], cfg, h, kind)
        x = x + h
        if memory is not None and "xattn" in p:
            x = x + attention(
                p["xattn"], cfg, _apply_norm(p["lnx"], cfg, x, kind), positions,
                memory=memory, dp=dp,
            )
        z = _apply_norm(p["ln2"], cfg, x, kind)
        h = (moe_ffn(p["moe"], cfg, z, dp=dp) if cfg.num_experts
             else mlp(z, p["mlp"], cfg.mlp_kind))
        if cfg.post_norms:
            h = _apply_norm(p["post2"], cfg, h, kind)
        return x + h
    if kind == "rglru":
        x = x + rglru_block(p["rglru"], cfg, _apply_norm(p["ln1"], cfg, x, kind),
                            dp=dp)
        return x + mlp(_apply_norm(p["ln2"], cfg, x, kind), p["mlp"], cfg.mlp_kind)
    if kind == "rwkv":
        x = x + rwkv_time_mix(p["time"], cfg, _apply_norm(p["ln1"], cfg, x, kind))
        return x + rwkv_channel_mix(p["channel"], cfg, _apply_norm(p["ln2"], cfg, x, kind))
    raise ValueError(kind)


def _run_groups(params, cfg: ModelConfig, x, positions, *, memory=None,
                causal=True, dp=DP_DEFAULT):
    for g_idx, (unit, repeats) in enumerate(cfg.scan_groups()):
        gp = params["groups"][g_idx]

        def unit_fn(h, layer_p, unit=unit):
            for i, kind in enumerate(unit):
                h = _block_forward(
                    layer_p[f"b{i}"], cfg, kind, h, positions,
                    memory=memory, causal=causal, dp=dp,
                )
            return _constrain(h, dp), None

        f = jax.checkpoint(unit_fn) if cfg.remat else unit_fn
        x, _ = jax.lax.scan(f, x, gp, unroll=True if cfg.scan_unroll else 1)
    return x


def _embed(params, cfg: ModelConfig, tokens):
    e = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        e = e * jnp.asarray(cfg.d_model**0.5, e.dtype)
    return e.astype(DTYPES[cfg.dtype])


def _unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = jax.lax.dot_general(
            x, params["embed"], (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # T5/PaLM tied-head scaling: this repo's embed init is unit-variance
        # (see layers.P_), so against RMS-1 activations the raw tied product
        # emits std-sqrt(D) logits (loss ~3x ln V at init, huge per-batch
        # variance, and any final_logit_softcap saturated from step 0);
        # 1/sqrt(D) restores unit-scale logits for every from-scratch run.
        # If a reference-checkpoint import path is ever added, this pairs
        # with the init and must become per-config alongside it.
        logits = logits * jnp.asarray(cfg.d_model**-0.5, jnp.float32)
    else:
        logits = dense(x, params["unembed"]).astype(jnp.float32)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _encode(params, cfg: ModelConfig, frames, dp):
    """Whisper-style encoder over precomputed frame embeddings (stub
    frontend) with sinusoidal positions and non-causal attention."""
    B, S, D = frames.shape
    pos = jnp.arange(S)
    half = D // 2
    freq = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (9.21 / max(half - 1, 1)))
    ang = pos[:, None].astype(jnp.float32) * freq[None]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    x = (frames.astype(jnp.float32) + pe[None]).astype(DTYPES[cfg.dtype])
    positions = jnp.broadcast_to(pos[None], (B, S))
    enc = params["encoder"]

    def unit_fn(h, layer_p):
        h = _block_forward(layer_p["b0"], cfg, "attn", h, positions,
                           causal=False, dp=dp)
        return _constrain(h, dp), None

    f = jax.checkpoint(unit_fn) if cfg.remat else unit_fn
    x, _ = jax.lax.scan(f, x, enc["blocks"], unroll=True if cfg.scan_unroll else 1)
    return _apply_norm(enc["final_norm"], cfg, x, "attn")


def _hidden(params, cfg: ModelConfig, batch: dict, *, dp=DP_DEFAULT):
    """Backbone through the final norm (pre-unembed)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    if cfg.mrope_sections is not None:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    memory = None
    if cfg.encoder_layers:
        memory = _encode(params, cfg, batch["frames"], dp)
    x = _constrain(_embed(params, cfg, tokens), dp)
    x = _run_groups(params, cfg, x, positions, memory=memory, dp=dp)
    return _apply_norm(params["final_norm"], cfg, x, "attn")


def forward(params, cfg: ModelConfig, batch: dict, *, dp=DP_DEFAULT):
    """batch: tokens (B,S) [+ positions (B,S,3) for M-RoPE,
    + frames (B,Se,D) for enc-dec]. Returns fp32 logits (B,S,V)."""
    return _unembed(params, cfg, _hidden(params, cfg, batch, dp=dp))


def loss_fn(params, cfg: ModelConfig, batch: dict, *, dp=DP_DEFAULT,
            loss_chunk: int = 512):
    """Mean next-token cross-entropy; labels < 0 are masked.

    The (tokens, vocab) fp32 logits never materialize for the whole
    sequence: unembed + CE run CHUNKED over the sequence under
    jax.checkpoint (recomputed in backward).  At vocab 256k this is the
    difference between ~70 GiB and <2 GiB of loss buffers per chip
    (EXPERIMENTS.md §Perf, iteration M1).
    """
    x = _hidden(params, cfg, batch, dp=dp)
    labels = batch["labels"]
    B, S, D = x.shape
    c = min(loss_chunk, S)
    pad = (-S) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (S + pad) // c
    xs = x.reshape(B, n, c, D).swapaxes(0, 1)          # (n, B, c, D)
    ls = labels.reshape(B, n, c).swapaxes(0, 1)        # (n, B, c)

    def chunk_nll(carry, xl):
        xc, lc = xl
        logits = _unembed(params, cfg, xc)             # (B, c, V) fp32
        mask = (lc >= 0).astype(jnp.float32)
        safe = jnp.maximum(lc, 0)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        s, m = carry
        return (s + ((logz - gold) * mask).sum(), m + mask.sum()), None

    (nll, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_nll), (jnp.zeros(()), jnp.zeros(())), (xs, ls),
        unroll=True if cfg.scan_unroll else 1,
    )
    return nll / jnp.maximum(cnt, 1.0)


# ------------------------------ serving -------------------------------


def init_cache(params, cfg: ModelConfig, batch: int, max_len: int,
               frames: Optional[jax.Array] = None, dp=DP_DEFAULT) -> dict:
    """Per-layer decode state, stacked to mirror the scan groups."""
    memory = None
    if cfg.encoder_layers:
        memory = _encode(params, cfg, frames, dp)

    def layer_state(kind):
        if kind in ("attn", "local"):
            return init_kv_cache(cfg, kind, batch, max_len)
        if kind == "rglru":
            return init_rglru_state(cfg, batch)
        return init_rwkv_state(cfg, batch)

    groups = []
    for unit, repeats in cfg.scan_groups():
        unit_state = {
            f"b{i}": layer_state(kind) for i, kind in enumerate(unit)
        }
        groups.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape),
                unit_state,
            )
        )
    return {
        "groups": groups,
        "step": jnp.zeros((), jnp.int32),
        "memory": memory,
    }


def _block_decode(p, cfg: ModelConfig, kind: str, x, state, step, memory):
    if kind in ("attn", "local"):
        h, new = decode_attention(
            p["attn"], cfg, _apply_norm(p["ln1"], cfg, x, kind), state, step,
            kind=kind,
        )
        if cfg.post_norms:
            h = _apply_norm(p["post1"], cfg, h, kind)
        x = x + h
        if memory is not None and "xattn" in p:
            zx = _apply_norm(p["lnx"], cfg, x, kind)
            hx = attention(p["xattn"], cfg, zx,
                           jnp.broadcast_to(step[None, None], (x.shape[0], 1)),
                           memory=memory)
            x = x + hx
        z = _apply_norm(p["ln2"], cfg, x, kind)
        h = moe_ffn(p["moe"], cfg, z) if cfg.num_experts else mlp(z, p["mlp"], cfg.mlp_kind)
        if cfg.post_norms:
            h = _apply_norm(p["post2"], cfg, h, kind)
        return x + h, new
    if kind == "rglru":
        h, new = rglru_decode(p["rglru"], cfg, _apply_norm(p["ln1"], cfg, x, kind), state)
        x = x + h
        return x + mlp(_apply_norm(p["ln2"], cfg, x, kind), p["mlp"], cfg.mlp_kind), new
    if kind == "rwkv":
        h, new_t = rwkv_time_mix_decode(
            p["time"], cfg, _apply_norm(p["ln1"], cfg, x, kind), state
        )
        x = x + h
        h, new_c = rwkv_channel_mix_decode(
            p["channel"], cfg, _apply_norm(p["ln2"], cfg, x, kind), new_t
        )
        return x + h, new_c
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                *, dp=DP_DEFAULT):
    """One serving step: tokens (B,) -> logits (B, V), updated cache."""
    B = tokens.shape[0]
    step = cache["step"]
    x = _embed(params, cfg, tokens[:, None])
    memory = cache["memory"]
    new_groups = []
    for g_idx, (unit, repeats) in enumerate(cfg.scan_groups()):
        gp = params["groups"][g_idx]
        gs = cache["groups"][g_idx]

        def unit_fn(h, inp, unit=unit):
            layer_p, layer_s = inp
            new_s = {}
            for i, kind in enumerate(unit):
                h, ns = _block_decode(
                    layer_p[f"b{i}"], cfg, kind, h, layer_s[f"b{i}"], step, memory
                )
                new_s[f"b{i}"] = ns
            return h, new_s

        x, ns = jax.lax.scan(
            unit_fn, x, (gp, gs), unroll=True if cfg.scan_unroll else 1
        )
        new_groups.append(ns)
    x = _apply_norm(params["final_norm"], cfg, x, "attn")
    logits = _unembed(params, cfg, x)[:, 0]
    new_cache = {"groups": new_groups, "step": step + 1, "memory": memory}
    return logits, new_cache


# --------------------------- paged serving ----------------------------


def init_paged_cache(
    cfg: ModelConfig, num_slots: int, num_pages: int, page_size: int
) -> dict:
    """Decode state for the paged/continuous-batching path.

    Attention layers share one page pool per layer (plus a trash page —
    see `attention.init_paged_kv_cache`); recurrent layers (rglru/rwkv)
    keep ordinary per-slot state that the engine re-initializes on
    admission via the step's `write_mask`.  Encoder-decoder configs are
    not paged (their decode state is per-request memory, not a KV pool).
    """
    if cfg.encoder_layers:
        raise ValueError(
            "paged serving supports decoder-only configs; "
            f"{cfg.name} has encoder layers"
        )

    def layer_state(kind):
        if kind in ("attn", "local"):
            return init_paged_kv_cache(cfg, num_pages, page_size)
        if kind == "rglru":
            return init_rglru_state(cfg, num_slots)
        return init_rwkv_state(cfg, num_slots)

    groups = []
    for unit, repeats in cfg.scan_groups():
        unit_state = {f"b{i}": layer_state(kind) for i, kind in enumerate(unit)}
        groups.append(
            jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape),
                unit_state,
            )
        )
    return {"groups": groups}


def _block_decode_paged(p, cfg: ModelConfig, kind: str, x, state,
                        page_map, steps, write_mask):
    if kind in ("attn", "local"):
        h, new = paged_decode_attention(
            p["attn"], cfg, _apply_norm(p["ln1"], cfg, x, kind), state,
            page_map, steps, write_mask, kind=kind,
        )
        if cfg.post_norms:
            h = _apply_norm(p["post1"], cfg, h, kind)
        x = x + h
        z = _apply_norm(p["ln2"], cfg, x, kind)
        h = (moe_ffn(p["moe"], cfg, z) if cfg.num_experts
             else mlp(z, p["mlp"], cfg.mlp_kind))
        if cfg.post_norms:
            h = _apply_norm(p["post2"], cfg, h, kind)
        return x + h, new
    # recurrent layers: per-slot (B, ...) state — zero a slot's state at
    # the first token of a fresh admission (init state is all-zeros, so
    # slot reuse cannot leak the previous request's recurrence), run the
    # dense decode body, then hold back non-written slots' updates
    def bmask(m, a):
        if a.shape[0] != m.shape[0]:      # rwkv wkv state is (B*H, N, N)
            m = jnp.repeat(m, a.shape[0] // m.shape[0])
        return m.reshape((-1,) + (1,) * (a.ndim - 1))

    fresh = write_mask & (steps == 0)
    state = jax.tree.map(
        lambda o: jnp.where(bmask(fresh, o), jnp.zeros((), o.dtype), o),
        state,
    )
    h, new = _block_decode(p, cfg, kind, x, state, steps, None)
    return h, jax.tree.map(
        lambda n, o: jnp.where(bmask(write_mask, n), n, o), new, state
    )


def paged_decode_step(
    params,
    cfg: ModelConfig,
    cache: dict,               # from init_paged_cache
    tokens: jax.Array,         # (B,) current token per slot
    page_map: jax.Array,       # (B, P) int32 physical page ids (trash = N)
    steps: jax.Array,          # (B,) int32 per-slot absolute position
    write_mask: jax.Array,     # (B,) bool — gate KV writes / state updates
):
    """One continuous-batching step: every slot decodes its own position.

    Identical math to `decode_step` per live slot (bitwise on the lax
    path when P * page_size == the dense cache's max_len); masked slots
    write to the trash page and keep their recurrent state, so one
    compiled step serves any admit/retire pattern.
    """
    x = _embed(params, cfg, tokens[:, None])
    new_groups = []
    for g_idx, (unit, repeats) in enumerate(cfg.scan_groups()):
        gp = params["groups"][g_idx]
        gs = cache["groups"][g_idx]

        def unit_fn(h, inp, unit=unit):
            layer_p, layer_s = inp
            new_s = {}
            for i, kind in enumerate(unit):
                h, ns = _block_decode_paged(
                    layer_p[f"b{i}"], cfg, kind, h, layer_s[f"b{i}"],
                    page_map, steps, write_mask,
                )
                new_s[f"b{i}"] = ns
            return h, new_s

        x, ns = jax.lax.scan(
            unit_fn, x, (gp, gs), unroll=True if cfg.scan_unroll else 1
        )
        new_groups.append(ns)
    x = _apply_norm(params["final_norm"], cfg, x, "attn")
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, {"groups": new_groups}


# ------------------------------ facade --------------------------------


@dataclasses.dataclass
class Transformer:
    cfg: ModelConfig
    model_axis: int = 16

    def __post_init__(self):
        self.cfg.validate()
        self.descr = model_params(self.cfg, self.model_axis)

    def init(self, key):
        return init_tree(self.descr, key, DTYPES[self.cfg.dtype])

    def abstract(self):
        return abstract_tree(self.descr, DTYPES[self.cfg.dtype])

    def specs(self):
        return spec_tree(self.descr)

    @property
    def num_params(self) -> int:
        return count_params(self.descr)
