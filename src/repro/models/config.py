"""Model configuration covering the 10 assigned architectures.

One `ModelConfig` describes any member of the zoo; per-arch files in
`repro.configs` instantiate it with the published numbers.  Layer
patterns are expressed as a repeating unit of block kinds so the stack
can be lowered as scan-over-layers per homogeneous group (compile-time
control at 500k-seq / 80-layer scale).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: Optional[int] = None      # None => MHA
    head_dim: Optional[int] = None          # None => d_model // num_heads

    # --- attention variants ---
    rope_theta: float = 10_000.0
    window: Optional[int] = None            # sliding-window width
    attn_logit_softcap: Optional[float] = None   # gemma2 attention softcap
    final_logit_softcap: Optional[float] = None  # gemma2 LM-head softcap
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    query_scale: Optional[float] = None     # gemma2: (d_model/num_heads)^-0.5

    # --- per-layer pattern; one entry per layer in the repeating unit ---
    # kinds: "attn" (global), "local" (sliding window), "rglru", "rwkv"
    block_unit: tuple[str, ...] = ("attn",)

    # --- mlp ---
    mlp_kind: str = "swiglu"                # swiglu | geglu | gelu
    post_norms: bool = False                # gemma2: post-sublayer RMSNorms

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500                 # whisper: 30 s of 10 ms frames / 2

    # --- ssm details ---
    rglru_conv_width: int = 4
    rwkv_head_dim: int = 64

    # --- modality frontend stub ---
    frontend: str = "none"                  # none | audio_frames | vision_patches

    # --- embedding / norm / numerics ---
    scale_embeddings: bool = False          # gemma: * sqrt(d_model)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # --- training-memory policy (per-arch; see DESIGN.md §6) ---
    optimizer: str = "adamw"                # adamw | adafactor
    remat: bool = True
    scan_unroll: bool = False               # unroll scan-over-layers (the
                                            # dry-run's depth variants use
                                            # this for loop-aware costing)

    # ------------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def head_width(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def layer_kinds(self) -> tuple[str, ...]:
        """Expanded per-layer kind list of length num_layers (decoder)."""
        unit = self.block_unit
        kinds = tuple(unit[i % len(unit)] for i in range(self.num_layers))
        return kinds

    def scan_groups(self) -> list[tuple[tuple[str, ...], int]]:
        """(unit, repeats) groups covering layer_kinds(); the trailing
        partial unit (if any) becomes its own group of repeat 1."""
        unit = self.block_unit
        full, rem = divmod(self.num_layers, len(unit))
        groups: list[tuple[tuple[str, ...], int]] = []
        if full:
            groups.append((unit, full))
        if rem:
            groups.append((unit[:rem], 1))
        return groups

    @property
    def is_attention_free(self) -> bool:
        return all(k in ("rglru", "rwkv") for k in self.layer_kinds())

    @property
    def supports_long_context(self) -> bool:
        """True when no layer needs an unbounded KV cache (SSM / hybrid
        with bounded local windows) — the long_500k eligibility rule."""
        for kind in self.layer_kinds():
            if kind == "attn":
                return False
            if kind == "local" and (self.window is None):
                return False
        return True

    def validate(self) -> None:
        assert self.d_model % self.num_heads == 0 or self.head_dim
        assert self.num_heads % self.kv_heads == 0, "GQA grouping"
        if self.num_experts:
            assert self.experts_per_token >= 1
        if "local" in self.block_unit:
            assert self.window is not None
        if self.encoder_layers:
            assert self.frontend == "audio_frames"
        if self.mrope_sections is not None:
            assert sum(self.mrope_sections) == self.head_width // 2
