"""Mixture-of-experts FFN (llama4-maverick top-1 x 128e, grok-1 top-2 x 8e).

Sort-based dispatch with a static per-expert capacity (MaxText-style):
token->expert assignments are sorted by expert id, each token gets its
rank within its expert group, tokens beyond capacity are dropped (their
residual passes through — standard capacity-drop semantics).  Expert
weights are laid out (E, din, dout) with experts sharded over "model"
when divisible (EP) and the hidden dim sharded otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import P_, current_mesh

__all__ = ["moe_params", "moe_ffn"]


def _constrain_tokens(x, dp):
    """Shard a (T, ...) flattened-token tensor over dp on dim 0."""
    if dp is None:
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty:
        return x
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape.get(a, 1)
    if x.shape[0] % dp_size != 0:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 1)))
    )


def _constrain_bsd(x, dp):
    """Shard a (B, S, D) tensor over dp on batch (post-combine)."""
    if dp is None:
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty:
        return x
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape.get(a, 1)
    if x.shape[0] % dp_size != 0:
        return x
    return jax.lax.with_sharding_constraint(x, P(dp, None, None))


def _constrain_ecd(x, dp):
    """Shard (E, C, F_or_D) expert buffers: experts over "model" when
    they divide it (EP), else capacity over dp + feature over "model" —
    without this GSPMD tends to replicate the expert einsums (observed
    21x flops and 20 GiB fp32 activations on grok-1)."""
    if dp is None:
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.shape:
        return x
    E = x.shape[0]
    model = mesh.shape["model"]
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape.get(a, 1)
    spec = [None] * x.ndim
    if E % model == 0:
        spec[0] = "model"
        if x.shape[1] % dp_size == 0:
            spec[1] = dp
    else:
        if x.shape[1] % dp_size == 0:
            spec[1] = dp
        if x.shape[-1] % model == 0:
            spec[-1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


def moe_params(cfg: ModelConfig, model_axis: int = 16) -> dict:
    E, D, F = cfg.num_experts, cfg.d_model, cfg.d_ff
    if E % model_axis == 0:
        espec_in = P("model", "data", None)    # expert-parallel
        espec_out = P("model", None, "data")
    else:
        espec_in = P(None, "data", "model")    # tensor-parallel inside expert
        espec_out = P(None, "model", "data")
    return {
        "router": P_((D, E), P("data", None), scale=0.1),
        "wi": P_((E, D, F), espec_in),
        "wg": P_((E, D, F), espec_in),
        "wo": P_((E, F, D), espec_out),
    }


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array,
            dp=("data",), token_chunk: int = 131_072) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).

    Tokens are processed in chunks under jax.checkpoint: the gather/
    scatter cotangents and dispatch buffers scale with the CHUNK, not
    the 1M-token global batch (§Perf M8).  Routing (and capacity) is
    per-chunk — standard local-capacity semantics.
    """
    B, S, D = x.shape
    T = B * S
    tc = min(token_chunk, T)
    if T % tc != 0:
        tc = T  # irregular sizes (smoke tests): single chunk
    n = T // tc
    xt_all = _constrain_tokens(x.reshape(T, D), dp)
    if n == 1:
        return _constrain_bsd(
            _moe_chunk(params, cfg, xt_all, dp).reshape(B, S, D), dp
        )
    xs = xt_all.reshape(n, tc, D)

    def chunk_fn(_, xc):
        return 0, _constrain_tokens(_moe_chunk(params, cfg, xc, dp), dp)

    _, out = jax.lax.scan(
        jax.checkpoint(chunk_fn), 0, xs,
        unroll=True if cfg.scan_unroll else 1,
    )
    # constrain the STACKED (n, tc, D) scan output: per-iteration
    # constraints inside the body do not bind the stack buffer
    if dp is not None:
        mesh = current_mesh()
        if mesh is not None and not mesh.empty:
            dp_size = 1
            for a in (dp if isinstance(dp, tuple) else (dp,)):
                dp_size *= mesh.shape.get(a, 1)
            if tc % dp_size == 0:
                out = jax.lax.with_sharding_constraint(out, P(None, dp, None))
    return _constrain_bsd(out.reshape(B, S, D), dp)


def _moe_chunk(params: dict, cfg: ModelConfig, xt: jax.Array, dp) -> jax.Array:
    """Route + dispatch + expert FFN + combine for (T, D) tokens."""
    T, D = xt.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    # fp32 router LOGITS without materializing an fp32 copy of xt
    logits = jnp.einsum(
        "td,de->te", xt, params["router"].astype(xt.dtype),
        preferred_element_type=jnp.float32,
    )
    gate_all = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(gate_all, K)            # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # flatten assignments and rank tokens within each expert
    flat_e = experts.reshape(-1)                            # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    # rank within group = index - first index of this expert id
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(T * K) - first
    C = max(1, int(cfg.moe_capacity_factor * T * K / E))
    C = C + (-C) % 256                                      # shard-friendly
    keep = rank < C
    token_id = order // K                                   # source token
    slot_e = sorted_e
    slot_c = jnp.where(keep, rank, C)                       # overflow -> sink

    # dispatch: scatter only an (E, C+pad) int32 INDEX map, then GATHER
    # the big (E, C, D) buffer — scattering activations directly defeats
    # GSPMD sharding (§Perf M4)
    pad_slots = 256
    idx = jnp.full((E, C + pad_slots), T, jnp.int32)
    idx = idx.at[slot_e, slot_c].set(token_id.astype(jnp.int32), mode="drop")
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    h = _constrain_ecd(jnp.take(xt_pad, idx[:, :C], axis=0), dp)  # (E, C, D)

    # expert einsums emit the model dtype (MXU accumulates fp32; a forced
    # fp32 preferred type materializes fp32 copies of every buffer)
    up = jnp.einsum("ecd,edf->ecf", h, params["wi"])
    gset = jnp.einsum("ecd,edf->ecf", h, params["wg"])
    act = _constrain_ecd(jax.nn.silu(gset) * up, dp)
    out_e = _constrain_ecd(jnp.einsum("ecf,efd->ecd", act, params["wo"]), dp)

    # combine: pure GATHER back via the inverse sort permutation — a
    # scatter-add into (T, D) defeats GSPMD sharding (§Perf M4)
    out_pad = jnp.concatenate(
        [out_e, jnp.zeros((E, 1, D), out_e.dtype)], axis=1
    )                                                       # (E, C+1, D)
    inv = jnp.argsort(order)                                # (T*K,)
    c_of = slot_c[inv].reshape(T, K)
    keep_tk = keep[inv].reshape(T, K)
    gathered = _constrain_tokens(out_pad[experts, c_of], dp)   # (T, K, D)
    w = (gates * keep_tk).astype(xt.dtype)
    combined = jnp.einsum("tkd,tk->td", gathered, w)
    return combined.astype(xt.dtype)
