"""Attention for the model zoo: GQA/MQA, RoPE/M-RoPE, sliding windows,
logit softcapping, cross-attention, chunked (flash-style) XLA path for
long sequences, and KV-cache decode.

The Pallas kernel (`repro.kernels.flash_attention`) is the TPU target
for the S x S hot spot; `chunked_attention` is the identical-math XLA
path used for lowering on any backend (lax.scan over KV blocks, online
softmax — never materializes the full score matrix).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import P_, current_mesh, dense, mrope, rope


def _constrain_heads(x, dp):
    """Shard (B, H, S, dh) on batch x heads when the dims divide — keeps
    the S x S score tensors head-sharded instead of replicated."""
    if dp is None:
        return x
    mesh = current_mesh()
    if mesh is None or mesh.empty or "model" not in mesh.shape:
        return x
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape.get(a, 1)
    spec = [None] * x.ndim
    if x.shape[0] % dp_size == 0:
        spec[0] = dp
    if x.shape[1] % mesh.shape["model"] == 0:
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))

__all__ = [
    "attn_params", "attention", "decode_attention", "chunked_attention",
    "init_kv_cache", "init_paged_kv_cache", "paged_decode_attention",
]

_NEG_INF = -1e30


def attn_params(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, Hkv, dh = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_width
    return {
        "wq": P_((D, H * dh), P("data", "model")),
        "wk": P_((D, Hkv * dh), P("data", "model")),
        "wv": P_((D, Hkv * dh), P("data", "model")),
        "wo": P_((H * dh, D), P("model", "data")),
    }


def _heads(x, n, dh):
    B, S, _ = x.shape
    return x.reshape(B, S, n, dh).transpose(0, 2, 1, 3)  # (B, H, S, dh)


def _unheads(x):
    B, H, S, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * dh)


def _apply_rope(cfg: ModelConfig, x, positions):
    if cfg.mrope_sections is not None:
        return mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return rope(x, positions, cfg.rope_theta)


def _scale(cfg: ModelConfig) -> float:
    if cfg.query_scale is not None:
        return cfg.query_scale
    return 1.0 / math.sqrt(cfg.head_width)


def _mask_bias(q_pos, k_pos, *, causal: bool, window: Optional[int]):
    """(..., Sq, Sk) additive bias from position tensors."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return jnp.where(m, 0.0, _NEG_INF)


def full_attention(q, k, v, bias, *, softcap, scale):
    """Direct attention; q: (B,H,Sq,dh), k/v: (B,Hkv,Sk,dh)."""
    B, H, Sq, dh = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Sq, dh)
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg.astype(jnp.float32) * scale,
        k.astype(jnp.float32), preferred_element_type=jnp.float32,
    )
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = s + bias[:, None, None] if bias.ndim == 3 else s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return o.reshape(B, H, Sq, dh).astype(q.dtype)


def chunked_attention(
    q, k, v, q_pos, k_pos, *, causal, window, softcap, scale,
    chunk: int = 1024, unroll: bool = False,
):
    """Online-softmax attention scanned over KV chunks (XLA flash path).

    q: (B,H,Sq,dh); k/v: (B,Hkv,Sk,dh); q_pos: (B,Sq); k_pos: (B,Sk).
    """
    B, H, Sq, dh = q.shape
    _, Hkv, Sk, dv = v.shape
    g = H // Hkv
    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, dh) * scale
    kc = k.reshape(B, Hkv, nchunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, nchunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    pc = k_pos.reshape(B, nchunks, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, kpb = inp                       # (B,Hkv,c,dh), ..., (B,c)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qf, kb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kpb[:, :] >= 0                   # (B, c) padding
        qp = q_pos[:, None, None, :, None]      # (B,1,1,Sq,1)
        kp = kpb[:, None, None, None, :]        # (B,1,1,1,c)
        keep = mask[:, None, None, None, :]
        if causal:
            keep &= kp <= qp
        if window is not None:
            keep &= kp > qp - window
        s = jnp.where(keep, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, g, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, dv), jnp.float32)
    # remat the chunk step: otherwise backward saves every chunk's score
    # tensor and the memory win evaporates (§Perf M6)
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (kc, vc, pc),
        unroll=True if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, dv).astype(q.dtype)


def banded_local_attention(
    q, k, v, q_pos, k_pos, *, window, softcap, scale, block: int = 1024
):
    """Causal sliding-window attention restricted to the diagonal band.

    q blocks attend only to the ceil(window/block)+1 KV blocks that can
    fall inside their window: flops scale with S*(window+block) instead
    of S^2 (§Perf P2.1 — 6-11x on the 32k local-attention cells).  KV is
    front-padded so band indices are static gathers; padded positions
    are -1 and masked.
    """
    B, H, Sq, dh = q.shape
    _, Hkv, Sk, dv = v.shape
    g = H // Hkv
    c = min(block, Sq)
    pad_t = (-Sq) % c
    if pad_t:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_t)), constant_values=-1)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_t)), constant_values=-1)
    S = q.shape[2]
    nb = S // c
    band = -(-window // c) + 1        # blocks that can intersect the window
    qf = (q.astype(jnp.float32) * scale).reshape(B, Hkv, g, nb, c, dh)
    kb = k.reshape(B, Hkv, nb, c, dh)
    vb = v.reshape(B, Hkv, nb, c, dv)
    pb = k_pos.reshape(B, nb, c)
    # front-pad (band-1) dummy blocks; padded block row i covers true
    # blocks [i-band+1 .. i]
    kb = jnp.pad(kb, ((0, 0), (0, 0), (band - 1, 0), (0, 0), (0, 0)))
    vb = jnp.pad(vb, ((0, 0), (0, 0), (band - 1, 0), (0, 0), (0, 0)))
    pb = jnp.pad(pb, ((0, 0), (band - 1, 0), (0, 0)), constant_values=-1)
    idx = jnp.arange(nb)[:, None] + jnp.arange(band)[None, :]   # (nb, band)
    kband = kb[:, :, idx].reshape(B, Hkv, nb, band * c, dh)
    vband = vb[:, :, idx].reshape(B, Hkv, nb, band * c, dv)
    pband = pb[:, idx].reshape(B, nb, band * c)

    s = jnp.einsum(
        "bhgncd,bhnkd->bhgnck", qf, kband.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                          # (B, Hkv, g, nb, c, band*c)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = q_pos.reshape(B, nb, c)[:, None, None, :, :, None]
    kp = pband[:, None, None, :, None, :]
    keep = (kp >= 0) & (kp <= qp) & (kp > qp - window)
    s = jnp.where(keep, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum(
        "bhgnck,bhnkd->bhgncd", p, vband.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o = o.reshape(B, H, S, dv)[:, :, :Sq]
    return o.astype(q.dtype)


def attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    kind: str = "attn",                 # attn | local
    causal: bool = True,
    memory: Optional[jax.Array] = None,  # cross-attention source (B,Sm,D)
    memory_positions: Optional[jax.Array] = None,
    chunk_threshold: int = 2047,
    dp=("data",),
) -> jax.Array:
    """Self- (or cross-) attention over a full sequence (train/prefill)."""
    H, Hkv, dh = cfg.num_heads, cfg.kv_heads, cfg.head_width
    window = cfg.window if kind == "local" else None
    src = x if memory is None else memory
    q = _constrain_heads(_heads(dense(x, params["wq"]), H, dh), dp)
    k = _constrain_heads(_heads(dense(src, params["wk"]), Hkv, dh), dp)
    v = _constrain_heads(_heads(dense(src, params["wv"]), Hkv, dh), dp)
    if memory is None:
        q = _apply_rope(cfg, q, positions)
        k = _apply_rope(cfg, k, positions)
        k_pos = positions if positions.ndim == 2 else positions[..., 0]
    else:
        # cross-attention: no rotary on encoder memory (whisper style)
        k_pos = (
            memory_positions
            if memory_positions is not None
            else jnp.broadcast_to(jnp.arange(src.shape[1])[None], src.shape[:2])
        )
    q_pos = positions if positions.ndim == 2 else positions[..., 0]
    scale = _scale(cfg)
    softcap = cfg.attn_logit_softcap
    Sk = src.shape[1]
    # sliding-window layers take the BANDED path (flops ~ S*(window+c),
    # §Perf P2.1); global attention above the threshold takes the
    # online-softmax chunked path (memory ~ S*c, §Perf M2)
    if window is not None and causal and memory is None and Sk > window:
        o = banded_local_attention(
            q, k, v, q_pos, k_pos,
            window=window, softcap=softcap, scale=scale,
            block=min(1024, window),
        )
    elif Sk > chunk_threshold:
        o = chunked_attention(
            q, k, v, q_pos, k_pos,
            causal=causal and memory is None, window=window,
            softcap=softcap, scale=scale,
            chunk=min(1024, Sk), unroll=cfg.scan_unroll,
        )
    else:
        bias = _mask_bias(
            q_pos, k_pos, causal=causal and memory is None, window=window
        )
        o = full_attention(q, k, v, bias, softcap=softcap, scale=scale)
    return dense(_unheads(o), params["wo"])


# ------------------------------ decode --------------------------------


def init_kv_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    """Cache for one attention layer. Local layers keep only a rotating
    window-sized buffer (bounded state — the long_500k enabler for
    hybrid archs)."""
    L = min(cfg.window, max_len) if (kind == "local" and cfg.window) else max_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "k": jnp.zeros((batch, cfg.kv_heads, L, cfg.head_width), dt),
        "v": jnp.zeros((batch, cfg.kv_heads, L, cfg.head_width), dt),
        "pos": jnp.full((batch, L), -1, jnp.int32),
    }


def decode_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,              # (B, 1, D)
    cache: dict,
    step: jax.Array,           # scalar int32: absolute position
    *,
    kind: str = "attn",
    memory_kv: Optional[tuple] = None,  # precomputed cross (k, v, k_pos)
) -> tuple[jax.Array, dict]:
    H, Hkv, dh = cfg.num_heads, cfg.kv_heads, cfg.head_width
    B = x.shape[0]
    q = _heads(dense(x, params["wq"]), H, dh)        # (B,H,1,dh)
    if memory_kv is not None:
        k, v, k_pos = memory_kv
        bias = jnp.zeros((B, 1, k.shape[2]), jnp.float32)
        o = full_attention(q, k, v, bias, softcap=cfg.attn_logit_softcap,
                           scale=_scale(cfg))
        return dense(_unheads(o), params["wo"]), cache

    pos_b = jnp.broadcast_to(step[None] if step.ndim == 0 else step, (B,))
    if cfg.mrope_sections is not None:
        qpos = jnp.broadcast_to(pos_b[:, None, None], (B, 1, 3))
    else:
        qpos = pos_b[:, None]
    q = _apply_rope(cfg, q, qpos)
    k_new = _heads(dense(x, params["wk"]), Hkv, dh)
    v_new = _heads(dense(x, params["wv"]), Hkv, dh)
    k_new = _apply_rope(cfg, k_new, qpos)

    L = cache["k"].shape[2]
    slot = (step % L).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, 0, slot, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, 0, slot, 0))
    pos = jax.lax.dynamic_update_slice(
        cache["pos"], pos_b[:, None].astype(jnp.int32), (0, slot)
    )
    window = cfg.window if kind == "local" else None
    valid = pos >= 0
    keep = valid & (pos <= pos_b[:, None])
    if window is not None:
        keep &= pos > (pos_b[:, None] - window)
    bias = jnp.where(keep, 0.0, _NEG_INF)[:, None, :]   # (B,1,Sk)->broadcast
    o = full_attention(q, k, v, bias, softcap=cfg.attn_logit_softcap,
                       scale=_scale(cfg))
    out = dense(_unheads(o), params["wo"])
    return out, {"k": k, "v": v, "pos": pos}


# --------------------------- paged decode ------------------------------


def init_paged_kv_cache(
    cfg: ModelConfig, num_pages: int, page_size: int
) -> dict:
    """Page-pool KV cache for one attention layer.

    Pages are the unit of allocation (`serve.kv_pages.PageTable` owns the
    slot -> page mapping); one extra trash page at index `num_pages`
    swallows writes of inactive slots so the jitted step signature stays
    static regardless of which slots hold live requests.
    """
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    shape = (num_pages + 1, cfg.kv_heads, page_size, cfg.head_width)
    return {"k_pages": jnp.zeros(shape, dt), "v_pages": jnp.zeros(shape, dt)}


def paged_decode_attention(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,              # (B, 1, D)
    cache: dict,               # k_pages/v_pages (N+1, Hkv, ps, dh)
    page_map: jax.Array,       # (B, P) physical page per logical page, N=trash
    steps: jax.Array,          # (B,) int32 per-slot absolute position
    write_mask: jax.Array,     # (B,) bool — False routes the write to trash
    *,
    kind: str = "attn",
) -> tuple[jax.Array, dict]:
    """`decode_attention` reading/writing KV through a page table.

    The logical sequence of slot b lives at pages `page_map[b]` in order:
    position t maps to page t // ps, offset t % ps, so the gathered
    (B, Hkv, P*ps, dh) view reproduces the dense cache layout exactly and
    the attention math below is bitwise-identical to the dense path
    (padded/stale entries carry an exact -inf bias, contributing exact
    zeros to the softmax on both paths).  Per-slot `steps` replace the
    dense path's scalar clock — slots at different depths decode in one
    batched call (the continuous-batching enabler).
    """
    H, Hkv, dh = cfg.num_heads, cfg.kv_heads, cfg.head_width
    B = x.shape[0]
    num_pages = cache["k_pages"].shape[0] - 1
    ps = cache["k_pages"].shape[2]
    P = page_map.shape[1]

    q = _heads(dense(x, params["wq"]), H, dh)        # (B,H,1,dh)
    pos_b = steps.astype(jnp.int32)
    if cfg.mrope_sections is not None:
        qpos = jnp.broadcast_to(pos_b[:, None, None], (B, 1, 3))
    else:
        qpos = pos_b[:, None]
    q = _apply_rope(cfg, q, qpos)
    k_new = _heads(dense(x, params["wk"]), Hkv, dh)  # (B,Hkv,1,dh)
    v_new = _heads(dense(x, params["wv"]), Hkv, dh)
    k_new = _apply_rope(cfg, k_new, qpos)

    # scatter the new token's KV into its page (trash page when masked)
    logical = jnp.clip(pos_b // ps, 0, P - 1)
    phys = jnp.take_along_axis(page_map, logical[:, None], axis=1)[:, 0]
    phys = jnp.where(write_mask, phys, num_pages)
    off = pos_b % ps
    k_pages = cache["k_pages"].at[phys, :, off, :].set(
        k_new[:, :, 0, :], mode="drop"
    )
    v_pages = cache["v_pages"].at[phys, :, off, :].set(
        v_new[:, :, 0, :], mode="drop"
    )

    # gather the slot's pages back into a contiguous logical view
    k = k_pages[page_map]                            # (B,P,Hkv,ps,dh)
    v = v_pages[page_map]
    k = k.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, P * ps, dh)
    v = v.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, P * ps, dh)
    k_pos = jnp.broadcast_to(jnp.arange(P * ps, dtype=jnp.int32)[None], (B, P * ps))
    keep = k_pos <= pos_b[:, None]
    window = cfg.window if kind == "local" else None
    if window is not None:
        keep &= k_pos > (pos_b[:, None] - window)
    bias = jnp.where(keep, 0.0, _NEG_INF)[:, None, :]
    o = full_attention(q, k, v, bias, softcap=cfg.attn_logit_softcap,
                       scale=_scale(cfg))
    out = dense(_unheads(o), params["wo"])
    return out, {"k_pages": k_pages, "v_pages": v_pages}
