from .config import ModelConfig
from .model import (
    Transformer,
    decode_step,
    forward,
    init_cache,
    init_paged_cache,
    loss_fn,
    paged_decode_step,
)

__all__ = [
    "ModelConfig",
    "Transformer",
    "decode_step",
    "forward",
    "init_cache",
    "init_paged_cache",
    "loss_fn",
    "paged_decode_step",
]
