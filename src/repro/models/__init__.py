from .config import ModelConfig
from .model import Transformer, decode_step, forward, init_cache, loss_fn

__all__ = [
    "ModelConfig",
    "Transformer",
    "decode_step",
    "forward",
    "init_cache",
    "loss_fn",
]
