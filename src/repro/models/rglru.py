"""RG-LRU recurrent block (recurrentgemma / Griffin).

Block: x -> [linear -> short causal depthwise conv -> RG-LRU] gated by
GeLU branch -> output projection.  The RG-LRU is a diagonal,
input-gated linear recurrence

    r_t = sigmoid(W_a x_t),  i_t = sigmoid(W_x x_t)
    a_t = exp(c * softplus(Lambda) * (-r_t))          in (0, 1)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

lowered with `jax.lax.associative_scan` (log-depth; the TPU-friendly
form of the recurrence) for train/prefill and as an O(1) state update
for decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig
from .layers import P_, constrain_act, dense

__all__ = ["rglru_params", "rglru_block", "rglru_decode", "init_rglru_state"]

_C = 8.0  # Griffin's scalar multiplier on the log-decay


def rglru_params(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    W = cfg.rglru_conv_width
    return {
        "wx": P_((D, D), P("data", "model")),        # recurrence branch in
        "wy": P_((D, D), P("data", "model")),        # gate branch in
        "conv": P_((W, D), P(None, "model"), init="normal", scale=0.1),
        "wa": P_((D, D), P("data", "model"), scale=0.5),
        "wi": P_((D, D), P("data", "model"), scale=0.5),
        "lam": P_((D,), P("model"), init="normal", scale=0.5),
        "wo": P_((D, D), P("model", "data")),
    }


def _conv1d_causal(x, w, state=None):
    """Depthwise causal conv, width W. x: (B,S,D), w: (W,D).
    With `state` ((B, W-1, D) trailing inputs) acts as a streaming step."""
    W = w.shape[0]
    if state is not None:
        x_ext = jnp.concatenate([state, x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        x_ext[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W)
    )
    return out.astype(x.dtype)


def _gates(params, x):
    a_log = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * jax.nn.sigmoid(
        dense(x, params["wa"]).astype(jnp.float32)
    )
    a = jnp.exp(a_log)                                   # (B,S,D) in (0,1)
    i = jax.nn.sigmoid(dense(x, params["wi"]).astype(jnp.float32))
    u = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, u


def _combine(l, r):
    al, ul = l
    ar, ur = r
    return al * ar, ur + ar * ul


def rglru_block(params: dict, cfg: ModelConfig, x: jax.Array,
                dp=("data",), chunk: int = 512) -> jax.Array:
    """Full-sequence form (train / prefill). x: (B, S, D).

    The recurrence runs CHUNKED: an associative scan inside each
    sequence chunk (log-depth, TPU-friendly) with an O(B*D) carry across
    chunks, under jax.checkpoint — the fp32 gate/scan-tree intermediates
    only ever exist for one chunk (§Perf M3: 28 GiB -> fits on the
    recurrentgemma train cell).
    """
    B, S, D = x.shape
    gate = jax.nn.gelu(dense(x, params["wy"]), approximate=True)
    h_in = _conv1d_causal(dense(x, params["wx"]), params["conv"])
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad), (0, 0)))
    n = (S + pad) // c
    hc = h_in.reshape(B, n, c, D).swapaxes(0, 1)          # (n, B, c, D)

    def chunk_fn(h0, hin_c):
        a, u = _gates(params, hin_c)                      # fp32 (B, c, D)
        a = constrain_act(a, dp)
        u = constrain_act(u, dp)
        u = u.at[:, 0].add(a[:, 0] * h0)                  # fold carry in
        _, h = jax.lax.associative_scan(_combine, (a, u), axis=1)
        return h[:, -1], h.astype(hin_c.dtype)

    h0 = jnp.zeros((B, D), jnp.float32)
    _, hs = jax.lax.scan(
        jax.checkpoint(chunk_fn), h0, hc,
        unroll=True if cfg.scan_unroll else 1,
    )  # (n, B, c, D)
    h = hs.swapaxes(0, 1).reshape(B, S + pad, D)[:, :S]
    y = h.astype(x.dtype) * gate
    return dense(y, params["wo"])


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "conv": jnp.zeros(
            (batch, cfg.rglru_conv_width - 1, cfg.d_model),
            jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
        ),
    }


def rglru_decode(
    params: dict, cfg: ModelConfig, x: jax.Array, state: dict
) -> tuple[jax.Array, dict]:
    """One-token step. x: (B, 1, D); O(1) state update."""
    gate = jax.nn.gelu(dense(x, params["wy"]), approximate=True)
    xr = dense(x, params["wx"])
    h_in = _conv1d_causal(xr, params["conv"], state=state["conv"])
    new_conv = jnp.concatenate([state["conv"], xr], axis=1)[:, 1:]
    a, u = _gates(params, h_in)                           # (B,1,D)
    h = a[:, 0] * state["h"] + u[:, 0]
    y = h[:, None].astype(x.dtype) * gate
    return dense(y, params["wo"]), {"h": h, "conv": new_conv}
