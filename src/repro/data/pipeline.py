"""Token data pipeline: deterministic synthetic LM stream + memmap-backed
binary corpus, with device placement sharded over the mesh's data axes.

Determinism contract (fault tolerance): batch contents are a pure
function of (seed, step), so a restart that resumes from checkpoint
step S reproduces the exact training stream — no data-loader state in
the checkpoint.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["SyntheticLM", "MemmapCorpus", "shard_batch", "write_synthetic_corpus"]


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-distributed token stream with document structure (BOS=0,
    in-doc Markov-ish correlation so the loss is learnable)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 512

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        ranks = rng.zipf(1.3, size=(B, S + 1))
        tokens = np.clip(ranks, 1, self.vocab_size - 1).astype(np.int64)
        # learnable structure: with prob .3 copy the token `shift` back
        shift = int(rng.integers(1, 4))
        rep = rng.uniform(size=(B, S + 1)) < 0.3
        rep[:, :shift] = False
        src = np.roll(tokens, shift, axis=1)
        tokens[rep] = src[rep]
        # document boundaries
        bos = rng.uniform(size=(B, S + 1)) < (1.0 / self.mean_doc_len)
        tokens[bos] = 0
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "labels": tokens[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass
class MemmapCorpus:
    """Flat uint16/uint32 token file; batches are strided windows chosen
    by a seeded permutation (production-style binary corpus reader)."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0
    dtype: str = "uint16"

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._num_windows = (len(self._data) - 1) // self.seq_len
        if self._num_windows < self.global_batch:
            raise ValueError(
                f"corpus too small: {self._num_windows} windows "
                f"< batch {self.global_batch}"
            )

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self._num_windows, self.global_batch)
        starts = idx * self.seq_len
        toks = np.stack(
            [self._data[s : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def write_synthetic_corpus(path: str, num_tokens: int, vocab_size: int,
                           seed: int = 0) -> str:
    rng = np.random.default_rng(seed)
    toks = np.clip(rng.zipf(1.3, num_tokens), 1, vocab_size - 1).astype(np.uint16)
    tmp = path + ".tmp"
    toks.tofile(tmp)
    os.replace(tmp, path)
    return path


def shard_batch(batch: dict, mesh, dp_axes: tuple[str, ...]) -> dict:
    """Place host batch on the mesh: leading (batch) dim over dp axes."""
    def put(x):
        spec = P(dp_axes, *([None] * (x.ndim - 1)))
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, batch)
