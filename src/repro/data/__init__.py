from .pipeline import MemmapCorpus, SyntheticLM, shard_batch, write_synthetic_corpus

__all__ = ["MemmapCorpus", "SyntheticLM", "shard_batch", "write_synthetic_corpus"]
