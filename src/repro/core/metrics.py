"""Error metrics used throughout the paper (§II eq. 1, Thm 2 eq. 2)."""
from __future__ import annotations

import numpy as np

__all__ = ["relative_error", "theorem2_bound"]


def relative_error(x: np.ndarray, x0: np.ndarray) -> float:
    """||x - x_ave * 1|| / ||x0||  (the paper's accuracy measure)."""
    avg = float(np.mean(x0))
    return float(np.linalg.norm(np.asarray(x) - avg) / np.linalg.norm(x0))


def theorem2_bound(n: int, eps: float) -> float:
    """Thm 2: final error <= sqrt(6) * n * eps w.h.p."""
    return float(np.sqrt(6.0) * n * eps)
