"""Wireless-medium transmission-cost and failure models.

The paper's systems argument is that multiscale gossip wins *on the
wireless medium* — link-level ACKs, retransmissions, and congestion —
not just on raw message counts (§VI-C; Nokleby et al. price consensus in
energy and bandwidth, Dimakis et al.'s geographic gossip prices by hop
distance).  This module prices the presampled exchange schedule
directly:

* `CostModel` — per-hop energy, iid-Geometric(p) link-level
  retransmissions, and a congestion surcharge for concurrent exchanges
  sharing the medium.  Pricing is a **pure reduction over the
  presampled ``(T, B)`` schedule arrays** (plus the plan's per-edge
  route hops, already folded into ``ExchangeSchedule.cost``): the
  retransmission draws come from an RNG stream independent of the
  exchange stream, so turning the cost model on NEVER perturbs the
  bitwise exchange trajectory (x / usage / messages are identical with
  the model on or off).  This replaces the post-hoc
  `core.failures.handshake_cost` scalar with per-trial, per-level
  pricing attached to `EngineResult.cost`.

* `FailureModel` — the declarative failure/churn surface threaded
  through `multiscale_gossip` → `execute_plan` → `gossip_core`.
  `loss_p` is the paper's §VI-C-2 message-loss model (unchanged
  semantics, bitwise-compatible with the legacy ``loss_p=`` kwarg); the
  scenario fields (churn, stragglers, regional outage, Byzantine
  dropped updates) *perturb the presampled schedule* — masking which
  exchanges happen and which updates apply — and replay the value pass,
  so a scenario run is exactly the reliable run's schedule with events
  injected.  Scenario event times are fractions of the finest level's
  tick budget, so they are well-defined under fixed-iterations mode
  (``fixed_ticks_scale > 0``); churned nodes stay down for all coarser
  levels.

Both dataclasses are frozen/hashable (they participate in the
compiled-executor cache key), mirroring the dist layer's `SyncConfig`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np

__all__ = [
    "CostModel",
    "FailureModel",
    "MediumCost",
    "FailureCtx",
    "expected_retransmissions",
    "price_messages",
    "failure_sets",
    "route_edge_transmissions",
    "level_edge_messages",
    "price_edge_messages",
]

# RNG stream tags for cost/perturbation draws: folded into the level key
# BEFORE the per-tick fold, so these streams are disjoint from the
# exchange streams (fold_in(key, t)) by construction — extra draws from
# them cannot perturb any exchange decision.
_TAG_RETX = 2_147_483_640
_TAG_STRAGGLER = 2_147_483_641


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Wireless transmission pricing (static, hashable).

    hop_energy: energy units per physical single-hop transmission — a
        scalar, or a per-overlay-edge tuple keyed off one level's
        route-incidence CSR (heterogeneous links: long hops cost more).
        Per-edge models are priced closed-form only, through
        `level_edge_messages` + `price_edge_messages`; the schedule
        reduction and `price_messages` reject them.
    retransmit_p: per-attempt link-level delivery probability; each
        logical single-hop transmission physically takes Geometric(p)
        attempts (ACK/retransmit until delivery, the handshake model of
        §VI-C-1).  1.0 disables retransmissions.
    congestion_alpha: energy surcharge, per active exchange and per
        OTHER exchange concurrent with it at the same tick of the same
        level (the level's cells share the radio medium) — the
        surcharge for one exchange at a tick with c concurrent
        exchanges is ``hop_energy * congestion_alpha * (c - 1)``.
    sample: True samples the Geometric retransmissions inside the
        schedule reduction (independent RNG stream, bitwise-neutral);
        False prices them with the closed-form mean ``T * (1-p)/p``.
    """

    hop_energy: object = 1.0  # float | per-edge tuple[float, ...]
    retransmit_p: float = 1.0
    congestion_alpha: float = 0.0
    sample: bool = True

    def __post_init__(self):
        if not 0.0 < self.retransmit_p <= 1.0:
            raise ValueError(
                f"retransmit_p must be in (0, 1], got {self.retransmit_p}")
        he = self.hop_energy
        if not isinstance(he, (int, float)):
            # a list/ndarray (natural from configs) would silently break
            # hashability — coerce to a tuple, like regional_window
            try:
                he = tuple(float(v) for v in he)
            except (TypeError, ValueError):
                raise ValueError(
                    f"hop_energy must be a float or a per-edge sequence "
                    f"of floats, got {self.hop_energy!r}")
            object.__setattr__(self, "hop_energy", he)
            if any(v < 0 for v in he):
                raise ValueError("hop_energy / congestion_alpha must be >= 0")
        elif he < 0:
            raise ValueError("hop_energy / congestion_alpha must be >= 0")
        if self.congestion_alpha < 0:
            raise ValueError("hop_energy / congestion_alpha must be >= 0")

    @property
    def heterogeneous(self) -> bool:
        """True when hop_energy is a per-edge map (closed-form pricing
        through `price_edge_messages` only)."""
        return isinstance(self.hop_energy, tuple)


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Failure/churn surface (static, hashable).

    loss_p: per-hop message delivery probability (paper §VI-C-2; a lost
        request aborts the exchange, a lost reply leaves only the
        contacted node updated).  None = reliable.  Bitwise-identical
        to the legacy ``loss_p=`` kwarg.  May also be a per-overlay-edge
        tuple keyed off one level's route-incidence CSR (heterogeneous
        links) — per-edge models price closed-form only, through
        `level_edge_messages` + `price_edge_messages`; the trajectory
        engine rejects them.
    churn_fraction / churn_time: `churn_fraction` of the nodes leave
        the network at `churn_time` (fraction of the finest level's
        tick budget) and stay down for the rest of the run — their
        exchanges vanish; a live node contacting a churned partner
        wastes the forward-leg transmissions.
    straggler_fraction / straggler_success: stragglers' exchanges
        succeed only w.p. `straggler_success` per attempt (slow or
        heterogeneous links); failed attempts are still priced at full
        exchange cost (the link stalls, the radios transmitted).
    regional_radius / regional_window: nodes within `regional_radius`
        of a random epicenter are down during
        ``[window[0], window[1])`` (fractions of the finest level's
        budget) — a correlated regional outage.  ``window[1] > 1``
        makes the outage permanent (persists through coarser levels).
    drop_fraction: Byzantine/dropped updates — the flagged nodes never
        apply incoming updates (their stale value keeps leaking into
        the average, the paper's mass-distortion failure).  The
        mass-weighted variant (``weighted=True``) is the EF-style
        recovery story: values travel as (w·x, w) pairs, so a frozen
        node distorts the fused mean by at most its own share.
    seed: failure-injection RNG (node selection, epicenter draw) —
        independent of the gossip seed.
    """

    loss_p: object = None  # None | float | per-edge tuple[float, ...]
    churn_fraction: float = 0.0
    churn_time: float = 0.5
    straggler_fraction: float = 0.0
    straggler_success: float = 0.25
    regional_radius: float = 0.0
    regional_window: tuple = (0.25, 0.75)
    drop_fraction: float = 0.0
    seed: int = 0

    def __post_init__(self):
        lp = self.loss_p
        if lp is not None and not isinstance(lp, (int, float)):
            # per-edge map: coerce to a tuple (hashability, as with
            # regional_window) and validate every entry
            try:
                lp = tuple(float(v) for v in lp)
            except (TypeError, ValueError):
                raise ValueError(
                    f"loss_p must be None, a float, or a per-edge "
                    f"sequence of floats, got {self.loss_p!r}")
            object.__setattr__(self, "loss_p", lp)
            for v in lp:
                if not 0.0 < v <= 1.0:
                    raise ValueError(f"loss_p must be in (0, 1], got {v}")
        elif lp is not None and not 0.0 < lp <= 1.0:
            raise ValueError(f"loss_p must be in (0, 1], got {lp}")
        for name in ("churn_fraction", "straggler_fraction", "drop_fraction"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if not 0.0 < self.straggler_success <= 1.0:
            raise ValueError("straggler_success must be in (0, 1]")
        # a list (natural from JSON configs) would silently break the
        # frozen dataclass's hashability, which the compiled-executor
        # cache key relies on — coerce and validate
        try:
            w = tuple(float(t) for t in self.regional_window)
        except (TypeError, ValueError):
            raise ValueError(
                f"regional_window must be a (t0, t1) pair of floats, "
                f"got {self.regional_window!r}")
        if len(w) != 2:
            raise ValueError(
                f"regional_window must be a (t0, t1) pair, got {w!r}")
        if not 0.0 <= w[0] <= w[1]:
            raise ValueError(
                f"regional_window needs 0 <= t0 <= t1, got {w!r}")
        object.__setattr__(self, "regional_window", w)

    @property
    def heterogeneous(self) -> bool:
        """True when loss_p is a per-edge map (closed-form pricing
        through `price_edge_messages` only)."""
        return isinstance(self.loss_p, tuple)

    @property
    def has_scenario(self) -> bool:
        """True when any schedule-perturbing field is active (loss_p
        alone is the legacy trajectory-level model, not a scenario)."""
        return (
            self.churn_fraction > 0
            or self.straggler_fraction > 0
            or self.regional_radius > 0
            or self.drop_fraction > 0
        )


class FailureCtx(NamedTuple):
    """Per-level device arrays + static windows for scenario perturbation.

    Built by the engine from `failure_sets` mapped through the level's
    `slot_node`; consumed by `gossip_core`'s presampled chunk.
    """

    churned: object      # (B, C) bool — slot leaves at churn_tick
    straggler: object    # (B, C) bool
    byz: object          # (B, C) bool — never applies updates
    regional: object     # (B, C) bool — down during [reg_t0, reg_t1)
    churn_tick: int      # static, level-local ticks
    reg_t0: int          # static
    reg_t1: int          # static
    straggler_success: float  # static


@dataclasses.dataclass
class MediumCost:
    """Per-trial priced cost of one plan execution (T trials).

    All arrays are host-side float64; `transmissions` equals the
    engine's logical message count (single-hop transmissions including
    the dissemination down-pass) — pricing never changes it.
    """

    transmissions: np.ndarray      # (T,) logical single-hop transmissions
    retransmissions: np.ndarray    # (T,) extra physical attempts
    congestion: np.ndarray         # (T,) concurrency surcharge, energy units
    energy: np.ndarray             # (T,) total energy
    level_energy: np.ndarray       # (T, L) per executed level (no down-pass)
    model: CostModel

    @property
    def physical_transmissions(self) -> np.ndarray:
        return self.transmissions + self.retransmissions


def expected_retransmissions(transmissions, p: float) -> np.ndarray:
    """Closed-form mean extra attempts for `transmissions` logical
    single-hop transmissions: each takes Geometric(p) physical attempts
    (mean 1/p), so the extra attempts sum to ``T * (1 - p) / p``."""
    if not 0.0 < p <= 1.0:
        raise ValueError(f"retransmit_p must be in (0, 1], got {p}")
    return np.asarray(transmissions, np.float64) * (1.0 - p) / p


def price_messages(
    messages,
    model: CostModel,
    rng: Optional[np.random.Generator] = None,
) -> MediumCost:
    """Price a plain message count (scalar or per-trial array) without a
    schedule — the host-side path for baselines (e.g. path averaging)
    whose executors do not run the presampled reduction.  Congestion is
    0 (no concurrency information in a bare count).

    Supersedes `core.failures.handshake_cost`: the handshake total
    ``T + NegBinomial(T, p)`` is exactly `transmissions +
    retransmissions` here.

    When ``model.sample`` and retransmissions are in play
    (``retransmit_p < 1``), `rng` is required: a hidden fixed-seed
    default would make every no-rng call draw identical NegBinomial
    variates, so repeated "sampled" pricings of different runs would
    be silently correlated.
    """
    if model.heterogeneous:
        raise ValueError(
            "per-edge hop_energy has no meaning for a bare message count "
            "— use level_edge_messages + price_edge_messages")
    msgs = np.atleast_1d(np.asarray(messages, np.int64))
    p = model.retransmit_p
    if p >= 1.0:
        retx = np.zeros(msgs.shape, np.float64)
    elif model.sample:
        if rng is None:
            raise ValueError(
                "price_messages needs an explicit rng when model.sample "
                "and retransmit_p < 1 (pass sample=False for the "
                "closed-form mean instead)")
        retx = np.array(
            [float(rng.negative_binomial(int(m), p)) if m > 0 else 0.0
             for m in msgs])
    else:
        retx = expected_retransmissions(msgs, p)
    cong = np.zeros(msgs.shape, np.float64)
    energy = model.hop_energy * (msgs + retx)
    return MediumCost(
        transmissions=msgs.astype(np.float64), retransmissions=retx,
        congestion=cong, energy=energy,
        level_energy=energy[:, None], model=model,
    )


def route_edge_transmissions(lp) -> np.ndarray:
    """Per-overlay-edge single-hop transmissions of ONE request+reply
    exchange over that edge: the sum of the level's route-incidence
    counts attributed to the edge (path endpoints transmit once,
    interior relays twice — i.e. ``2 * route_hops``).

    `lp` is a level plan carrying the overlay attribution arrays
    (`edge_pos_i` / `inc_edge` / `inc_count`); levels without routed
    overlay exchanges (finest level, cell-local gossip) are rejected.
    """
    if lp.edge_pos_i is None or lp.inc_edge is None:
        raise ValueError(
            "level has no overlay route-incidence attribution "
            "(per-edge pricing applies to routed overlay levels only)")
    tx = np.zeros(len(np.asarray(lp.edge_pos_i)), np.int64)
    np.add.at(tx, np.asarray(lp.inc_edge, np.int64),
              np.asarray(lp.inc_count, np.int64))
    return tx


def level_edge_messages(lp, usage) -> np.ndarray:
    """Per-overlay-edge logical single-hop transmissions of one level
    run: the edge's exchange count — its two directed usage counters,
    gathered from the flat `usage` buffer exactly as `overlay_node_sends`
    does — times its per-exchange route transmissions.  `usage` may be
    ``(U,)`` or carry leading trial axes (``(T, U)``); the edge axis is
    appended last.
    """
    tx = route_edge_transmissions(lp)
    usage = np.asarray(usage, np.int64)
    use_e = usage[..., lp.edge_pos_i] + usage[..., lp.edge_pos_j]
    return use_e * tx


def price_edge_messages(
    edge_messages,
    model: CostModel,
    failures: Optional[FailureModel] = None,
) -> MediumCost:
    """Closed-form pricing of per-edge logical transmission counts under
    heterogeneous links: `model.hop_energy` and `failures.loss_p` may
    each be a per-edge tuple (or a scalar, broadcast over edges).

    The per-attempt delivery probability of edge e is
    ``p_e = retransmit_p * loss_p_e`` (link-level ACK loss compounds
    with medium loss); expected extra attempts are the Geometric mean
    ``m_e * (1 - p_e) / p_e`` and energy is
    ``hop_energy_e * (m_e + retx_e)``.  Closed-form ONLY: per-edge
    sampling has no schedule to draw against, so a sampling model
    (``model.sample`` with an effective ``p_e < 1``) is rejected —
    construct the model with ``sample=False``.

    `edge_messages` is ``(E,)`` or ``(T, E)`` (from
    `level_edge_messages`); returns a `MediumCost` whose per-trial
    totals sum over edges and whose `level_energy` is the per-edge
    energy breakdown ``(T, E)``.  Congestion is 0 (no concurrency
    information in per-edge counts).
    """
    msgs = np.asarray(edge_messages, np.float64)
    if msgs.ndim == 1:
        msgs = msgs[None, :]
    elif msgs.ndim != 2:
        raise ValueError(
            f"edge_messages must be (E,) or (T, E), got shape {msgs.shape}")
    E = msgs.shape[1]

    def per_edge(v, name):
        if isinstance(v, tuple):
            if len(v) != E:
                raise ValueError(
                    f"{name} has {len(v)} entries but edge_messages has "
                    f"{E} edges")
            return np.asarray(v, np.float64)
        return np.full(E, float(v), np.float64)

    hop_e = per_edge(model.hop_energy, "hop_energy")
    loss = failures.loss_p if failures is not None else None
    loss_e = per_edge(loss if loss is not None else 1.0, "loss_p")
    p_e = model.retransmit_p * loss_e
    if model.sample and np.any(p_e < 1.0):
        raise ValueError(
            "per-edge pricing is closed-form only — pass "
            "CostModel(sample=False) (there is no schedule to sample "
            "per-edge retransmissions against)")
    retx_e = msgs * (1.0 - p_e) / p_e
    edge_energy = hop_e * (msgs + retx_e)
    return MediumCost(
        transmissions=msgs.sum(axis=1),
        retransmissions=retx_e.sum(axis=1),
        congestion=np.zeros(msgs.shape[0], np.float64),
        energy=edge_energy.sum(axis=1),
        level_energy=edge_energy,
        model=model,
    )


def failure_sets(model: FailureModel, n: int, coords=None) -> dict:
    """Draw the failure-injection node sets (host, deterministic in
    `model.seed`): boolean (n,) masks for churned / straggler / byz /
    regional nodes, plus the regional epicenter.  The draw order is
    fixed so adding one scenario field never reshuffles another's set.
    """
    rng = np.random.default_rng(model.seed)

    def pick(frac):
        m = np.zeros(n, bool)
        k = int(round(frac * n))
        if k > 0:
            m[rng.choice(n, size=min(k, n), replace=False)] = True
        return m

    churned = pick(model.churn_fraction)
    straggler = pick(model.straggler_fraction)
    byz = pick(model.drop_fraction)
    epicenter = rng.uniform(0.0, 1.0, 2)
    regional = np.zeros(n, bool)
    if model.regional_radius > 0 and coords is not None:
        d = np.linalg.norm(np.asarray(coords) - epicenter[None, :], axis=1)
        regional = d < model.regional_radius
    return {
        "churned": churned, "straggler": straggler, "byz": byz,
        "regional": regional, "epicenter": epicenter,
    }
