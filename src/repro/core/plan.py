"""Ahead-of-time hierarchy planning for the plan/execute simulation core.

The multiscale simulation splits into two halves:

* **plan** (this module, host/numpy): everything that depends only on
  the deployment — the recursive partition, induced-subgraph batches for
  every level, overlay grid edges (with nearest-pair augmentation for
  disconnected grids), representative election, batched greedy-geographic
  routes between representatives as padded arrays, and per-edge
  route-incidence CSR arrays so node-send attribution is a single
  scatter-add.  None of it depends on node *values*, so one plan serves
  any number of Monte-Carlo trials.
* **execute** (`core.engine`, device/JAX): runs all K levels through the
  batched gossip engine with promotion/reweighting expressed as
  gathers, `vmap`-able over trial seeds.

A `HierarchyPlan` is built once per (graph, partition, election seed)
and is reusable across trials, eps targets, weighted/unweighted modes,
loss models, and engine backends.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .gossip import batched_graphs
from .partition import Partition, build_partition
from .rgg import Graph, induced_subgraph
from .routing import BatchedRoutes, batched_routes_to_nodes

__all__ = ["LevelPlan", "HierarchyPlan", "build_plan", "overlay_node_sends"]


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One hierarchy level, fully batched (B graphs, C slots, D slots/row).

    `kind == "cells"`: induced subgraphs of the finest cells; exchanges
    are single-hop.  `kind == "overlay"`: grids of representatives; each
    directed slot carries the greedy-route hop count of its edge.
    """

    level: int               # paper level: k (finest) down to 1 (top grid)
    kind: str                # "cells" | "overlay"
    neighbors: np.ndarray    # (B, C, D) int32, padded with -1
    degrees: np.ndarray      # (B, C) int32
    n_nodes: np.ndarray      # (B,) int32
    node_mask: np.ndarray    # (B, C) bool
    edge_hops: np.ndarray    # (B, C, D) int32 (all 1 for "cells")
    slot_node: np.ndarray    # (B, C) int32 global node id per slot, -1 pad
    max_hops: int            # longest routed exchange at this level
    # -- attribution --------------------------------------------------------
    # cells: global id of the partner in each directed slot (-1 pad)
    partner_node: Optional[np.ndarray]       # (B, C, D) int32
    # overlay: gather indices mapping each undirected edge e to its two
    # directed usage slots, plus the route-incidence CSR (entry p says:
    # node inc_node[p] transmits inc_count[p] times per use of edge
    # inc_edge[p]) — attribution is usage_e gathered then scatter-added.
    edge_b: Optional[np.ndarray]             # (E,) int32 graph index
    edge_i: Optional[np.ndarray]             # (E,) int32 endpoint slots
    edge_si: Optional[np.ndarray]            # (E,) int32 slot of v in i's row
    edge_j: Optional[np.ndarray]             # (E,)
    edge_sj: Optional[np.ndarray]            # (E,)
    inc_node: Optional[np.ndarray]           # (NNZ,) int32 global node ids
    inc_edge: Optional[np.ndarray]           # (NNZ,) int32 edge index
    inc_count: Optional[np.ndarray]          # (NNZ,) int32 sends per use
    routes: Optional[BatchedRoutes]          # the padded routes themselves
    # -- promotion to the next (coarser) level; None on the last level ------
    rep_slot: Optional[np.ndarray]           # (B,) int32 elected rep slot
    rep_node: Optional[np.ndarray]           # (B,) int64 global node id
    line16: Optional[np.ndarray]             # (B,) f32 Alg.1 line-16 factor
    next_graph: Optional[np.ndarray]         # (B,) int32 graph at next level
    next_slot: Optional[np.ndarray]          # (B,) int32 slot at next level

    @property
    def num_graphs(self) -> int:
        return int(self.neighbors.shape[0])

    @property
    def graph_sizes(self) -> tuple:
        return (
            int(self.n_nodes.min()),
            float(self.n_nodes.mean()),
            int(self.n_nodes.max()),
        )


@dataclasses.dataclass(eq=False)
class HierarchyPlan:
    """The full ahead-of-time pass: partition + per-level batches +
    routes + attribution, value-independent and trial-reusable."""

    graph: Graph
    partition: Partition
    levels: tuple            # LevelPlan, execution order: cells first, 1 last
    rep_counts: np.ndarray   # (n,) int64 — election is part of the plan
    disconnected_cells: int  # finest cells whose induced subgraph splits
    final_graph: np.ndarray  # (n,) int32 — where each node reads its
    final_slot: np.ndarray   # (n,) int32   final estimate (last level's x)
    disseminate: bool        # K >= 2: down-pass costs n messages
    seed: int
    rep_mode: str
    # compiled-executor cache, keyed by engine config (see core.engine)
    exec_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def k(self) -> int:
        return self.partition.k


def _elect(
    rng: np.random.Generator,
    mode: str,
    num: int,
    coords: np.ndarray,
    center: np.ndarray,
) -> int:
    """Local index of the representative among `num` members."""
    if mode == "first":
        return 0
    if mode == "random":
        return int(rng.integers(num))
    d = np.sum((coords - center) ** 2, axis=1)
    return int(np.argmin(d))


def _grid_components(num: int, edges: np.ndarray) -> np.ndarray:
    """Union-find component labels for a small local graph."""
    parent = np.arange(num)

    def find(u):
        while parent[u] != u:
            parent[u] = parent[parent[u]]
            u = parent[u]
        return u

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv
    return np.array([find(u) for u in range(num)])


def _connect_components(local_edges: list, coords: np.ndarray, num: int) -> list:
    """Add nearest-pair edges until the local rep graph is connected
    (handles empty sibling cells — paper §VII 'disconnected grids')."""
    if num <= 1:
        return local_edges
    while True:
        comp = _grid_components(
            num, np.asarray(local_edges, np.int64).reshape(-1, 2)
        )
        labels = np.unique(comp)
        if len(labels) == 1:
            return local_edges
        a = np.where(comp == labels[0])[0]
        b = np.where(comp != labels[0])[0]
        d = np.sum((coords[a][:, None, :] - coords[b][None, :, :]) ** 2, axis=2)
        ia, ib = np.unravel_index(int(np.argmin(d)), d.shape)
        local_edges.append((int(a[ia]), int(b[ib])))


class _OverlayGraph:
    """Duck-typed graph (n / max_deg / neighbors / degrees) for batching,
    tracking which row slot each undirected edge landed in."""

    def __init__(self, num: int, edges: np.ndarray, hops: np.ndarray):
        self.n = num
        nbrs: list[list[int]] = [[] for _ in range(num)]
        hp: list[list[int]] = [[] for _ in range(num)]
        self.slot_i = np.zeros(len(edges), np.int32)  # slot of v in u's row
        self.slot_j = np.zeros(len(edges), np.int32)  # slot of u in v's row
        for e, ((u, v), h) in enumerate(zip(edges, hops)):
            self.slot_i[e] = len(nbrs[u])
            nbrs[u].append(int(v))
            hp[u].append(int(h))
            self.slot_j[e] = len(nbrs[v])
            nbrs[v].append(int(u))
            hp[v].append(int(h))
        self.max_deg = max(1, max((len(r) for r in nbrs), default=1))
        self.neighbors = np.full((num, self.max_deg), -1, np.int32)
        self.edge_hops = np.ones((num, self.max_deg), np.int32)
        self.degrees = np.array([len(r) for r in nbrs], np.int32)
        for u in range(num):
            self.neighbors[u, : len(nbrs[u])] = nbrs[u]
            self.edge_hops[u, : len(hp[u])] = hp[u]


def _route_incidence(routes: BatchedRoutes) -> tuple:
    """CSR incidence (inc_node, inc_edge, inc_count) of padded routes:
    one request+reply exchange over edge e makes its path endpoints
    transmit once and interior nodes twice (2 * hops total)."""
    E, W = routes.nodes.shape
    col = np.arange(W)[None, :]
    hops = routes.hops[:, None]
    on_path = (col <= hops) & (routes.nodes >= 0)
    count = np.where((col == 0) | (col == hops), 1, 2)
    e_idx = np.broadcast_to(np.arange(E)[:, None], (E, W))
    keep = on_path & (hops > 0)
    return (
        routes.nodes[keep].astype(np.int32),
        e_idx[keep].astype(np.int32),
        count[keep].astype(np.int32),
    )


def overlay_node_sends(
    lp: LevelPlan, usage: np.ndarray, n: int
) -> np.ndarray:
    """Reference (numpy) overlay attribution: per-edge exchange counts
    gathered from the directed usage array, scatter-added through the
    route-incidence CSR.  The engine runs the same computation in JAX."""
    usage_e = (
        usage[lp.edge_b, lp.edge_i, lp.edge_si]
        + usage[lp.edge_b, lp.edge_j, lp.edge_sj]
    ).astype(np.int64)
    sends = np.zeros(n, np.int64)
    np.add.at(sends, lp.inc_node, usage_e[lp.inc_edge] * lp.inc_count)
    return sends


def build_plan(
    g: Graph,
    *,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
) -> HierarchyPlan:
    """One ahead-of-time pass over the deployment: partition, batched
    induced subgraphs, overlay grids, representative election, batched
    routes, and attribution CSR for every level."""
    rng = np.random.default_rng(seed)
    n = g.n
    part = build_partition(n, k=k, a=a, cell_max=cell_max)
    K = part.k
    rep_counts = np.zeros(n, np.int64)
    levels: list[LevelPlan] = []

    # ---------------- finest level: induced cell subgraphs ----------------
    cell_of_node = part.cell_of(g.coords, K)
    present_cells = np.unique(cell_of_node)
    subgraphs, sub_ids = [], []
    for c in present_cells:
        sg, ids = induced_subgraph(g, np.where(cell_of_node == c)[0])
        subgraphs.append(sg)
        sub_ids.append(ids)
    disconnected = sum(0 if sg.is_connected() else 1 for sg in subgraphs)
    neighbors, degrees, n_nodes, mask = batched_graphs(subgraphs)
    B, C = mask.shape
    slot_node = np.full((B, C), -1, np.int32)
    for b, ids in enumerate(sub_ids):
        slot_node[b, : len(ids)] = ids
    # partner in each directed slot, as a global node id
    nbr_safe = np.clip(neighbors, 0, None)
    partner = np.where(
        neighbors >= 0, np.take_along_axis(
            np.broadcast_to(slot_node[:, :, None], neighbors.shape),
            nbr_safe, axis=1,
        ), -1,
    ).astype(np.int32)

    # elect finest-cell representatives + Alg.1 line-16 reweighting factor
    centers = part.cell_center(K, present_cells)
    rep_slot = np.zeros(B, np.int32)
    for b, ids in enumerate(sub_ids):
        rep_slot[b] = _elect(rng, rep_mode, len(ids), g.coords[ids], centers[b])
    rep_node = slot_node[np.arange(B), rep_slot].astype(np.int64)
    line16 = np.ones(B, np.float32)
    if K >= 2:
        parents = part.parent_cell(K, present_cells)
        sizes = n_nodes.astype(np.float64)
        for p in np.unique(parents):
            sel = parents == p
            line16[sel] = (
                sizes[sel] * int(sel.sum()) / sizes[sel].sum()
            ).astype(np.float32)

    base_kwargs = dict(
        level=K, kind="cells", neighbors=neighbors, degrees=degrees,
        n_nodes=n_nodes, node_mask=mask,
        edge_hops=np.ones(neighbors.shape, np.int32), slot_node=slot_node,
        max_hops=1, partner_node=partner,
        edge_b=None, edge_i=None, edge_si=None, edge_j=None, edge_sj=None,
        inc_node=None, inc_edge=None, inc_count=None, routes=None,
    )

    if K == 1:
        # degenerate single-level run: no promotion, but the per-cell
        # election still happens (and is counted) as in Alg. 1
        rep_counts[rep_node] += 1
        levels.append(LevelPlan(
            **base_kwargs, rep_slot=None, rep_node=None, line16=None,
            next_graph=None, next_slot=None,
        ))
        final_graph = np.zeros(n, np.int32)
        final_slot = np.zeros(n, np.int32)
        for b, ids in enumerate(sub_ids):
            final_graph[ids] = b
            final_slot[ids] = np.arange(len(ids))
        return HierarchyPlan(
            graph=g, partition=part, levels=tuple(levels),
            rep_counts=rep_counts, disconnected_cells=disconnected,
            final_graph=final_graph, final_slot=final_slot,
            disseminate=False, seed=seed, rep_mode=rep_mode,
        )

    rep_counts[rep_node] += 1
    cur_cells, cur_level = present_cells, K
    pending_base = base_kwargs  # promotion targets filled once grouped

    # ---------------- overlay levels k-1 .. 1 ----------------
    while cur_level > 1:
        j = cur_level - 1
        parents = part.parent_cell(cur_level, cur_cells)
        all_edges = part.child_grid_edges(j)
        order = np.argsort(parents, kind="stable")
        uniq_parents, starts = np.unique(parents[order], return_index=True)
        groups = np.split(order, starts[1:])

        # promotion mapping for the previous level
        next_graph = np.zeros(len(cur_cells), np.int32)
        next_slot = np.zeros(len(cur_cells), np.int32)
        for b, grp in enumerate(groups):
            next_graph[grp] = b
            next_slot[grp] = np.arange(len(grp))
        if pending_base is not None:
            levels.append(LevelPlan(
                **pending_base, rep_slot=rep_slot, rep_node=rep_node,
                line16=line16, next_graph=next_graph, next_slot=next_slot,
            ))
            pending_base = None
        else:
            prev = levels[-1]
            levels[-1] = dataclasses.replace(
                prev, rep_slot=rep_slot, rep_node=rep_node,
                line16=np.ones(prev.num_graphs, np.float32),
                next_graph=next_graph, next_slot=next_slot,
            )

        # per-parent overlay grids; route ALL edges of the level at once
        group_edges, group_sizes = [], []
        for grp in groups:
            cells_here = cur_cells[grp]
            local = {int(c): i for i, c in enumerate(cells_here)}
            edges = [
                (local[int(u)], local[int(v)])
                for u, v in all_edges
                if int(u) in local and int(v) in local
            ]
            edges = _connect_components(edges, g.coords[rep_node[grp]], len(grp))
            group_edges.append(edges)
            group_sizes.append(len(grp))
        flat_pairs = np.concatenate([
            np.stack([
                rep_node[grp[[u for u, _ in edges]]],
                rep_node[grp[[v for _, v in edges]]],
            ], axis=1) if edges else np.zeros((0, 2), np.int64)
            for grp, edges in zip(groups, group_edges)
        ]) if groups else np.zeros((0, 2), np.int64)
        routes = batched_routes_to_nodes(g, flat_pairs)
        hops_all = np.maximum(1, routes.hops)
        level_max_hops = int(hops_all.max()) if len(hops_all) else 1

        overlay_graphs = []
        e0 = 0
        edge_b, edge_i, edge_si, edge_j, edge_sj = [], [], [], [], []
        for b, (grp, edges) in enumerate(zip(groups, group_edges)):
            m = len(edges)
            og = _OverlayGraph(
                len(grp), np.asarray(edges, np.int64).reshape(-1, 2),
                hops_all[e0 : e0 + m],
            )
            overlay_graphs.append(og)
            for e in range(m):
                u, v = edges[e]
                edge_b.append(b)
                edge_i.append(u)
                edge_si.append(og.slot_i[e])
                edge_j.append(v)
                edge_sj.append(og.slot_j[e])
            e0 += m

        neighbors, degrees, n_nodes, mask = batched_graphs(overlay_graphs)
        Bg, Cg = mask.shape
        edge_hops = np.ones((Bg, Cg, neighbors.shape[2]), np.int32)
        slot_node = np.full((Bg, Cg), -1, np.int32)
        for b, (og, grp) in enumerate(zip(overlay_graphs, groups)):
            edge_hops[b, : og.n, : og.max_deg] = og.edge_hops
            slot_node[b, : og.n] = rep_node[grp]
        inc_node, inc_edge, inc_count = _route_incidence(routes)

        overlay_kwargs = dict(
            level=j, kind="overlay", neighbors=neighbors, degrees=degrees,
            n_nodes=n_nodes, node_mask=mask, edge_hops=edge_hops,
            slot_node=slot_node, max_hops=level_max_hops, partner_node=None,
            edge_b=np.asarray(edge_b, np.int32),
            edge_i=np.asarray(edge_i, np.int32),
            edge_si=np.asarray(edge_si, np.int32),
            edge_j=np.asarray(edge_j, np.int32),
            edge_sj=np.asarray(edge_sj, np.int32),
            inc_node=inc_node, inc_edge=inc_edge, inc_count=inc_count,
            routes=routes,
        )

        if j == 1:
            levels.append(LevelPlan(
                **overlay_kwargs, rep_slot=None, rep_node=None, line16=None,
                next_graph=None, next_slot=None,
            ))
            break

        # elect a level-j representative per grid (promotion filled on the
        # next iteration, once the grouping at level j-1 is known)
        centers = part.cell_center(j, uniq_parents)
        rep_slot = np.zeros(Bg, np.int32)
        for b, grp in enumerate(groups):
            rep_slot[b] = _elect(
                rng, rep_mode, len(grp), g.coords[rep_node[grp]], centers[b]
            )
        new_rep_node = slot_node[np.arange(Bg), rep_slot].astype(np.int64)
        rep_counts[new_rep_node] += 1
        levels.append(LevelPlan(
            **overlay_kwargs, rep_slot=rep_slot, rep_node=new_rep_node,
            line16=np.ones(Bg, np.float32), next_graph=None, next_slot=None,
        ))
        rep_node = new_rep_node
        cur_cells, cur_level = uniq_parents, j

    # dissemination: every node reads its level-2 cell's slot in the
    # final (level-1) grid, which is a single graph
    final_lp = levels[-1]
    lvl2 = part.cell_of(g.coords, 2)
    slot_of_cell = np.full(part.num_cells(2), -1, np.int32)
    # final level slots hold reps of level-2 cells, ordered like cur_cells
    for p in range(int(final_lp.n_nodes[0])):
        slot_of_cell[int(cur_cells[p])] = p
    final_graph = np.zeros(n, np.int32)
    final_slot = slot_of_cell[lvl2]
    assert (final_slot >= 0).all(), "every node's level-2 cell must be present"
    return HierarchyPlan(
        graph=g, partition=part, levels=tuple(levels),
        rep_counts=rep_counts, disconnected_cells=disconnected,
        final_graph=final_graph, final_slot=final_slot.astype(np.int32),
        disseminate=True, seed=seed, rep_mode=rep_mode,
    )
