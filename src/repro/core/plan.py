"""Ahead-of-time hierarchy planning for the plan/execute simulation core.

The multiscale simulation splits into two halves:

* **plan** (this module, host/numpy): everything that depends only on
  the deployment — the recursive partition, induced-subgraph batches for
  every level, overlay grid edges (with nearest-pair augmentation for
  disconnected grids), representative election, batched greedy-geographic
  routes between representatives, and per-edge route-incidence CSR
  arrays so node-send attribution is a single scatter-add.  None of it
  depends on node *values*, so one plan serves any number of
  Monte-Carlo trials.
* **execute** (`core.engine`, device/JAX): runs all K levels through the
  batched gossip engine with promotion/reweighting expressed as
  gathers, `vmap`-able over trial seeds.

Adjacency is CSR throughout `LevelPlan` (`nbr_start` / `nbr_flat` /
`hop_flat`, one flat entry per directed edge plus a trailing sentinel)
— the historical ``(B, C, D)`` dense padded arrays cost O(B*C*D) host
and device memory on the degree spread, which is what capped plans near
n=2000.  Dense views remain available as properties for small-n
consumers (`synchronous`, tests).

Two builders produce *identical* plans (same element order, same RNG
consumption, same floats — asserted by the parity tests):

* ``method="vectorized"`` (default): grouping via stable sorts, edges
  via one directed-edge flattening pass, per-parent overlay assembly as
  a handful of lexsorts, and connectivity via `scipy.sparse.csgraph`.
  The historical per-group edge filter was O(#groups × #grid-edges) —
  quadratic in n and the reason an n=10^5 build took ~450 s; the
  vectorized path is a few seconds.
* ``method="reference"``: the historical per-cell / per-group python
  loops, kept as the dense-path oracle.

`build_plan` records a `build_seconds` breakdown (partition / cells /
overlay / routes / incidence) on the returned `HierarchyPlan`, surfaced
as `plan_build_s` in benchmark artifacts.

A `HierarchyPlan` is built once per (graph, partition, election seed)
and is reusable across trials, eps targets, weighted/unweighted modes,
loss models, and engine backends.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .gossip import batched_graphs
from .partition import Partition, build_partition
from .rgg import Graph, induced_subgraph
from .routing import BatchedRoutes, batched_routes_to_nodes
from .schedule import flat_usage_to_dense

__all__ = [
    "LevelPlan",
    "HierarchyPlan",
    "build_plan",
    "overlay_node_sends",
    "PLAN_METHODS",
]

PLAN_METHODS = ("vectorized", "reference")


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One hierarchy level, fully batched (B graphs, C slots).

    `kind == "cells"`: induced subgraphs of the finest cells; exchanges
    are single-hop.  `kind == "overlay"`: grids of representatives; each
    directed edge carries the greedy-route hop count of its edge.

    Adjacency is CSR: row ``(b, c)`` owns flat entries ``nbr_start[b, c]
    : nbr_start[b, c] + degrees[b, c]``; the flat arrays carry one
    trailing sentinel entry (neighbor 0, hops 1, attribution ids = n,
    the engine's trash slot) so edgeless levels stay well-formed.
    Dense ``(B, C, D)`` views are available as `neighbors` /
    `edge_hops` / `partner_node` properties — materialized on demand,
    for small-n consumers only.
    """

    level: int               # paper level: k (finest) down to 1 (top grid)
    kind: str                # "cells" | "overlay"
    nbr_start: np.ndarray    # (B, C) int32 flat offset of each row
    nbr_flat: np.ndarray     # (nnz+1,) int32 neighbor slot within the graph
    hop_flat: np.ndarray     # (nnz+1,) int32 per-directed-edge route hops
    degrees: np.ndarray      # (B, C) int32
    n_nodes: np.ndarray      # (B,) int32
    node_mask: np.ndarray    # (B, C) bool
    slot_node: np.ndarray    # (B, C) int32 global node id per slot, -1 pad
    max_hops: int            # longest routed exchange at this level
    max_deg: int             # D of the dense views
    # -- attribution --------------------------------------------------------
    # cells: global ids of each flat entry's owner and partner (sentinel n),
    # so per-node sends are two 1-D scatter-adds of the flat usage counters.
    row_node: Optional[np.ndarray]           # (nnz+1,) int32
    partner_flat: Optional[np.ndarray]       # (nnz+1,) int32
    # overlay: gather indices mapping each undirected edge e to its two
    # directed usage entries (flat positions), plus the route-incidence
    # CSR (entry p says: node inc_node[p] transmits inc_count[p] times per
    # use of edge inc_edge[p]) — attribution is usage_e gathered then
    # scatter-added.
    edge_b: Optional[np.ndarray]             # (E,) int32 graph index
    edge_i: Optional[np.ndarray]             # (E,) int32 endpoint slots
    edge_si: Optional[np.ndarray]            # (E,) int32 slot of v in i's row
    edge_j: Optional[np.ndarray]             # (E,)
    edge_sj: Optional[np.ndarray]            # (E,)
    edge_pos_i: Optional[np.ndarray]         # (E,) int32 flat usage index i->j
    edge_pos_j: Optional[np.ndarray]         # (E,) int32 flat usage index j->i
    inc_node: Optional[np.ndarray]           # (NNZ,) int32 global node ids
    inc_edge: Optional[np.ndarray]           # (NNZ,) int32 edge index
    inc_count: Optional[np.ndarray]          # (NNZ,) int32 sends per use
    routes: Optional[BatchedRoutes]          # the padded routes themselves
    # -- promotion to the next (coarser) level; None on the last level ------
    rep_slot: Optional[np.ndarray]           # (B,) int32 elected rep slot
    rep_node: Optional[np.ndarray]           # (B,) int64 global node id
    line16: Optional[np.ndarray]             # (B,) f32 Alg.1 line-16 factor
    next_graph: Optional[np.ndarray]         # (B,) int32 graph at next level
    next_slot: Optional[np.ndarray]          # (B,) int32 slot at next level

    @property
    def num_graphs(self) -> int:
        return int(self.degrees.shape[0])

    @property
    def nnz(self) -> int:
        """True directed-edge count (sentinel excluded)."""
        return int(self.nbr_flat.shape[0]) - 1

    @property
    def graph_sizes(self) -> tuple:
        return (
            int(self.n_nodes.min()),
            float(self.n_nodes.mean()),
            int(self.n_nodes.max()),
        )

    def _dense_view(self, flat: np.ndarray, fill) -> np.ndarray:
        B, C = self.degrees.shape
        deg = self.degrees.ravel().astype(np.int64)
        nnz = int(deg.sum())
        starts = np.concatenate([[0], np.cumsum(deg)])[:-1]
        row = np.repeat(np.arange(B * C), deg)
        col = np.arange(nnz) - np.repeat(starts, deg)
        out = np.full((B * C, self.max_deg), fill, flat.dtype)
        out[row, col] = flat[:nnz]
        return out.reshape(B, C, self.max_deg)

    @property
    def neighbors(self) -> np.ndarray:
        """Dense (B, C, D) padded view, -1 pad — small-n consumers only."""
        return self._dense_view(self.nbr_flat, -1)

    @property
    def edge_hops(self) -> np.ndarray:
        """Dense (B, C, D) hop view, 1 pad (the historical padding)."""
        return self._dense_view(self.hop_flat, 1)

    @property
    def partner_node(self) -> Optional[np.ndarray]:
        """Dense (B, C, D) partner-global-id view, -1 pad ("cells" only)."""
        if self.partner_flat is None:
            return None
        n = int(self.partner_flat[-1])  # sentinel holds the trash id == n
        dense = self._dense_view(self.partner_flat, -1)
        dense[dense == n] = -1
        return dense

    def dense_usage(self, usage_flat: np.ndarray) -> np.ndarray:
        """Scatter flat (nnz+1,) usage counters to the dense (B, C, D)
        layout of the historical engine output."""
        return flat_usage_to_dense(usage_flat, self.degrees, self.max_deg)


@dataclasses.dataclass(eq=False)
class HierarchyPlan:
    """The full ahead-of-time pass: partition + per-level batches +
    routes + attribution, value-independent and trial-reusable."""

    graph: Graph
    partition: Partition
    levels: tuple            # LevelPlan, execution order: cells first, 1 last
    rep_counts: np.ndarray   # (n,) int64 — election is part of the plan
    disconnected_cells: int  # finest cells whose induced subgraph splits
    final_graph: np.ndarray  # (n,) int32 — where each node reads its
    final_slot: np.ndarray   # (n,) int32   final estimate (last level's x)
    disseminate: bool        # K >= 2: down-pass costs n messages
    seed: int
    rep_mode: str
    method: str = "vectorized"
    # host-side wall-clock breakdown of build_plan (seconds):
    # partition / cells / overlay / routes / incidence / total
    build_seconds: Optional[dict] = None
    # compiled-executor cache, keyed by engine config (see core.engine)
    exec_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def k(self) -> int:
        return self.partition.k

    # plans are pickled by the content-addressed plan cache
    # (core.plan_cache); the compiled-executor cache holds jitted
    # callables and must not ride along
    def __getstate__(self):
        state = dict(self.__dict__)
        state["exec_cache"] = {}
        return state

    def __setstate__(self, state):
        self.__dict__.update(state)
        if not self.__dict__.get("exec_cache"):
            self.exec_cache = {}


# --------------------------------------------------------------------------
# shared helpers (both builders)
# --------------------------------------------------------------------------


def _exclusive_starts(degrees: np.ndarray) -> tuple[np.ndarray, int]:
    """Row-major exclusive prefix sum of degrees → (start (B,C), nnz)."""
    deg = degrees.ravel().astype(np.int64)
    cs = np.concatenate([[0], np.cumsum(deg)])
    return cs[:-1].reshape(degrees.shape).astype(np.int32), int(cs[-1])


def _csr_fields_from_dense(
    neighbors: np.ndarray,
    degrees: np.ndarray,
    edge_hops: Optional[np.ndarray] = None,
    slot_node: Optional[np.ndarray] = None,
    partner_node: Optional[np.ndarray] = None,
    n: Optional[int] = None,
) -> dict:
    """Flatten dense padded adjacency into the LevelPlan CSR fields.

    Entry order is the dense row order, so jidx draws address the same
    neighbor in both layouts.
    """
    B, C, D = neighbors.shape
    start, nnz = _exclusive_starts(degrees)
    keep = np.arange(D)[None, None, :] < degrees[:, :, None]
    nbr_flat = np.concatenate(
        [neighbors[keep].astype(np.int32), np.array([0], np.int32)]
    )
    if edge_hops is None:
        hop_flat = np.ones(nnz + 1, np.int32)
    else:
        hop_flat = np.concatenate(
            [np.asarray(edge_hops)[keep].astype(np.int32),
             np.array([1], np.int32)]
        )
    fields = dict(
        nbr_start=start, nbr_flat=nbr_flat, hop_flat=hop_flat, max_deg=D,
        row_node=None, partner_flat=None,
    )
    if partner_node is not None:
        deg = degrees.ravel().astype(np.int64)
        fields["row_node"] = np.concatenate(
            [np.repeat(slot_node.ravel(), deg),
             np.array([n])]
        ).astype(np.int32)
        fields["partner_flat"] = np.concatenate(
            [partner_node[keep], np.array([n])]
        ).astype(np.int32)
    return fields


def _line16_factors(parents: np.ndarray, n_nodes: np.ndarray) -> np.ndarray:
    """Alg. 1 line-16 reweighting: cell_size * (#siblings) / (parent
    population), grouped by parent.  One bincount pass; the per-group
    float64 sums accumulate in index order, shared by both builders so
    their plans stay bitwise-identical."""
    B = len(parents)
    porder = np.argsort(parents, kind="stable")
    uniq, counts = np.unique(parents[porder], return_counts=True)
    gidx = np.empty(B, np.int64)
    gidx[porder] = np.repeat(np.arange(len(uniq)), counts)
    sizes = n_nodes.astype(np.float64)
    tot = np.bincount(gidx, weights=sizes, minlength=len(uniq))
    return (sizes * counts[gidx].astype(np.float64) / tot[gidx]).astype(
        np.float32
    )


def _elect(
    rng: np.random.Generator,
    mode: str,
    num: int,
    coords: np.ndarray,
    center: np.ndarray,
) -> int:
    """Local index of the representative among `num` members."""
    if mode == "first":
        return 0
    if mode == "random":
        return int(rng.integers(num))
    d = np.sum((coords - center) ** 2, axis=1)
    return int(np.argmin(d))


def _grid_components(num: int, edges: np.ndarray) -> np.ndarray:
    """Union-find component labels for a small local graph."""
    parent = np.arange(num)

    def find(u):
        while parent[u] != u:
            parent[u] = parent[parent[u]]
            u = parent[u]
        return u

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv
    return np.array([find(u) for u in range(num)])


def _connect_components(local_edges: list, coords: np.ndarray, num: int) -> list:
    """Add nearest-pair edges until the local rep graph is connected
    (handles empty sibling cells — paper §VII 'disconnected grids')."""
    if num <= 1:
        return local_edges
    while True:
        comp = _grid_components(
            num, np.asarray(local_edges, np.int64).reshape(-1, 2)
        )
        labels = np.unique(comp)
        if len(labels) == 1:
            return local_edges
        a = np.where(comp == labels[0])[0]
        b = np.where(comp != labels[0])[0]
        d = np.sum((coords[a][:, None, :] - coords[b][None, :, :]) ** 2, axis=2)
        ia, ib = np.unravel_index(int(np.argmin(d)), d.shape)
        local_edges.append((int(a[ia]), int(b[ib])))


class _OverlayGraph:
    """Duck-typed graph (n / max_deg / neighbors / degrees) for batching,
    tracking which row slot each undirected edge landed in (reference
    builder only; the vectorized builder assembles CSR directly)."""

    def __init__(self, num: int, edges: np.ndarray, hops: np.ndarray):
        self.n = num
        nbrs: list[list[int]] = [[] for _ in range(num)]
        hp: list[list[int]] = [[] for _ in range(num)]
        self.slot_i = np.zeros(len(edges), np.int32)  # slot of v in u's row
        self.slot_j = np.zeros(len(edges), np.int32)  # slot of u in v's row
        for e, ((u, v), h) in enumerate(zip(edges, hops)):
            self.slot_i[e] = len(nbrs[u])
            nbrs[u].append(int(v))
            hp[u].append(int(h))
            self.slot_j[e] = len(nbrs[v])
            nbrs[v].append(int(u))
            hp[v].append(int(h))
        self.max_deg = max(1, max((len(r) for r in nbrs), default=1))
        self.neighbors = np.full((num, self.max_deg), -1, np.int32)
        self.edge_hops = np.ones((num, self.max_deg), np.int32)
        self.degrees = np.array([len(r) for r in nbrs], np.int32)
        for u in range(num):
            self.neighbors[u, : len(nbrs[u])] = nbrs[u]
            self.edge_hops[u, : len(hp[u])] = hp[u]


def _route_incidence(routes: BatchedRoutes) -> tuple:
    """CSR incidence (inc_node, inc_edge, inc_count) of padded routes:
    one request+reply exchange over edge e makes its path endpoints
    transmit once and interior nodes twice (2 * hops total)."""
    E, W = routes.nodes.shape
    col = np.arange(W)[None, :]
    hops = routes.hops[:, None]
    on_path = (col <= hops) & (routes.nodes >= 0)
    count = np.where((col == 0) | (col == hops), 1, 2)
    e_idx = np.broadcast_to(np.arange(E)[:, None], (E, W))
    keep = on_path & (hops > 0)
    return (
        routes.nodes[keep].astype(np.int32),
        e_idx[keep].astype(np.int32),
        count[keep].astype(np.int32),
    )


def overlay_node_sends(
    lp: LevelPlan, usage: np.ndarray, n: int
) -> np.ndarray:
    """Reference (numpy) overlay attribution: per-edge exchange counts
    gathered from the flat usage counters, scatter-added through the
    route-incidence CSR.  The engine runs the same computation in JAX."""
    usage = np.asarray(usage)
    usage_e = (usage[lp.edge_pos_i] + usage[lp.edge_pos_j]).astype(np.int64)
    sends = np.zeros(n, np.int64)
    np.add.at(sends, lp.inc_node, usage_e[lp.inc_edge] * lp.inc_count)
    return sends


def _dissemination_maps(
    part: Partition, cur_cells: np.ndarray, final_lp: LevelPlan, n: int,
    coords: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Every node reads its level-2 cell's slot in the final (level-1)
    grid, which is a single graph."""
    lvl2 = part.cell_of(coords, 2)
    slot_of_cell = np.full(part.num_cells(2), -1, np.int32)
    # final level slots hold reps of level-2 cells, ordered like cur_cells
    top = int(final_lp.n_nodes[0])
    slot_of_cell[cur_cells[:top].astype(np.int64)] = np.arange(top, dtype=np.int32)
    final_graph = np.zeros(n, np.int32)
    final_slot = slot_of_cell[lvl2]
    assert (final_slot >= 0).all(), "every node's level-2 cell must be present"
    return final_graph, final_slot.astype(np.int32)


# --------------------------------------------------------------------------
# reference builder (the historical per-cell / per-group loops)
# --------------------------------------------------------------------------


def _build_reference(
    g: Graph, part: Partition, rng: np.random.Generator,
    seed: int, rep_mode: str, timings: dict,
) -> HierarchyPlan:
    n = g.n
    K = part.k
    rep_counts = np.zeros(n, np.int64)
    levels: list[LevelPlan] = []

    # ---------------- finest level: induced cell subgraphs ----------------
    t0 = time.perf_counter()
    cell_of_node = part.cell_of(g.coords, K)
    present_cells = np.unique(cell_of_node)
    subgraphs, sub_ids = [], []
    for c in present_cells:
        sg, ids = induced_subgraph(g, np.where(cell_of_node == c)[0])
        subgraphs.append(sg)
        sub_ids.append(ids)
    disconnected = sum(0 if sg.is_connected() else 1 for sg in subgraphs)
    neighbors, degrees, n_nodes, mask = batched_graphs(subgraphs)
    B, C = mask.shape
    slot_node = np.full((B, C), -1, np.int32)
    for b, ids in enumerate(sub_ids):
        slot_node[b, : len(ids)] = ids
    # partner in each directed slot, as a global node id
    nbr_safe = np.clip(neighbors, 0, None)
    partner = np.where(
        neighbors >= 0, np.take_along_axis(
            np.broadcast_to(slot_node[:, :, None], neighbors.shape),
            nbr_safe, axis=1,
        ), -1,
    ).astype(np.int32)

    # elect finest-cell representatives + Alg.1 line-16 reweighting factor
    centers = part.cell_center(K, present_cells)
    rep_slot = np.zeros(B, np.int32)
    for b, ids in enumerate(sub_ids):
        rep_slot[b] = _elect(rng, rep_mode, len(ids), g.coords[ids], centers[b])
    rep_node = slot_node[np.arange(B), rep_slot].astype(np.int64)
    line16 = np.ones(B, np.float32)
    if K >= 2:
        parents = part.parent_cell(K, present_cells)
        line16 = _line16_factors(parents, n_nodes)

    base_kwargs = dict(
        level=K, kind="cells", degrees=degrees,
        n_nodes=n_nodes, node_mask=mask, slot_node=slot_node,
        max_hops=1,
        **_csr_fields_from_dense(
            neighbors, degrees, edge_hops=None, slot_node=slot_node,
            partner_node=partner, n=n,
        ),
        edge_b=None, edge_i=None, edge_si=None, edge_j=None, edge_sj=None,
        edge_pos_i=None, edge_pos_j=None,
        inc_node=None, inc_edge=None, inc_count=None, routes=None,
    )
    timings["cells"] += time.perf_counter() - t0

    if K == 1:
        # degenerate single-level run: no promotion, but the per-cell
        # election still happens (and is counted) as in Alg. 1
        rep_counts[rep_node] += 1
        levels.append(LevelPlan(
            **base_kwargs, rep_slot=None, rep_node=None, line16=None,
            next_graph=None, next_slot=None,
        ))
        final_graph = np.zeros(n, np.int32)
        final_slot = np.zeros(n, np.int32)
        for b, ids in enumerate(sub_ids):
            final_graph[ids] = b
            final_slot[ids] = np.arange(len(ids))
        return HierarchyPlan(
            graph=g, partition=part, levels=tuple(levels),
            rep_counts=rep_counts, disconnected_cells=disconnected,
            final_graph=final_graph, final_slot=final_slot,
            disseminate=False, seed=seed, rep_mode=rep_mode,
            method="reference",
        )

    rep_counts[rep_node] += 1
    cur_cells, cur_level = present_cells, K
    pending_base = base_kwargs  # promotion targets filled once grouped

    # ---------------- overlay levels k-1 .. 1 ----------------
    while cur_level > 1:
        j = cur_level - 1
        t0 = time.perf_counter()
        parents = part.parent_cell(cur_level, cur_cells)
        all_edges = part.child_grid_edges(j)
        order = np.argsort(parents, kind="stable")
        uniq_parents, starts = np.unique(parents[order], return_index=True)
        groups = np.split(order, starts[1:])

        # promotion mapping for the previous level
        next_graph = np.zeros(len(cur_cells), np.int32)
        next_slot = np.zeros(len(cur_cells), np.int32)
        for b, grp in enumerate(groups):
            next_graph[grp] = b
            next_slot[grp] = np.arange(len(grp))
        if pending_base is not None:
            levels.append(LevelPlan(
                **pending_base, rep_slot=rep_slot, rep_node=rep_node,
                line16=line16, next_graph=next_graph, next_slot=next_slot,
            ))
            pending_base = None
        else:
            prev = levels[-1]
            levels[-1] = dataclasses.replace(
                prev, rep_slot=rep_slot, rep_node=rep_node,
                line16=np.ones(prev.num_graphs, np.float32),
                next_graph=next_graph, next_slot=next_slot,
            )

        # per-parent overlay grids; route ALL edges of the level at once
        group_edges, group_sizes = [], []
        for grp in groups:
            cells_here = cur_cells[grp]
            local = {int(c): i for i, c in enumerate(cells_here)}
            edges = [
                (local[int(u)], local[int(v)])
                for u, v in all_edges
                if int(u) in local and int(v) in local
            ]
            edges = _connect_components(edges, g.coords[rep_node[grp]], len(grp))
            group_edges.append(edges)
            group_sizes.append(len(grp))
        flat_pairs = np.concatenate([
            np.stack([
                rep_node[grp[[u for u, _ in edges]]],
                rep_node[grp[[v for _, v in edges]]],
            ], axis=1) if edges else np.zeros((0, 2), np.int64)
            for grp, edges in zip(groups, group_edges)
        ]) if groups else np.zeros((0, 2), np.int64)
        timings["overlay"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        routes = batched_routes_to_nodes(g, flat_pairs)
        timings["routes"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        hops_all = np.maximum(1, routes.hops)
        level_max_hops = int(hops_all.max()) if len(hops_all) else 1

        overlay_graphs = []
        e0 = 0
        edge_b, edge_i, edge_si, edge_j, edge_sj = [], [], [], [], []
        for b, (grp, edges) in enumerate(zip(groups, group_edges)):
            m = len(edges)
            og = _OverlayGraph(
                len(grp), np.asarray(edges, np.int64).reshape(-1, 2),
                hops_all[e0 : e0 + m],
            )
            overlay_graphs.append(og)
            for e in range(m):
                u, v = edges[e]
                edge_b.append(b)
                edge_i.append(u)
                edge_si.append(og.slot_i[e])
                edge_j.append(v)
                edge_sj.append(og.slot_j[e])
            e0 += m

        neighbors, degrees, n_nodes, mask = batched_graphs(overlay_graphs)
        Bg, Cg = mask.shape
        edge_hops = np.ones((Bg, Cg, neighbors.shape[2]), np.int32)
        slot_node = np.full((Bg, Cg), -1, np.int32)
        for b, (og, grp) in enumerate(zip(overlay_graphs, groups)):
            edge_hops[b, : og.n, : og.max_deg] = og.edge_hops
            slot_node[b, : og.n] = rep_node[grp]
        csr = _csr_fields_from_dense(neighbors, degrees, edge_hops=edge_hops)
        edge_b = np.asarray(edge_b, np.int32)
        edge_i = np.asarray(edge_i, np.int32)
        edge_si = np.asarray(edge_si, np.int32)
        edge_j = np.asarray(edge_j, np.int32)
        edge_sj = np.asarray(edge_sj, np.int32)
        start = csr["nbr_start"]
        timings["overlay"] += time.perf_counter() - t0
        t0 = time.perf_counter()
        inc_node, inc_edge, inc_count = _route_incidence(routes)
        timings["incidence"] += time.perf_counter() - t0
        t0 = time.perf_counter()

        overlay_kwargs = dict(
            level=j, kind="overlay", degrees=degrees,
            n_nodes=n_nodes, node_mask=mask,
            slot_node=slot_node, max_hops=level_max_hops,
            **csr,
            edge_b=edge_b, edge_i=edge_i, edge_si=edge_si,
            edge_j=edge_j, edge_sj=edge_sj,
            edge_pos_i=(start[edge_b, edge_i] + edge_si).astype(np.int32),
            edge_pos_j=(start[edge_b, edge_j] + edge_sj).astype(np.int32),
            inc_node=inc_node, inc_edge=inc_edge, inc_count=inc_count,
            routes=routes,
        )

        if j == 1:
            levels.append(LevelPlan(
                **overlay_kwargs, rep_slot=None, rep_node=None, line16=None,
                next_graph=None, next_slot=None,
            ))
            timings["overlay"] += time.perf_counter() - t0
            break

        # elect a level-j representative per grid (promotion filled on the
        # next iteration, once the grouping at level j-1 is known)
        centers = part.cell_center(j, uniq_parents)
        rep_slot = np.zeros(Bg, np.int32)
        for b, grp in enumerate(groups):
            rep_slot[b] = _elect(
                rng, rep_mode, len(grp), g.coords[rep_node[grp]], centers[b]
            )
        new_rep_node = slot_node[np.arange(Bg), rep_slot].astype(np.int64)
        rep_counts[new_rep_node] += 1
        levels.append(LevelPlan(
            **overlay_kwargs, rep_slot=rep_slot, rep_node=new_rep_node,
            line16=np.ones(Bg, np.float32), next_graph=None, next_slot=None,
        ))
        rep_node = new_rep_node
        cur_cells, cur_level = uniq_parents, j
        timings["overlay"] += time.perf_counter() - t0

    final_graph, final_slot = _dissemination_maps(
        part, cur_cells, levels[-1], n, g.coords
    )
    return HierarchyPlan(
        graph=g, partition=part, levels=tuple(levels),
        rep_counts=rep_counts, disconnected_cells=disconnected,
        final_graph=final_graph, final_slot=final_slot,
        disseminate=True, seed=seed, rep_mode=rep_mode, method="reference",
    )


# --------------------------------------------------------------------------
# vectorized builder (default)
# --------------------------------------------------------------------------


def _group_by(keys: np.ndarray) -> tuple:
    """Stable grouping: returns (order, uniq, group_of, loc_of, counts)
    with `group_of[i]` the group index of element i and `loc_of[i]` its
    rank within the group (original order preserved — matches the
    reference builder's np.split over a stable argsort)."""
    m = len(keys)
    order = np.argsort(keys, kind="stable")
    uniq, counts = np.unique(keys[order], return_counts=True)
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    group_of = np.empty(m, np.int64)
    group_of[order] = np.repeat(np.arange(len(uniq)), counts)
    loc_of = np.empty(m, np.int64)
    loc_of[order] = np.arange(m) - np.repeat(starts, counts)
    return order, uniq, group_of, loc_of, counts


def _components_per_group(
    num: int, src: Optional[np.ndarray], dst: Optional[np.ndarray],
    group_of: np.ndarray, n_groups: int,
    csr: Optional[tuple[np.ndarray, np.ndarray]] = None,
) -> np.ndarray:
    """#connected components per group for a graph on `num` vertices
    whose edges never cross groups.  Pass ``csr=(indptr, indices)`` when
    the adjacency is already in CSR layout — skips the COO build/sort,
    which dominates at nnz ~ 10^8."""
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components

    if csr is not None:
        indptr, indices = csr
        if len(indices):
            adj = sp.csr_matrix(
                (np.ones(len(indices), np.int8), indices, indptr),
                shape=(num, num),
            )
            _, labels = connected_components(adj, directed=False)
        else:
            labels = np.arange(num)
    elif len(src):
        adj = sp.coo_matrix(
            (np.ones(len(src), np.int8), (src, dst)), shape=(num, num)
        )
        _, labels = connected_components(adj, directed=False)
    else:
        labels = np.arange(num)
    key = group_of.astype(np.int64) * (num + 1) + labels
    uniq = np.unique(key)
    return np.bincount(uniq // (num + 1), minlength=n_groups)


# nodes per sub-band of the in-cell edge scan: ~64k rows keeps every
# slice (flat ids, repeated cells, keep mask) a few MB — cache-resident
# on the same host the graph builder's chunk size was tuned for
_CELLS_BAND = 65_536


def _cells_edge_chunk(payload, lohi):
    """fork_map task: filter one contiguous NATURAL node range [lo, hi)
    of the CSR adjacency down to in-cell edges.  `nbr_flat` is scanned
    sequentially and the only gather is into the n-int32 cell-id table
    (cache-resident), so the pass is memory-bandwidth bound on one read
    of the flat slice — rank-ordering the survivors is the caller's
    O(kept) permute, not an O(nnz) reorder here.  Returns (kept-count
    per row, kept partner ids); chunks concatenated in task order
    reproduce the full natural-order edge stream bitwise."""
    nbr_start, nbr_flat, degrees, cell32 = payload
    lo, hi = lohi
    kept_counts, kept_dst = [], []
    for b0 in range(lo, hi, _CELLS_BAND):
        b1 = min(b0 + _CELLS_BAND, hi)
        s0 = int(nbr_start[b0])
        flat = nbr_flat[s0:int(nbr_start[b1])]
        keep = cell32[flat] == np.repeat(cell32[b0:b1], degrees[b0:b1])
        ck = np.zeros(len(flat) + 1, np.int64)
        np.cumsum(keep, out=ck[1:])
        bound = nbr_start[b0:b1 + 1] - s0
        kept_counts.append(ck[bound[1:]] - ck[bound[:-1]])
        kept_dst.append(flat[keep])
    if not kept_counts:
        return np.zeros(0, np.int64), np.zeros(0, np.int32)
    return np.concatenate(kept_counts), np.concatenate(kept_dst)


def _build_vectorized(
    g: Graph, part: Partition, rng: np.random.Generator,
    seed: int, rep_mode: str, timings: dict, workers: int = 0,
) -> HierarchyPlan:
    n = g.n
    K = part.k
    rep_counts = np.zeros(n, np.int64)
    levels: list[LevelPlan] = []
    coords = g.coords

    # ---------------- finest level: induced cell subgraphs ----------------
    t0 = time.perf_counter()
    cell_of_node = part.cell_of(coords, K)
    _, present_cells, graph_of, local_of, cell_sizes = _group_by(cell_of_node)
    B = len(present_cells)
    C = int(cell_sizes.max())
    n_nodes = cell_sizes.astype(np.int32)
    mask = np.arange(C)[None, :] < n_nodes[:, None]
    slot_node = np.full((B, C), -1, np.int32)
    slot_node[graph_of, local_of] = np.arange(n, dtype=np.int32)

    # all in-cell directed edges, flattened in (node, row-slot) order —
    # exactly the induced_subgraph row order of the reference builder.
    # The filter scans the CSR in natural node order (the sequential,
    # cache-friendly direction; sharded over node ranges when
    # workers > 1), then permutes only the O(kept) survivors into rank
    # (cell-sorted) row order — never the O(nnz) stream.  The result is
    # the same edge sequence as the historical filter-then-stable-sort.
    order = np.argsort(cell_of_node, kind="stable")
    rank = np.empty(n, np.int64)
    rank[order] = np.arange(n)
    cell32 = cell_of_node.astype(np.int32)
    payload = (g.nbr_start, g.nbr_flat, g.degrees, cell32)
    if workers > 1 and n >= 2 * workers:
        from .parallel import fork_map

        bounds = np.linspace(0, n, workers + 1).astype(np.int64)
        tasks = [(int(bounds[i]), int(bounds[i + 1]))
                 for i in range(workers)]
        chunks = fork_map(
            _cells_edge_chunk, tasks, workers=workers, payload=payload
        )
    else:
        chunks = [_cells_edge_chunk(payload, (0, n))]
    kept_nat = np.concatenate([c[0] for c in chunks])  # natural row order
    dst_nat = np.concatenate([c[1] for c in chunks])
    # natural -> rank row order: within-row order is CSR order on both
    # sides, so each row just shifts by (rank-space start - natural one)
    kept_ord = kept_nat[order]
    out_start = np.zeros(n, np.int64)
    np.cumsum(kept_ord[:-1], out=out_start[1:])
    nat_start = np.zeros(n, np.int64)
    np.cumsum(kept_nat[:-1], out=nat_start[1:])
    pos = (
        np.repeat(out_start[rank] - nat_start, kept_nat)
        + np.arange(len(dst_nat), dtype=np.int64)
    )
    dst = np.empty_like(dst_nat)
    dst[pos] = dst_nat
    src = np.repeat(order.astype(np.int32), kept_ord)
    degrees = np.zeros((B, C), np.int32)
    degrees[graph_of, local_of] = kept_nat.astype(np.int32)
    nbr_start, nnz = _exclusive_starts(degrees)
    nbr_flat = np.concatenate(
        [local_of[dst], [0]]
    ).astype(np.int32)
    hop_flat = np.ones(nnz + 1, np.int32)
    row_node = np.concatenate([src, [n]]).astype(np.int32)
    partner_flat = np.concatenate([dst, [n]]).astype(np.int32)
    max_deg = max(1, int(kept_nat.max(initial=0)))

    # disconnected-cell count via sparse connected components, fed the
    # rank-space CSR we already hold (COO build/sort skipped)
    comp_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(kept_ord, out=comp_indptr[1:])
    comp_per_cell = _components_per_group(
        n, None, None, graph_of[order], B,
        csr=(comp_indptr, rank[dst]),
    )
    disconnected = int((comp_per_cell > 1).sum())

    # elect finest-cell representatives + Alg.1 line-16 reweighting factor
    centers = part.cell_center(K, present_cells)
    rep_slot = np.zeros(B, np.int32)
    if rep_mode == "random":
        for b in range(B):
            rep_slot[b] = int(rng.integers(int(cell_sizes[b])))
    elif rep_mode != "first":
        order = np.argsort(cell_of_node, kind="stable")
        d = np.sum((coords[order] - centers[graph_of[order]]) ** 2, axis=1)
        # first-minimum per group, matching np.argmin's tie-break
        o2 = np.lexsort((np.arange(n), d, graph_of[order]))
        firsts = o2[np.unique(graph_of[order][o2], return_index=True)[1]]
        rep_slot = (firsts - np.concatenate(
            [[0], np.cumsum(cell_sizes)])[:-1][graph_of[order][firsts]]
        ).astype(np.int32)
    rep_node = slot_node[np.arange(B), rep_slot].astype(np.int64)
    line16 = np.ones(B, np.float32)
    if K >= 2:
        parents = part.parent_cell(K, present_cells)
        line16 = _line16_factors(parents, n_nodes)

    base_kwargs = dict(
        level=K, kind="cells", degrees=degrees, n_nodes=n_nodes,
        node_mask=mask, slot_node=slot_node, max_hops=1,
        nbr_start=nbr_start, nbr_flat=nbr_flat, hop_flat=hop_flat,
        max_deg=max_deg, row_node=row_node, partner_flat=partner_flat,
        edge_b=None, edge_i=None, edge_si=None, edge_j=None, edge_sj=None,
        edge_pos_i=None, edge_pos_j=None,
        inc_node=None, inc_edge=None, inc_count=None, routes=None,
    )
    timings["cells"] += time.perf_counter() - t0

    if K == 1:
        rep_counts[rep_node] += 1
        levels.append(LevelPlan(
            **base_kwargs, rep_slot=None, rep_node=None, line16=None,
            next_graph=None, next_slot=None,
        ))
        return HierarchyPlan(
            graph=g, partition=part, levels=tuple(levels),
            rep_counts=rep_counts, disconnected_cells=disconnected,
            final_graph=graph_of.astype(np.int32),
            final_slot=local_of.astype(np.int32),
            disseminate=False, seed=seed, rep_mode=rep_mode,
        )

    rep_counts[rep_node] += 1
    cur_cells, cur_level = present_cells, K
    pending_base = base_kwargs

    # ---------------- overlay levels k-1 .. 1 ----------------
    while cur_level > 1:
        j = cur_level - 1
        t0 = time.perf_counter()
        Bc = len(cur_cells)
        parents = part.parent_cell(cur_level, cur_cells)
        porder, uniq_parents, group_of, loc_of, gcount = _group_by(parents)
        G = len(uniq_parents)
        gstart = np.concatenate([[0], np.cumsum(gcount)])[:-1]

        # promotion mapping for the previous level
        next_graph = group_of.astype(np.int32)
        next_slot = loc_of.astype(np.int32)
        if pending_base is not None:
            levels.append(LevelPlan(
                **pending_base, rep_slot=rep_slot, rep_node=rep_node,
                line16=line16, next_graph=next_graph, next_slot=next_slot,
            ))
            pending_base = None
        else:
            prev = levels[-1]
            levels[-1] = dataclasses.replace(
                prev, rep_slot=rep_slot, rep_node=rep_node,
                line16=np.ones(prev.num_graphs, np.float32),
                next_graph=next_graph, next_slot=next_slot,
            )

        # base grid edges, mapped to positions in cur_cells and grouped by
        # parent in one pass (the reference builder's per-group filter over
        # ALL grid edges was the quadratic hot spot at large n)
        all_edges = part.child_grid_edges(j)
        pos_of = np.full(part.num_cells(cur_level), -1, np.int64)
        pos_of[cur_cells.astype(np.int64)] = np.arange(Bc)
        eu = pos_of[all_edges[:, 0]]
        ev = pos_of[all_edges[:, 1]]
        ekeep = (eu >= 0) & (ev >= 0)
        eu, ev = eu[ekeep], ev[ekeep]
        same = group_of[eu] == group_of[ev]
        eu, ev = eu[same], ev[same]
        ge = group_of[eu]
        eord = np.argsort(ge, kind="stable")
        eu, ev, ge = eu[eord], ev[eord], ge[eord]
        lu, lv = loc_of[eu], loc_of[ev]
        E = len(lu)
        ecount = np.bincount(ge, minlength=G)
        estart = np.concatenate([[0], np.cumsum(ecount)])[:-1]

        # repair disconnected groups exactly like the reference builder:
        # detect with sparse connected components (cheap), then run the
        # sequential nearest-pair augmentation on just those groups
        comp_per_group = _components_per_group(Bc, eu, ev, group_of, G)
        bad = np.nonzero(comp_per_group > 1)[0]
        if len(bad):
            add_u, add_v, add_g, add_k = [], [], [], []
            for gg in bad:
                s0, m0 = int(estart[gg]), int(ecount[gg])
                base = list(zip(lu[s0 : s0 + m0].tolist(),
                                lv[s0 : s0 + m0].tolist()))
                members = porder[gstart[gg] : gstart[gg] + gcount[gg]]
                full = _connect_components(
                    list(base), coords[rep_node[members]], int(gcount[gg])
                )
                for idx, (uu, vv) in enumerate(full[m0:]):
                    add_u.append(uu)
                    add_v.append(vv)
                    add_g.append(int(gg))
                    add_k.append(m0 + idx)
            base_key = np.arange(E) - estart[ge]
            lu = np.concatenate([lu, np.asarray(add_u, np.int64)])
            lv = np.concatenate([lv, np.asarray(add_v, np.int64)])
            ge = np.concatenate([ge, np.asarray(add_g, np.int64)])
            ekey = np.concatenate([base_key, np.asarray(add_k, np.int64)])
            ford = np.lexsort((ekey, ge))
            lu, lv, ge = lu[ford], lv[ford], ge[ford]
            E = len(lu)
            ecount = np.bincount(ge, minlength=G)
            estart = np.concatenate([[0], np.cumsum(ecount)])[:-1]
        timings["overlay"] += time.perf_counter() - t0

        # route ALL edges of the level at once
        t0 = time.perf_counter()
        cell_u = porder[gstart[ge] + lu] if E else np.zeros(0, np.int64)
        cell_v = porder[gstart[ge] + lv] if E else np.zeros(0, np.int64)
        flat_pairs = np.stack(
            [rep_node[cell_u], rep_node[cell_v]], axis=1
        ) if E else np.zeros((0, 2), np.int64)
        routes = batched_routes_to_nodes(g, flat_pairs, workers=workers)
        timings["routes"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        hops_all = np.maximum(1, routes.hops)
        level_max_hops = int(hops_all.max()) if len(hops_all) else 1

        # CSR overlay adjacency: each undirected edge contributes its two
        # directed entries in append order (u's entry then v's), so a
        # stable sort by row reproduces the reference row layout
        Cg = int(gcount.max())
        ent_g = np.repeat(ge, 2)
        ent_node = np.empty(2 * E, np.int64)
        ent_node[0::2] = lu
        ent_node[1::2] = lv
        ent_other = np.empty(2 * E, np.int64)
        ent_other[0::2] = lv
        ent_other[1::2] = lu
        ent_hop = np.repeat(hops_all.astype(np.int64), 2)
        rowid = ent_g * Cg + ent_node
        sord = np.argsort(rowid, kind="stable")
        rs = rowid[sord]
        newrun = np.concatenate([[True], rs[1:] != rs[:-1]]) \
            if len(rs) else np.zeros(0, bool)
        runstart = np.nonzero(newrun)[0]
        runidx = np.cumsum(newrun) - 1
        slot_sorted = np.arange(2 * E) - runstart[runidx] \
            if len(rs) else np.zeros(0, np.int64)
        slot = np.empty(2 * E, np.int64)
        slot[sord] = slot_sorted
        degrees = np.bincount(
            rowid, minlength=G * Cg
        ).astype(np.int32).reshape(G, Cg)
        nbr_start, nnz = _exclusive_starts(degrees)
        nbr_flat = np.concatenate([ent_other[sord], [0]]).astype(np.int32)
        hop_flat = np.concatenate([ent_hop[sord], [1]]).astype(np.int32)
        max_deg = max(1, int(degrees.max(initial=0)))
        flatpos = nbr_start.ravel()[rowid] + slot
        n_nodes = gcount.astype(np.int32)
        mask = np.arange(Cg)[None, :] < n_nodes[:, None]
        slot_node = np.full((G, Cg), -1, np.int32)
        slot_node[group_of, loc_of] = rep_node.astype(np.int32)
        timings["overlay"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        inc_node, inc_edge, inc_count = _route_incidence(routes)
        timings["incidence"] += time.perf_counter() - t0
        t0 = time.perf_counter()

        overlay_kwargs = dict(
            level=j, kind="overlay", degrees=degrees, n_nodes=n_nodes,
            node_mask=mask, slot_node=slot_node, max_hops=level_max_hops,
            nbr_start=nbr_start, nbr_flat=nbr_flat, hop_flat=hop_flat,
            max_deg=max_deg, row_node=None, partner_flat=None,
            edge_b=ge.astype(np.int32),
            edge_i=lu.astype(np.int32),
            edge_si=slot[0::2].astype(np.int32),
            edge_j=lv.astype(np.int32),
            edge_sj=slot[1::2].astype(np.int32),
            edge_pos_i=flatpos[0::2].astype(np.int32),
            edge_pos_j=flatpos[1::2].astype(np.int32),
            inc_node=inc_node, inc_edge=inc_edge, inc_count=inc_count,
            routes=routes,
        )

        if j == 1:
            levels.append(LevelPlan(
                **overlay_kwargs, rep_slot=None, rep_node=None, line16=None,
                next_graph=None, next_slot=None,
            ))
            timings["overlay"] += time.perf_counter() - t0
            break

        # elect a level-j representative per grid (promotion filled on the
        # next iteration, once the grouping at level j-1 is known)
        centers = part.cell_center(j, uniq_parents)
        rep_slot = np.zeros(G, np.int32)
        if rep_mode == "random":
            for b in range(G):
                rep_slot[b] = int(rng.integers(int(gcount[b])))
        elif rep_mode != "first":
            for b in range(G):
                members = porder[gstart[b] : gstart[b] + gcount[b]]
                d = np.sum(
                    (coords[rep_node[members]] - centers[b]) ** 2, axis=1
                )
                rep_slot[b] = int(np.argmin(d))
        new_rep_node = slot_node[np.arange(G), rep_slot].astype(np.int64)
        rep_counts[new_rep_node] += 1
        levels.append(LevelPlan(
            **overlay_kwargs, rep_slot=rep_slot, rep_node=new_rep_node,
            line16=np.ones(G, np.float32), next_graph=None, next_slot=None,
        ))
        rep_node = new_rep_node
        cur_cells, cur_level = uniq_parents, j
        timings["overlay"] += time.perf_counter() - t0

    final_graph, final_slot = _dissemination_maps(
        part, cur_cells, levels[-1], n, coords
    )
    return HierarchyPlan(
        graph=g, partition=part, levels=tuple(levels),
        rep_counts=rep_counts, disconnected_cells=disconnected,
        final_graph=final_graph, final_slot=final_slot,
        disseminate=True, seed=seed, rep_mode=rep_mode,
    )


def build_plan(
    g: Graph,
    *,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
    method: str = "vectorized",
    workers: int = 0,
) -> HierarchyPlan:
    """One ahead-of-time pass over the deployment: partition, batched
    induced subgraphs, overlay grids, representative election, batched
    routes, and attribution CSR for every level.

    `method="vectorized"` (default) and `method="reference"` build
    bitwise-identical plans; the reference path keeps the historical
    python loops as the oracle (it is quadratic in n — use it only at
    fig3 scales).

    ``workers > 1`` shards the cell-filter and routing stages of the
    vectorized builder across a fork pool (`core.parallel`); the output
    is bitwise-identical to the serial build (tested), so the knob is
    purely a wall-clock lever on multi-core hosts.  It never changes
    the plan, and is excluded from the plan-cache key.
    """
    if method not in PLAN_METHODS:
        raise ValueError(f"unknown plan method {method!r}")
    timings = {"partition": 0.0, "cells": 0.0, "overlay": 0.0,
               "routes": 0.0, "incidence": 0.0}
    t_all = time.perf_counter()
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    part = build_partition(g.n, k=k, a=a, cell_max=cell_max)
    timings["partition"] += time.perf_counter() - t0
    if method == "vectorized":
        plan = _build_vectorized(
            g, part, rng, seed, rep_mode, timings, workers=workers
        )
    else:
        plan = _build_reference(g, part, rng, seed, rep_mode, timings)
    timings["total"] = time.perf_counter() - t_all
    timings["workers"] = workers
    plan.build_seconds = {kk: round(v, 6) for kk, v in timings.items()}
    return plan
