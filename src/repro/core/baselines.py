"""Baselines the paper compares against (§II, §VI).

* `path_averaging`  — Benezit et al. [13]: route to a random target,
  average ALL nodes along the path (the state of the art the paper
  benchmarks against in Fig. 3/5).
* `geographic_gossip` — Dimakis et al. [11]: route to a random target,
  pairwise-average with the recipient only.
* `standard_gossip` — Boyd et al. [2]: single-hop neighbor gossip
  (wraps the batched engine with B=1).

Both routing-heavy baselines draw their routes through the same
vectorized router the plan/execute core uses
(`routing.batched_greedy_routes`): routes for a large block of upcoming
iterations are computed in one batched frontier-stepping call, consumed
in convergence-check windows, and send attribution is a vectorized
scatter-add over the padded path arrays
(`routing.accumulate_route_sends`) instead of per-hop Python loops.
Only the value updates remain sequential (they are order-dependent);
they are O(path length) numpy ops per iteration.

The (source, target) stream is drawn in the same per-iteration order as
the historical scalar implementation, and routing is value- and
rng-free, so in the reliable regime the trajectory, message count, and
attribution are draw-for-draw identical to the pre-batching code.

All report total single-hop transmissions and per-node send counts so
the paper's figures can be reproduced exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .gossip import gossip_until
from .rgg import Graph
from .routing import accumulate_route_sends, batched_greedy_routes

_ROUTE_BLOCK = 512  # iterations routed per batched router call

__all__ = [
    "BaselineResult",
    "path_averaging",
    "geographic_gossip",
    "standard_gossip",
]


@dataclasses.dataclass
class BaselineResult:
    x: np.ndarray            # (n,) final estimates
    messages: int            # total single-hop transmissions
    iterations: int
    converged: bool
    node_sends: np.ndarray   # (n,)

    def error(self, x0: np.ndarray) -> float:
        avg = float(np.mean(x0))
        return float(np.linalg.norm(self.x - avg) / np.linalg.norm(x0))


def _block_routes(g: Graph, rng: np.random.Generator, count: int):
    """Draw `count` (source, random-target) requests — in the exact
    per-iteration order of the scalar reference, so trajectories are
    reproducible draw-for-draw — and route them in one batched call."""
    srcs = np.empty(count, np.int64)
    targets = np.empty((count, 2))
    for i in range(count):
        srcs[i] = rng.integers(g.n)
        targets[i] = rng.uniform(0.0, 1.0, 2)
    return srcs, batched_greedy_routes(g, srcs, targets)


def path_averaging(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_iters: int = 2_000_000,
    check_every: int = 32,
    loss_p: Optional[float] = None,
) -> BaselineResult:
    """Randomized path averaging [13].

    One iteration: a uniformly random node wakes, draws a uniform target
    location, greedy-routes toward it accumulating values (|S|-1
    messages), the recipient averages and sends the result back down the
    path (|S|-1 messages), and every path node adopts the average.

    With `loss_p`, every single-hop transmission independently succeeds
    w.p. loss_p; a lost forward message aborts the iteration, a lost
    reply strands the prefix of the path with stale values (mass is
    distorted — paper §VI-C-2).
    """
    rng = np.random.default_rng(seed)
    n = g.n
    x = np.asarray(x0, np.float64).copy()
    mean = float(np.mean(x0))
    tol = eps * float(np.linalg.norm(x0))
    node_sends = np.zeros(n, np.int64)
    messages = 0
    it = 0
    converged = False
    while it < max_iters and not converged:
        # a block is a whole number of convergence windows so checks land
        # on the same global iteration counts as the scalar reference
        # (which, like this loop, may overshoot max_iters by < check_every)
        windows_left = -(-(max_iters - it) // check_every)
        block = check_every * max(1, min(_ROUTE_BLOCK // check_every, windows_left))
        _, routes = _block_routes(g, rng, block)
        nodes, hops = routes.nodes, routes.hops
        for w0 in range(0, block, check_every):
            w1 = w0 + check_every
            it += check_every
            if loss_p is None:
                messages += int(2 * hops[w0:w1].sum())
                accumulate_route_sends(
                    node_sends, nodes[w0:w1], hops[w0:w1]
                )
                for r in range(w0, w1):
                    L = int(hops[r])
                    if L == 0:
                        continue  # degenerate: src already closest to target
                    p = nodes[r, : L + 1]
                    x[p] = x[p].mean()
            else:
                fwd_fail = rng.geometric(1.0 - loss_p, size=w1 - w0)
                rep_fail = rng.geometric(1.0 - loss_p, size=w1 - w0)
                for r in range(w0, w1):
                    L = int(hops[r])
                    if L == 0:
                        continue
                    p = nodes[r, : L + 1]
                    # forward pass: hop t = p[t-1] -> p[t]
                    if fwd_fail[r - w0] <= L:
                        f = int(fwd_fail[r - w0])
                        messages += f
                        node_sends[p[:f]] += 1
                        continue
                    messages += L
                    node_sends[p[:-1]] += 1
                    avg = float(x[p].mean())
                    # reply pass: hop t = p[L-t+1] -> p[L-t]
                    upd = int(min(rep_fail[r - w0], L))
                    messages += upd
                    node_sends[p[L : L - upd : -1]] += 1
                    x[p[L - upd + 1 :]] = avg  # recipient + delivered prefix
            if np.linalg.norm(x - mean) <= tol:
                converged = True
                break
    return BaselineResult(
        x=x, messages=messages, iterations=it, converged=converged,
        node_sends=node_sends,
    )


def geographic_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_iters: int = 5_000_000,
    check_every: int = 64,
) -> BaselineResult:
    """Geographic gossip [11]: pairwise averaging with the node closest
    to a random target location, 2*hops messages per iteration."""
    rng = np.random.default_rng(seed)
    n = g.n
    x = np.asarray(x0, np.float64).copy()
    mean = float(np.mean(x0))
    tol = eps * float(np.linalg.norm(x0))
    node_sends = np.zeros(n, np.int64)
    messages = 0
    it = 0
    converged = False
    while it < max_iters and not converged:
        windows_left = -(-(max_iters - it) // check_every)
        block = check_every * max(1, min(_ROUTE_BLOCK // check_every, windows_left))
        srcs, routes = _block_routes(g, rng, block)
        nodes, hops = routes.nodes, routes.hops
        dsts = nodes[np.arange(block), hops]
        for w0 in range(0, block, check_every):
            w1 = w0 + check_every
            it += check_every
            messages += int(2 * hops[w0:w1].sum())
            accumulate_route_sends(node_sends, nodes[w0:w1], hops[w0:w1])
            for r in range(w0, w1):
                if hops[r] == 0:
                    continue
                avg = 0.5 * (x[srcs[r]] + x[dsts[r]])
                x[srcs[r]] = avg
                x[dsts[r]] = avg
            if np.linalg.norm(x - mean) <= tol:
                converged = True
                break
    return BaselineResult(
        x=x, messages=messages, iterations=it, converged=converged,
        node_sends=node_sends,
    )


def standard_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_ticks: int = 50_000_000,
) -> BaselineResult:
    """Single-hop randomized gossip [2] via the batched engine (B=1)."""
    res = gossip_until(
        np.asarray(x0, np.float32)[None, :],
        g.neighbors[None],
        g.degrees[None],
        np.array([g.n], np.int32),
        eps=eps,
        seed=seed,
        max_ticks=max_ticks,
    )
    usage = res.edge_usage[0]
    node_sends = usage.sum(axis=1).astype(np.int64)
    valid = g.neighbors >= 0
    np.add.at(node_sends, g.neighbors[valid], usage[valid])
    return BaselineResult(
        x=res.estimates()[0, : g.n],
        messages=res.total_messages,
        iterations=int(res.ticks[0]),
        converged=bool(res.converged[0]),
        node_sends=node_sends,
    )
