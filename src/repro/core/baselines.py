"""Baselines the paper compares against (§II, §VI).

* `path_averaging`  — Benezit et al. [13]: route to a random target,
  average ALL nodes along the path (the state of the art the paper
  benchmarks against in Fig. 3/5).
* `geographic_gossip` — Dimakis et al. [11]: route to a random target,
  pairwise-average with the recipient only.
* `standard_gossip` — Boyd et al. [2]: single-hop neighbor gossip
  (wraps the batched engine with B=1).

All report total single-hop transmissions and per-node send counts so
the paper's figures can be reproduced exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .gossip import gossip_until
from .rgg import Graph

__all__ = [
    "BaselineResult",
    "path_averaging",
    "geographic_gossip",
    "standard_gossip",
]


@dataclasses.dataclass
class BaselineResult:
    x: np.ndarray            # (n,) final estimates
    messages: int            # total single-hop transmissions
    iterations: int
    converged: bool
    node_sends: np.ndarray   # (n,)

    def error(self, x0: np.ndarray) -> float:
        avg = float(np.mean(x0))
        return float(np.linalg.norm(self.x - avg) / np.linalg.norm(x0))


def _greedy_path(g: Graph, src: int, target_xy: np.ndarray) -> list[int]:
    """Greedy geographic route; returns node list ending at the local
    minimizer of distance-to-target (the message recipient)."""
    coords = g.coords
    cur = int(src)
    d_cur = float((coords[cur, 0] - target_xy[0]) ** 2 + (coords[cur, 1] - target_xy[1]) ** 2)
    path = [cur]
    while True:
        deg = g.degrees[cur]
        if deg == 0:
            return path
        nbrs = g.neighbors[cur, :deg]
        d = np.sum((coords[nbrs] - target_xy) ** 2, axis=1)
        best = int(np.argmin(d))
        if d[best] >= d_cur:
            return path
        cur = int(nbrs[best])
        d_cur = float(d[best])
        path.append(cur)


def path_averaging(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_iters: int = 2_000_000,
    check_every: int = 32,
    loss_p: Optional[float] = None,
) -> BaselineResult:
    """Randomized path averaging [13].

    One iteration: a uniformly random node wakes, draws a uniform target
    location, greedy-routes toward it accumulating values (|S|-1
    messages), the recipient averages and sends the result back down the
    path (|S|-1 messages), and every path node adopts the average.

    With `loss_p`, every single-hop transmission independently succeeds
    w.p. loss_p; a lost forward message aborts the iteration, a lost
    reply strands the prefix of the path with stale values (mass is
    distorted — paper §VI-C-2).
    """
    rng = np.random.default_rng(seed)
    n = g.n
    x = np.asarray(x0, np.float64).copy()
    mean = float(np.mean(x0))
    tol = eps * float(np.linalg.norm(x0))
    node_sends = np.zeros(n, np.int64)
    messages = 0
    it = 0
    converged = False
    while it < max_iters:
        for _ in range(check_every):
            it += 1
            src = int(rng.integers(n))
            target = rng.uniform(0.0, 1.0, 2)
            path = _greedy_path(g, src, target)
            L = len(path) - 1
            if L == 0:
                # degenerate: src is already closest to the target
                continue
            if loss_p is None:
                messages += 2 * L
                node_sends[path[:-1]] += 1
                node_sends[path[1:]] += 1
                x[path] = np.mean(x[path])
            else:
                # forward pass: hop t = path[t-1] -> path[t]
                fwd_fail = rng.geometric(1.0 - loss_p)  # first failing hop
                if fwd_fail <= L:
                    messages += fwd_fail
                    node_sends[path[:fwd_fail]] += 1
                    continue
                messages += L
                node_sends[path[:-1]] += 1
                avg = float(np.mean(x[path]))
                # reply pass: hop t = path[L-t+1] -> path[L-t]
                rep_fail = rng.geometric(1.0 - loss_p)
                upd = min(rep_fail, L)
                messages += upd
                node_sends[path[L : L - upd : -1]] += 1
                x[path[L - upd + 1 :]] = avg  # recipient + delivered prefix
        if np.linalg.norm(x - mean) <= tol:
            converged = True
            break
    return BaselineResult(
        x=x, messages=messages, iterations=it, converged=converged,
        node_sends=node_sends,
    )


def geographic_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_iters: int = 5_000_000,
    check_every: int = 64,
) -> BaselineResult:
    """Geographic gossip [11]: pairwise averaging with the node closest
    to a random target location, 2*hops messages per iteration."""
    rng = np.random.default_rng(seed)
    n = g.n
    x = np.asarray(x0, np.float64).copy()
    mean = float(np.mean(x0))
    tol = eps * float(np.linalg.norm(x0))
    node_sends = np.zeros(n, np.int64)
    messages = 0
    it = 0
    converged = False
    while it < max_iters:
        for _ in range(check_every):
            it += 1
            src = int(rng.integers(n))
            target = rng.uniform(0.0, 1.0, 2)
            path = _greedy_path(g, src, target)
            L = len(path) - 1
            dst = path[-1]
            if dst == src:
                continue
            messages += 2 * L
            node_sends[path[:-1]] += 1
            node_sends[path[1:]] += 1
            avg = 0.5 * (x[src] + x[dst])
            x[src] = avg
            x[dst] = avg
        if np.linalg.norm(x - mean) <= tol:
            converged = True
            break
    return BaselineResult(
        x=x, messages=messages, iterations=it, converged=converged,
        node_sends=node_sends,
    )


def standard_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seed: int = 0,
    max_ticks: int = 50_000_000,
) -> BaselineResult:
    """Single-hop randomized gossip [2] via the batched engine (B=1)."""
    res = gossip_until(
        np.asarray(x0, np.float32)[None, :],
        g.neighbors[None],
        g.degrees[None],
        np.array([g.n], np.int32),
        eps=eps,
        seed=seed,
        max_ticks=max_ticks,
    )
    usage = res.edge_usage[0]
    node_sends = usage.sum(axis=1).astype(np.int64)
    valid = g.neighbors >= 0
    np.add.at(node_sends, g.neighbors[valid], usage[valid])
    return BaselineResult(
        x=res.estimates()[0, : g.n],
        messages=res.total_messages,
        iterations=int(res.ticks[0]),
        converged=bool(res.converged[0]),
        node_sends=node_sends,
    )
