"""The paper's primary contribution: multiscale gossip for decentralized
averaging (Tsianos & Rabbat, 2010), plus the baselines it is evaluated
against and the failure models of §VI-C.

The production mapping of this algorithm onto TPU meshes (gradient
synchronization) lives in `repro.dist`; the MXU-friendly batched cell
mixing kernel lives in `repro.kernels.cell_mixing`.
"""
from .baselines import (
    BaselineResult,
    geographic_gossip,
    path_averaging,
    standard_gossip,
)
from .failures import handshake_cost
from .gossip import GossipResult, batched_graphs, gossip_until
from .metrics import relative_error, theorem2_bound
from .multiscale import LevelReport, MultiscaleResult, multiscale_gossip
from .partition import Partition, auto_levels, build_partition
from .rgg import Graph, connectivity_radius, grid_graph, random_geometric_graph
from .routing import Route, greedy_route, route_table, route_to_node
from .synchronous import SyncMultiscaleResult, synchronous_multiscale

__all__ = [
    "BaselineResult",
    "Graph",
    "GossipResult",
    "LevelReport",
    "MultiscaleResult",
    "Partition",
    "Route",
    "auto_levels",
    "batched_graphs",
    "build_partition",
    "connectivity_radius",
    "geographic_gossip",
    "gossip_until",
    "greedy_route",
    "grid_graph",
    "handshake_cost",
    "multiscale_gossip",
    "path_averaging",
    "random_geometric_graph",
    "relative_error",
    "route_table",
    "route_to_node",
    "standard_gossip",
    "SyncMultiscaleResult",
    "synchronous_multiscale",
    "theorem2_bound",
]
