"""The paper's primary contribution: multiscale gossip for decentralized
averaging (Tsianos & Rabbat, 2010), plus the baselines it is evaluated
against and the failure models of §VI-C.

The production mapping of this algorithm onto TPU meshes (gradient
synchronization) lives in `repro.dist`; the MXU-friendly batched cell
mixing kernel lives in `repro.kernels.cell_mixing`.
"""
from .baselines import (
    BaselineResult,
    geographic_gossip,
    path_averaging,
    standard_gossip,
)
from .engine import EngineResult, execute_plan
from .failures import handshake_cost
from .gossip import (
    GOSSIP_BACKENDS,
    GossipResult,
    batched_graphs,
    gossip_core,
    gossip_until,
)
from .medium import (
    CostModel,
    FailureModel,
    MediumCost,
    expected_retransmissions,
    level_edge_messages,
    price_edge_messages,
    price_messages,
    route_edge_transmissions,
)
from .metrics import relative_error, theorem2_bound
from .multiscale import (
    LevelReport,
    MultiscaleResult,
    MultiscaleTrials,
    multiscale_gossip,
)
from .options import ExecOptions
from .partition import Partition, auto_levels, build_partition
from .plan import HierarchyPlan, LevelPlan, build_plan
from .plan_cache import (
    PLAN_CACHE_VERSION,
    load_plan,
    plan_key,
    setup_plan,
    store_plan,
)
from .rgg import (
    RGG_METHODS,
    Graph,
    connectivity_radius,
    grid_graph,
    random_geometric_graph,
)
from .schedule import (
    CsrGraphs,
    ExchangeSchedule,
    compose_schedule,
    dense_to_csr,
    flat_usage_to_dense,
    sample_schedule,
    sample_tick,
)
from .routing import (
    BatchedRoutes,
    Route,
    accumulate_route_sends,
    batched_greedy_routes,
    batched_routes_to_nodes,
    greedy_route,
    route_table,
    route_to_node,
)
from .scenarios import (
    Scenario,
    ScenarioResult,
    run_scenario_matrix,
    scenario_matrix,
)
from .synchronous import SyncMultiscaleResult, synchronous_multiscale

__all__ = [
    "BaselineResult",
    "BatchedRoutes",
    "CostModel",
    "CsrGraphs",
    "EngineResult",
    "ExecOptions",
    "FailureModel",
    "Graph",
    "GossipResult",
    "HierarchyPlan",
    "LevelPlan",
    "LevelReport",
    "MediumCost",
    "MultiscaleResult",
    "MultiscaleTrials",
    "Partition",
    "Route",
    "Scenario",
    "ScenarioResult",
    "accumulate_route_sends",
    "auto_levels",
    "batched_graphs",
    "batched_greedy_routes",
    "batched_routes_to_nodes",
    "build_partition",
    "build_plan",
    "connectivity_radius",
    "dense_to_csr",
    "execute_plan",
    "expected_retransmissions",
    "flat_usage_to_dense",
    "geographic_gossip",
    "gossip_core",
    "gossip_until",
    "greedy_route",
    "grid_graph",
    "handshake_cost",
    "load_plan",
    "multiscale_gossip",
    "path_averaging",
    "plan_key",
    "PLAN_CACHE_VERSION",
    "level_edge_messages",
    "price_edge_messages",
    "price_messages",
    "route_edge_transmissions",
    "random_geometric_graph",
    "relative_error",
    "RGG_METHODS",
    "route_table",
    "route_to_node",
    "run_scenario_matrix",
    "scenario_matrix",
    "setup_plan",
    "store_plan",
    "standard_gossip",
    "SyncMultiscaleResult",
    "synchronous_multiscale",
    "theorem2_bound",
]
