"""Device-resident executor for a `HierarchyPlan` (the execute half of
the plan/execute simulation core).

One call runs all K levels of multiscale gossip end-to-end in a single
compiled JAX function: per-level batched gossip (`gossip_core`),
representative election (static, from the plan), Alg.-1 line-16
reweighting and value promotion as gathers/scatters, send attribution as
gathers through the plan's route-incidence CSR plus one scatter-add, and
the dissemination down-pass as a gather — no host round-trips between
levels.  The executor is `vmap`-ped over trial seeds, so
`execute_plan(plan, x0, seeds=[s0..sT])` simulates T independent
Monte-Carlo trials in one compiled call — and `mesh=` additionally
`shard_map`s that trial axis over a 1-axis device mesh, so paper-scale
trial counts (10-25) fan out over real hardware (trials are padded up
to a device multiple and the padding discarded).

Backends: ``backend="lax"`` is the reference inner kernel;
``backend="pallas"`` walks each chunk's presampled schedule with the
`kernels.pair_apply` VMEM-resident TPU kernel (bitwise-identical to
lax; non-TPU hosts dispatch to the jnp oracle); ``backend="matmul"``
composes each chunk's mixing matrix with a log2 tree of batched MXU
matmuls (values agree up to f32 rounding).  ``schedule="per_tick"``
keeps the legacy sequential scan as the parity reference (see
`core.gossip`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .gossip import GOSSIP_BACKENDS, gossip_core
from .plan import HierarchyPlan

__all__ = ["EngineResult", "execute_plan", "fi_ticks"]

# Lighter XLA pipeline for the executor: these are small scatter/gather
# loops where full optimization buys nothing measurable at runtime but
# more than doubles compile time (the single-shot benchmark bottleneck
# on CPU).  The LLVM expensive-pass cut matters most: the executor's
# scatter bodies spend their compile budget in LLVM, not in HLO passes.
_COMPILER_OPTS = {
    "xla_backend_optimization_level": 0,
    "xla_llvm_disable_expensive_passes": True,
}


def fi_ticks(size: int, eps: float, scale: float, quadratic: bool) -> int:
    """Fixed-iterations budget (paper §VII): the theoretical
    epsilon-averaging-time bound for the worst-case graph size at the
    level — Theta(p^2 log 1/eps) ticks for p-node grids, Theta(p log
    1/eps) for the (near-complete) finest cells (Boyd et al. [2])."""
    ln = math.log(1.0 / eps)
    if quadratic:
        budget = 0.5 * size * size * ln
    else:
        budget = 4.0 * size * ln
    return max(32, math.ceil(scale * budget))


def trials_error(x_final: np.ndarray, x0: np.ndarray) -> np.ndarray:
    """(T,) relative error per trial (paper eq. 1); x0 may be (n,)
    shared or (T, n) per-trial."""
    x0 = np.asarray(x0)
    avg = x0.mean(axis=-1, keepdims=True)
    num = np.linalg.norm(x_final - avg, axis=-1)
    den = np.linalg.norm(np.broadcast_to(x0, x_final.shape), axis=-1)
    return num / den


@dataclasses.dataclass
class EngineResult:
    """Per-trial outputs of one vmapped plan execution (T trials)."""

    x_final: np.ndarray          # (T, n) estimates at every node
    messages: np.ndarray         # (T,) total single-hop transmissions
    node_sends: np.ndarray       # (T, n) transmissions attributed per node
    level_messages: np.ndarray   # (T, L) per executed level
    level_ticks: np.ndarray      # (T, L) max ticks over the level's graphs
    level_converged: np.ndarray  # (T, L) fraction of graphs converged
    edge_usage: list             # L arrays (T, B, C, D) exchange counts
    #                              (only when run with collect_usage=True)
    backend: str

    @property
    def trials(self) -> int:
        return int(self.x_final.shape[0])

    def error(self, x0: np.ndarray) -> np.ndarray:
        """(T,) relative error per trial; see `trials_error`."""
        return trials_error(self.x_final, x0)


def _level_consts(lp):
    c = {
        "neighbors": jnp.asarray(lp.neighbors, jnp.int32),
        "degrees": jnp.asarray(lp.degrees, jnp.int32),
        "n_nodes": jnp.asarray(lp.n_nodes, jnp.int32),
        "node_mask": jnp.asarray(lp.node_mask, bool),
        "edge_hops": jnp.asarray(lp.edge_hops, jnp.int32),
        "slot_node": jnp.asarray(lp.slot_node, jnp.int32),
    }
    if lp.kind == "cells":
        c["partner_node"] = jnp.asarray(lp.partner_node, jnp.int32)
    else:
        for name in ("edge_b", "edge_i", "edge_si", "edge_j", "edge_sj",
                     "inc_node", "inc_edge", "inc_count"):
            c[name] = jnp.asarray(getattr(lp, name), jnp.int32)
    if lp.rep_slot is not None:
        c["rep_slot"] = jnp.asarray(lp.rep_slot, jnp.int32)
        c["line16"] = jnp.asarray(lp.line16, jnp.float32)
        c["next_graph"] = jnp.asarray(lp.next_graph, jnp.int32)
        c["next_slot"] = jnp.asarray(lp.next_slot, jnp.int32)
    return c


def execute_plan(
    plan: HierarchyPlan,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seeds: Sequence[int] = (0,),
    weighted: bool = False,
    fixed_ticks_scale: float = 0.0,
    loss_p: Optional[float] = None,
    max_ticks_per_level: int = 2_000_000,
    check_every: int = 64,
    backend: str = "lax",
    schedule: str = "presampled",
    mesh=None,
    interpret: Optional[bool] = None,
    collect_usage: bool = False,
) -> EngineResult:
    """Execute `plan` for T = len(seeds) independent trials in one
    compiled, vmapped call.

    x0 may be (n,) — shared across trials — or (T, n) per-trial.  Each
    seed drives one trial's exchange randomness; the plan (partition,
    election, routes) is shared, so trials differ only in gossip noise.
    `mesh=` (a 1-axis `jax.sharding.Mesh`) shards the vmapped trial
    axis over devices via shard_map: T is padded up to a multiple of
    the mesh size with throwaway trials, each device runs its local
    slice of the vmap, and per-trial results are bitwise-independent of
    the sharding.  `collect_usage=True` additionally returns the raw
    per-level exchange counts (for attribution audits); leave it off on
    the hot path.
    """
    if backend not in GOSSIP_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = plan.graph.n
    x0 = np.asarray(x0, np.float32)
    T = len(seeds)
    per_trial_x0 = x0.ndim == 2
    if per_trial_x0 and x0.shape[0] != T:
        raise ValueError(f"x0 leading dim {x0.shape[0]} != trials {T}")
    if mesh is not None and len(mesh.shape) != 1:
        raise ValueError(
            f"execute_plan wants a 1-axis trial mesh, got {dict(mesh.shape)}"
        )
    pad = 0 if mesh is None else (-T) % mesh.devices.size
    V = 2 if weighted else 1
    L = len(plan.levels)
    K = plan.k

    # per-level loop config: eps / max_ticks are RUNTIME values (so the
    # eps-oracle and fixed-iterations modes share one compiled executor);
    # only the check cadence is static (scan length).
    eps_levels, maxt_levels, chk_levels = [], [], []
    for lp in plan.levels:
        if fixed_ticks_scale > 0:
            fixed = fi_ticks(
                int(lp.n_nodes.max()), eps, fixed_ticks_scale,
                quadratic=(lp.kind == "overlay"),
            )
            chk = max(1, min(check_every, fixed))
            eps_levels.append(-1.0)  # negative tol: the oracle never fires
            maxt_levels.append(((fixed + chk - 1) // chk) * chk)
            chk_levels.append(chk)
        else:
            eps_levels.append(float(eps))
            maxt_levels.append(int(max_ticks_per_level))
            chk_levels.append(int(check_every))
    # filled only when the executor must be (re)traced: a cache hit never
    # touches the plan's big constant arrays again
    consts: list = []

    def _run(x0_row, key, eps_arr, maxt_arr):
        node_sends = jnp.zeros(n + 1, jnp.int32)  # slot n swallows padding
        lvl_msgs, lvl_ticks, lvl_conv, usages = [], [], [], []
        xb = None
        for li, (lp, c, chk) in enumerate(zip(plan.levels, consts, chk_levels)):
            B = lp.num_graphs
            if lp.kind == "cells":
                vals = jnp.where(
                    c["node_mask"], x0_row[jnp.clip(c["slot_node"], 0)], 0.0
                )
                if weighted:
                    w = c["node_mask"].astype(jnp.float32)
                    xb = jnp.stack([vals * w, w], axis=-1)
                else:
                    xb = vals[..., None]
            x, usage, msgs, done, ticks = gossip_core(
                xb, c["neighbors"], c["degrees"], c["n_nodes"],
                c["edge_hops"], c["node_mask"],
                eps_arr[li], jax.random.fold_in(key, li),
                max_ticks=maxt_arr[li], check_every=chk, loss_p=loss_p,
                backend=backend, schedule=schedule, interpret=interpret,
            )
            # per-graph counters stay int32 on device; they are summed on
            # the host in int64 (jnp.sum would wrap without x64 mode)
            lvl_msgs.append(msgs)
            lvl_ticks.append(ticks.max())
            lvl_conv.append(done.mean())
            if collect_usage:
                usages.append(usage)
            # attribution: one scatter-add per level
            if lp.kind == "cells":
                idx = jnp.where(c["slot_node"] >= 0, c["slot_node"], n)
                node_sends = node_sends.at[idx.ravel()].add(
                    usage.sum(-1).ravel()
                )
                pidx = jnp.where(c["partner_node"] >= 0, c["partner_node"], n)
                node_sends = node_sends.at[pidx.ravel()].add(usage.ravel())
            else:
                usage_e = (
                    usage[c["edge_b"], c["edge_i"], c["edge_si"]]
                    + usage[c["edge_b"], c["edge_j"], c["edge_sj"]]
                )
                node_sends = node_sends.at[c["inc_node"]].add(
                    usage_e[c["inc_edge"]] * c["inc_count"]
                )
            # promotion (gathers; Alg.1 line 16 on the finest level)
            if lp.rep_slot is not None:
                v = x[jnp.arange(B), c["rep_slot"]]          # (B, V)
                if weighted:
                    v = v * c["n_nodes"][:, None].astype(jnp.float32)
                else:
                    v = v * c["line16"][:, None]
                B2, C2 = plan.levels[li + 1].node_mask.shape
                xb = jnp.zeros((B2, C2, V), jnp.float32).at[
                    c["next_graph"], c["next_slot"]
                ].set(v)
        # final estimate + dissemination down-pass
        est = x[..., 0] if V == 1 else x[..., 0] / jnp.maximum(x[..., 1], 1e-30)
        x_final = est[plan.final_graph, plan.final_slot]
        node_sends = node_sends[:n]
        if plan.disseminate:
            node_sends = node_sends + 1  # the n-message down-pass
        return (
            x_final, node_sends,
            tuple(lvl_msgs), jnp.stack(lvl_ticks), jnp.stack(lvl_conv),
            tuple(usages),
        )

    # throwaway padding trials bring T up to a mesh-device multiple
    pad_seeds = tuple(seeds) + tuple(seeds[:1]) * pad
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in pad_seeds])
    if per_trial_x0 and pad:
        x0 = np.concatenate([x0, np.repeat(x0[:1], pad, axis=0)], axis=0)
    args = (
        jnp.asarray(x0),
        keys,
        jnp.asarray(eps_levels, jnp.float32),
        jnp.asarray(maxt_levels, jnp.int32),
    )
    cache_key = (
        T, per_trial_x0, weighted, loss_p, backend, schedule, mesh, interpret,
        tuple(chk_levels), collect_usage,
    )
    fn = plan.exec_cache.get(cache_key)
    if fn is None:
        consts.extend(_level_consts(lp) for lp in plan.levels)
        if T == 1 and mesh is None:
            # single-trial fast path: the batching interpreter roughly
            # doubles trace time and XLA pays for size-1 batch dims on
            # every op — run the trial unbatched and re-add the trial
            # axis on the way out (per-trial results are independent of
            # the batching, see test_trials_vmap_matches_sequential)
            def run_v(x0_, keys_, eps_, maxt_):
                out = _run(x0_[0] if per_trial_x0 else x0_, keys_[0],
                           eps_, maxt_)
                return jax.tree_util.tree_map(lambda a: a[None], out)
        else:
            run_v = jax.vmap(_run, in_axes=(0 if per_trial_x0 else None, 0, None, None))
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            (axis,) = mesh.axis_names
            run_v = shard_map(
                run_v, mesh=mesh,
                in_specs=(P(axis) if per_trial_x0 else P(), P(axis), P(), P()),
                out_specs=P(axis), check_rep=False,
            )
        jitted = jax.jit(run_v)
        try:
            fn = jitted.lower(*args).compile(compiler_options=_COMPILER_OPTS)
        except Exception:  # options unsupported on this backend
            fn = jitted
        plan.exec_cache[cache_key] = fn
    xf, sends, lm, lt, lc, usages = fn(*args)
    if pad:
        xf, sends, lt, lc = xf[:T], sends[:T], lt[:T], lc[:T]
        lm = tuple(m[:T] for m in lm)
        usages = tuple(u[:T] for u in usages)
    # host-side int64 reduction of the per-graph int32 counters
    level_messages = np.stack(
        [np.asarray(m, np.int64).sum(axis=1) for m in lm], axis=1
    )
    messages = level_messages.sum(axis=1)
    if plan.disseminate:
        messages = messages + n
    return EngineResult(
        x_final=np.asarray(xf),
        messages=messages,
        node_sends=np.asarray(sends, np.int64),
        level_messages=level_messages,
        level_ticks=np.asarray(lt, np.int64),
        level_converged=np.asarray(lc, np.float64),
        edge_usage=[np.asarray(u) for u in usages],
        backend=backend,
    )
