"""Device-resident executor for a `HierarchyPlan` (the execute half of
the plan/execute simulation core).

One call runs all K levels of multiscale gossip end-to-end in a single
compiled JAX function: per-level batched gossip (`gossip_core`),
representative election (static, from the plan), Alg.-1 line-16
reweighting and value promotion as gathers/scatters, send attribution as
gathers through the plan's route-incidence CSR plus one scatter-add, and
the dissemination down-pass as a gather — no host round-trips between
levels.  Adjacency and usage counters are CSR end-to-end (flat
per-directed-edge arrays from `LevelPlan`), so device memory scales with
edge count, not with ``B*C*max_deg`` padding.

The executor is `vmap`-ped over trial seeds, so `execute_plan(plan, x0,
seeds=[s0..sT])` simulates T independent Monte-Carlo trials in one
compiled call.  `mesh=` shards that computation over real hardware:

* a 1-axis mesh shard_maps the trial axis (trials are padded up to a
  device multiple and the padding discarded);
* a 2-axis mesh with axes named exactly ``("trials", "nodes")``
  additionally shards every level's graph batch over node blocks.  Each
  shard samples the full global exchange schedule (threefry streams
  have no prefix property) and slices its own columns, so per-trial
  results are bitwise-identical to the unsharded run; cross-shard
  traffic is a psum at each overlay promotion boundary (reps move
  between graphs exactly there) plus the final assembly — the gossip
  inner loops themselves run shard-local.

Backends: ``backend="lax"`` is the reference inner kernel;
``backend="pallas"`` walks each chunk's presampled schedule with the
`kernels.pair_apply` TPU kernel, streaming cell state through VMEM in
cell blocks (bitwise-identical to lax; non-TPU hosts dispatch to the
jnp oracle); ``backend="matmul"`` composes each chunk's mixing matrix
with a log2 tree of batched MXU matmuls (values agree up to f32
rounding).  ``schedule="per_tick"`` keeps the legacy sequential scan as
the parity reference (see `core.gossip`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .gossip import GOSSIP_BACKENDS, gossip_core
from .medium import (
    CostModel,
    FailureCtx,
    FailureModel,
    MediumCost,
    expected_retransmissions,
    failure_sets,
)
from .options import ExecOptions
from .plan import HierarchyPlan
from .schedule import CsrGraphs

__all__ = ["EngineResult", "execute_plan", "fi_ticks"]

# Lighter XLA pipeline for the executor: these are small scatter/gather
# loops where full optimization buys nothing measurable at runtime but
# more than doubles compile time (the single-shot benchmark bottleneck
# on CPU).  The LLVM expensive-pass cut matters most: the executor's
# scatter bodies spend their compile budget in LLVM, not in HLO passes.
_COMPILER_OPTS = {
    "xla_backend_optimization_level": 0,
    "xla_llvm_disable_expensive_passes": True,
}


def fi_ticks(size: int, eps: float, scale: float, quadratic: bool) -> int:
    """Fixed-iterations budget (paper §VII): the theoretical
    epsilon-averaging-time bound for the worst-case graph size at the
    level — Theta(p^2 log 1/eps) ticks for p-node grids, Theta(p log
    1/eps) for the (near-complete) finest cells (Boyd et al. [2])."""
    ln = math.log(1.0 / eps)
    if quadratic:
        budget = 0.5 * size * size * ln
    else:
        budget = 4.0 * size * ln
    return max(32, math.ceil(scale * budget))


def trials_error(x_final: np.ndarray, x0: np.ndarray) -> np.ndarray:
    """(T,) relative error per trial (paper eq. 1); x0 may be (n,)
    shared or (T, n) per-trial."""
    x0 = np.asarray(x0)
    avg = x0.mean(axis=-1, keepdims=True)
    num = np.linalg.norm(x_final - avg, axis=-1)
    den = np.linalg.norm(np.broadcast_to(x0, x_final.shape), axis=-1)
    return num / den


@dataclasses.dataclass
class EngineResult:
    """Per-trial outputs of one vmapped plan execution (T trials)."""

    x_final: np.ndarray          # (T, n) estimates at every node
    messages: np.ndarray         # (T,) total single-hop transmissions
    node_sends: np.ndarray       # (T, n) transmissions attributed per node
    level_messages: np.ndarray   # (T, L) per executed level
    level_ticks: np.ndarray      # (T, L) max ticks over the level's graphs
    level_converged: np.ndarray  # (T, L) fraction of graphs converged
    edge_usage: list             # L flat (T, nnz+1) exchange counters in the
    #                              level's CSR layout (collect_usage=True
    #                              only; LevelPlan.dense_usage restores the
    #                              historical (B, C, D) view)
    backend: str
    cost: Optional[MediumCost] = None  # priced medium cost (CostModel runs)

    @property
    def trials(self) -> int:
        return int(self.x_final.shape[0])

    def error(self, x0: np.ndarray) -> np.ndarray:
        """(T,) relative error per trial; see `trials_error`."""
        return trials_error(self.x_final, x0)


def _level_consts(lp):
    c = {
        "adj": CsrGraphs(
            start=jnp.asarray(lp.nbr_start, jnp.int32),
            nbr=jnp.asarray(lp.nbr_flat, jnp.int32),
            hops=jnp.asarray(lp.hop_flat, jnp.int32),
            degrees=jnp.asarray(lp.degrees, jnp.int32),
            n_nodes=jnp.asarray(lp.n_nodes, jnp.int32),
        ),
        "node_mask": jnp.asarray(lp.node_mask, bool),
        "slot_node": jnp.asarray(lp.slot_node, jnp.int32),
    }
    if lp.kind == "cells":
        # per-flat-entry owner/partner global ids (sentinel = trash slot n)
        c["row_node"] = jnp.asarray(lp.row_node, jnp.int32)
        c["partner_flat"] = jnp.asarray(lp.partner_flat, jnp.int32)
    else:
        for name in ("edge_pos_i", "edge_pos_j",
                     "inc_node", "inc_edge", "inc_count"):
            c[name] = jnp.asarray(getattr(lp, name), jnp.int32)
    if lp.rep_slot is not None:
        c["rep_slot"] = jnp.asarray(lp.rep_slot, jnp.int32)
        c["line16"] = jnp.asarray(lp.line16, jnp.float32)
        c["next_graph"] = jnp.asarray(lp.next_graph, jnp.int32)
        c["next_slot"] = jnp.asarray(lp.next_slot, jnp.int32)
    return c


def _failure_consts(plan, failures, maxt_levels, n):
    """Per-level `FailureCtx`s plus the dissemination freeze-out, from
    the host-drawn failure node sets mapped through each level's slot
    layout and static event windows.

    Event times are fractions of the FINEST level's tick budget (the
    finest level is where events fire); churned nodes stay down through
    every coarser level (churn_tick=0 there), and a regional outage
    persists into coarser levels only when its window extends past 1.0.

    Returns (ctxs, freeze): `freeze` is None or a dict with the (n,)
    mask of nodes that must NOT receive the dissemination down-pass —
    Byzantine nodes discard it, churned / permanently-out regional
    nodes never hear it — plus their (graph, slot) coordinates in the
    finest level, whose post-gossip value is exactly their frozen one.
    """
    sets = failure_sets(failures, n, coords=plan.graph.coords)
    maxt0 = int(maxt_levels[0])
    t0f, t1f = failures.regional_window
    reg_perm = t1f > 1.0
    ctxs = []
    for li, lp in enumerate(plan.levels):
        sn = np.asarray(lp.slot_node)
        valid = sn >= 0
        idx = np.clip(sn, 0, n - 1)
        if li == 0:
            churn_tick = int(round(failures.churn_time * maxt0))
            reg_t0 = int(round(t0f * maxt0))
            reg_t1 = maxt0 + 1 if reg_perm else int(round(t1f * maxt0))
        else:
            churn_tick = 0  # already-churned nodes stay down
            maxt = int(maxt_levels[li])
            reg_t0, reg_t1 = (0, maxt + 1) if reg_perm else (0, 0)
        ctxs.append(FailureCtx(
            churned=jnp.asarray(valid & sets["churned"][idx]),
            straggler=jnp.asarray(valid & sets["straggler"][idx]),
            byz=jnp.asarray(valid & sets["byz"][idx]),
            regional=jnp.asarray(valid & sets["regional"][idx]),
            churn_tick=churn_tick,
            reg_t0=reg_t0,
            reg_t1=reg_t1,
            straggler_success=(
                float(failures.straggler_success)
                if failures.straggler_fraction > 0 else 1.0),
        ))
    frozen = sets["byz"] | sets["churned"]
    if reg_perm:
        frozen = frozen | sets["regional"]
    freeze = None
    if plan.disseminate and frozen.any():
        sn0 = np.asarray(plan.levels[0].slot_node)
        b, c = np.nonzero(sn0 >= 0)
        ids = sn0[b, c].astype(np.int64)
        graph0 = np.zeros(n, np.int32)
        slot0 = np.zeros(n, np.int32)
        graph0[ids] = b.astype(np.int32)
        slot0[ids] = c.astype(np.int32)
        freeze = {
            "frozen": jnp.asarray(frozen),
            "graph0": jnp.asarray(graph0),
            "slot0": jnp.asarray(slot0),
        }
    return ctxs, freeze


def _price_levels(cost, plan, n, level_messages, messages, lretx, lcong):
    """Reduce the executor's per-graph cost counters into a `MediumCost`.

    `level_messages` is (T, L) int64; `lretx`/`lcong` are the L per-level
    (T, B) device counters (empty tuples when `cost` is None).  When the
    model is closed-form (``sample=False`` or ``retransmit_p == 1``) the
    sampled counters are ignored and the Geometric mean ``T*(1-p)/p`` is
    applied to the logical counts instead.  The dissemination down-pass
    (n extra logical transmissions, already in `messages`) is priced in
    expectation — there is no schedule to sample against.
    """
    if cost is None:
        return None
    p = cost.retransmit_p
    if cost.sample and p < 1.0:
        level_retx = np.stack(
            [np.asarray(r, np.int64)[:, : lp.num_graphs].sum(axis=1)
             for r, lp in zip(lretx, plan.levels)],
            axis=1,
        ).astype(np.float64)
    else:
        level_retx = expected_retransmissions(level_messages, p)
    level_cong = np.stack(
        [np.asarray(cg, np.float64)[:, : lp.num_graphs].sum(axis=1)
         for cg, lp in zip(lcong, plan.levels)],
        axis=1,
    )
    retx = level_retx.sum(axis=1)
    if plan.disseminate and p < 1.0:
        retx = retx + n * (1.0 - p) / p
    cong_e = cost.hop_energy * cost.congestion_alpha * level_cong
    congestion = cong_e.sum(axis=1)
    return MediumCost(
        transmissions=np.asarray(messages, np.float64),
        retransmissions=retx,
        congestion=congestion,
        energy=cost.hop_energy * (messages + retx) + congestion,
        level_energy=(
            cost.hop_energy * (level_messages + level_retx) + cong_e),
        model=cost,
    )


def execute_plan(
    plan: HierarchyPlan,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    seeds: Sequence[int] = (0,),
    weighted: bool = False,
    fixed_ticks_scale: float = 0.0,
    options: Optional[ExecOptions] = None,
    failures: Optional[FailureModel] = None,
    cost: Optional[CostModel] = None,
) -> EngineResult:
    """Execute `plan` for T = len(seeds) independent trials in one
    compiled, vmapped call.

    x0 may be (n,) — shared across trials — or (T, n) per-trial.  Each
    seed drives one trial's exchange randomness; the plan (partition,
    election, routes) is shared, so trials differ only in gossip noise.

    `options` (an `ExecOptions`) selects backend / schedule / mesh /
    check cadence / tick budget (the historical flat kwargs were
    removed after their deprecation window — a stale call now raises
    `TypeError`).  `failures` (a `FailureModel`) carries the paper's
    `loss_p` message-loss model plus the scenario fields (churn,
    stragglers, regional outage, Byzantine drops) that perturb the
    presampled schedule — scenario event times are fractions of the
    finest level's tick budget, so run scenarios in fixed-iterations
    mode.  `cost` (a `CostModel`) prices the schedule (energy,
    retransmissions, congestion) into `EngineResult.cost` WITHOUT
    perturbing the exchange trajectory: x / usage / messages are
    bitwise-identical with the cost model on or off.

    `options.mesh` shards the computation via shard_map: a 1-axis
    `jax.sharding.Mesh` shards the vmapped trial axis (T is padded up
    to a multiple of the mesh size with throwaway trials); a 2-axis
    mesh with axes named ``("trials", "nodes")`` also blocks every
    level's graph batch over the "nodes" axis, with psum halos only at
    promotion boundaries — per-trial results are bitwise-independent of
    the sharding either way.  The node-sharded path requires
    ``schedule="presampled"`` and supports neither `collect_usage`
    (the flat usage buffer is deliberately never assembled globally)
    nor `failures` scenarios / `cost` pricing (their reductions are
    batch-global).

    `options.collect_usage` additionally returns the raw per-level flat
    exchange counters (for attribution audits); leave it off on the hot
    path.
    """
    options = options if options is not None else ExecOptions()
    backend, schedule, mesh = options.backend, options.schedule, options.mesh
    interpret, collect_usage = options.interpret, options.collect_usage
    check_every = options.check_every
    max_ticks_per_level = options.max_ticks_per_level
    if failures is not None and failures.heterogeneous:
        raise ValueError(
            "per-edge loss_p is closed-form pricing only — the trajectory "
            "engine needs a scalar; price heterogeneous links with "
            "level_edge_messages + price_edge_messages")
    if cost is not None and cost.heterogeneous:
        raise ValueError(
            "per-edge hop_energy is closed-form pricing only — price "
            "heterogeneous links with level_edge_messages + "
            "price_edge_messages")
    loss_p = failures.loss_p if failures is not None else None
    scenario = failures is not None and failures.has_scenario
    if backend not in GOSSIP_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if (scenario or cost is not None) and schedule != "presampled":
        raise ValueError(
            "failure scenarios / cost pricing require schedule='presampled'")
    if scenario and fixed_ticks_scale <= 0:
        raise ValueError(
            "failure scenarios require fixed_ticks_scale > 0: scenario "
            "event times are fractions of the finest level's tick budget, "
            "which the eps-oracle mode leaves unbounded")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = plan.graph.n
    x0 = np.asarray(x0, np.float32)
    T = len(seeds)
    per_trial_x0 = x0.ndim == 2
    if per_trial_x0 and x0.shape[0] != T:
        raise ValueError(f"x0 leading dim {x0.shape[0]} != trials {T}")
    node_mesh = False
    if mesh is not None:
        if len(mesh.shape) == 2 and tuple(mesh.axis_names) == (
            "trials", "nodes",
        ):
            node_mesh = True
            if schedule != "presampled":
                raise ValueError(
                    "the (trials, nodes) mesh requires schedule='presampled'"
                )
            if collect_usage:
                raise ValueError(
                    "collect_usage is not supported on the (trials, nodes) "
                    "mesh (flat usage stays shard-local)"
                )
            if scenario or cost is not None:
                raise ValueError(
                    "failure scenarios / cost pricing are not supported on "
                    "the (trials, nodes) mesh (their reductions are "
                    "batch-global)"
                )
        elif len(mesh.shape) != 1:
            raise ValueError(
                "execute_plan wants a 1-axis trial mesh or a 2-axis mesh "
                f"with axes ('trials', 'nodes'), got {dict(mesh.shape)}"
            )
    if mesh is None:
        pad = 0
    elif node_mesh:
        pad = (-T) % mesh.shape["trials"]
    else:
        pad = (-T) % mesh.devices.size
    nd = mesh.shape["nodes"] if node_mesh else 1
    V = 2 if weighted else 1
    L = len(plan.levels)
    K = plan.k

    # per-level loop config: eps / max_ticks are RUNTIME values (so the
    # eps-oracle and fixed-iterations modes share one compiled executor);
    # only the check cadence is static (scan length).
    eps_levels, maxt_levels, chk_levels = [], [], []
    for lp in plan.levels:
        if fixed_ticks_scale > 0:
            fixed = fi_ticks(
                int(lp.n_nodes.max()), eps, fixed_ticks_scale,
                quadratic=(lp.kind == "overlay"),
            )
            chk = max(1, min(check_every, fixed))
            eps_levels.append(-1.0)  # negative tol: the oracle never fires
            maxt_levels.append(((fixed + chk - 1) // chk) * chk)
            chk_levels.append(chk)
        else:
            eps_levels.append(float(eps))
            maxt_levels.append(int(max_ticks_per_level))
            chk_levels.append(int(check_every))
    # filled only when the executor must be (re)traced: a cache hit never
    # touches the plan's big constant arrays again.  fail_ctxs holds the
    # per-level scenario flags (slot-mapped failure sets + static event
    # windows), freeze_c the dissemination freeze-out; both are filled
    # alongside consts.
    consts: list = []
    fail_ctxs: list = []
    freeze_c: list = []

    def _shard_cols(B):
        """This shard's contiguous block of the B graphs: clipped column
        ids plus the realness mask (clipped duplicates sample masked-out
        schedules, so they contribute nothing anywhere)."""
        Bs = -(-B // nd)
        sidx = jax.lax.axis_index("nodes") * Bs + jnp.arange(Bs)
        return jnp.minimum(sidx, B - 1), sidx < B, sidx

    def _run(x0_row, key, eps_arr, maxt_arr):
        node_sends = jnp.zeros(n + 1, jnp.int32)  # slot n swallows padding
        lvl_msgs, lvl_ticks, lvl_conv, usages = [], [], [], []
        lvl_retx, lvl_cong = [], []
        xb = None
        frozen_vals = None
        for li, (lp, c, chk) in enumerate(zip(plan.levels, consts, chk_levels)):
            B = lp.num_graphs
            if node_mesh:
                cols, ok, _ = _shard_cols(B)
                mask = c["node_mask"][cols] & ok[:, None]
                shard = (cols, ok)
            else:
                cols, ok, mask, shard = slice(None), None, c["node_mask"], None
            if lp.kind == "cells":
                vals = jnp.where(
                    mask, x0_row[jnp.clip(c["slot_node"][cols], 0)], 0.0
                )
                if weighted:
                    w = mask.astype(jnp.float32)
                    xb_loc = jnp.stack([vals * w, w], axis=-1)
                else:
                    xb_loc = vals[..., None]
            else:
                # promotion left xb global (the psum halo); take our block
                xb_loc = xb[cols] if node_mesh else xb
            out = gossip_core(
                xb_loc, c["adj"], mask,
                eps_arr[li], jax.random.fold_in(key, li),
                max_ticks=maxt_arr[li], check_every=chk, loss_p=loss_p,
                backend=backend, schedule=schedule, interpret=interpret,
                node_shard=shard,
                failure_ctx=fail_ctxs[li] if scenario else None,
                cost_model=cost, hop_cap=max(1, int(lp.max_hops)),
            )
            if cost is not None:
                x, usage, msgs, done, ticks, retx_l, cong_l = out
                lvl_retx.append(retx_l)
                lvl_cong.append(cong_l)
            else:
                x, usage, msgs, done, ticks = out
            # per-graph counters stay int32 on device; they are summed on
            # the host in int64 (jnp.sum would wrap without x64 mode)
            lvl_msgs.append(msgs)
            if node_mesh:
                lvl_ticks.append(jax.lax.pmax(ticks.max(), "nodes"))
                lvl_conv.append(
                    jax.lax.psum((done & ok).sum(), "nodes") / B
                )
            else:
                lvl_ticks.append(ticks.max())
                lvl_conv.append(done.mean())
            if collect_usage:
                usages.append(usage)
            # a frozen node's own post-gossip value at the finest level
            # is its value for the rest of the run: snapshot it before
            # promotion for the dissemination freeze-out
            if li == 0 and scenario and freeze_c and freeze_c[0] is not None:
                fz = freeze_c[0]
                e0 = (x[..., 0] if V == 1
                      else x[..., 0] / jnp.maximum(x[..., 1], 1e-30))
                frozen_vals = e0[fz["graph0"], fz["slot0"]]
            # attribution: gathers through the plan CSR + one scatter-add
            # per level.  Under node sharding `usage` is the shard's
            # partial flat counter (both directed entries of an overlay
            # edge live in one graph, hence one shard), so the partial
            # node_sends just psum at the end.
            if lp.kind == "cells":
                node_sends = node_sends.at[c["row_node"]].add(usage)
                node_sends = node_sends.at[c["partner_flat"]].add(usage)
            else:
                usage_e = usage[c["edge_pos_i"]] + usage[c["edge_pos_j"]]
                node_sends = node_sends.at[c["inc_node"]].add(
                    usage_e[c["inc_edge"]] * c["inc_count"]
                )
            # promotion (gathers; Alg.1 line 16 on the finest level)
            if lp.rep_slot is not None:
                Bl = x.shape[0]
                v = x[jnp.arange(Bl), c["rep_slot"][cols]]   # (Bl, V)
                if weighted:
                    v = v * c["adj"].n_nodes[cols, None].astype(jnp.float32)
                else:
                    v = v * c["line16"][cols, None]
                B2, C2 = plan.levels[li + 1].node_mask.shape
                if node_mesh:
                    # reps hop shards here: scatter into a trash-rowed
                    # global buffer and psum the halo over node blocks
                    tg = jnp.where(ok, c["next_graph"][cols], B2)
                    full = jnp.zeros((B2 + 1, C2, V), jnp.float32).at[
                        tg, c["next_slot"][cols]
                    ].set(jnp.where(ok[:, None], v, 0.0))
                    xb = jax.lax.psum(full, "nodes")[:B2]
                else:
                    xb = jnp.zeros((B2, C2, V), jnp.float32).at[
                        c["next_graph"], c["next_slot"]
                    ].set(v)
        # final estimate + dissemination down-pass
        est = x[..., 0] if V == 1 else x[..., 0] / jnp.maximum(x[..., 1], 1e-30)
        if node_mesh:
            BL, CL = plan.levels[-1].node_mask.shape
            cols, ok, sidx = _shard_cols(BL)
            tg = jnp.where(ok, sidx, BL)
            full = jnp.zeros((BL + 1, CL), jnp.float32).at[tg].set(
                jnp.where(ok[:, None], est, 0.0)
            )
            est = jax.lax.psum(full, "nodes")[:BL]
        x_final = est[plan.final_graph, plan.final_slot]
        # Byzantine nodes discard the down-pass; churned / permanently
        # regional-out nodes never hear it — they keep their frozen value
        if frozen_vals is not None:
            x_final = jnp.where(freeze_c[0]["frozen"], frozen_vals, x_final)
        node_sends = node_sends[:n]
        if node_mesh:
            node_sends = jax.lax.psum(node_sends, "nodes")
        if plan.disseminate:
            node_sends = node_sends + 1  # the n-message down-pass
        return (
            x_final, node_sends,
            tuple(lvl_msgs), jnp.stack(lvl_ticks), jnp.stack(lvl_conv),
            tuple(usages), tuple(lvl_retx), tuple(lvl_cong),
        )

    # throwaway padding trials bring T up to a mesh-device multiple
    pad_seeds = tuple(seeds) + tuple(seeds[:1]) * pad
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in pad_seeds])
    if per_trial_x0 and pad:
        x0 = np.concatenate([x0, np.repeat(x0[:1], pad, axis=0)], axis=0)
    args = (
        jnp.asarray(x0),
        keys,
        jnp.asarray(eps_levels, jnp.float32),
        jnp.asarray(maxt_levels, jnp.int32),
    )
    cache_key = (
        T, per_trial_x0, weighted, failures, cost, backend, schedule, mesh,
        interpret, tuple(chk_levels), collect_usage,
        # scenario event ticks are baked into the trace as constants
        # derived from maxt_levels (see _failure_consts), so executors
        # traced for different tick budgets must not collide
        tuple(maxt_levels) if scenario else None,
    )
    fn = plan.exec_cache.get(cache_key)
    if fn is None:
        consts.extend(_level_consts(lp) for lp in plan.levels)
        if scenario:
            ctxs, freeze = _failure_consts(plan, failures, maxt_levels, n)
            fail_ctxs.extend(ctxs)
            freeze_c.append(freeze)
        if T == 1 and mesh is None:
            # single-trial fast path: the batching interpreter roughly
            # doubles trace time and XLA pays for size-1 batch dims on
            # every op — run the trial unbatched and re-add the trial
            # axis on the way out (per-trial results are independent of
            # the batching, see test_trials_vmap_matches_sequential)
            def run_v(x0_, keys_, eps_, maxt_):
                out = _run(x0_[0] if per_trial_x0 else x0_, keys_[0],
                           eps_, maxt_)
                return jax.tree_util.tree_map(lambda a: a[None], out)
        else:
            run_v = jax.vmap(_run, in_axes=(0 if per_trial_x0 else None, 0, None, None))
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            if node_mesh:
                Pt = P("trials")
                run_v = shard_map(
                    run_v, mesh=mesh,
                    in_specs=(Pt if per_trial_x0 else P(), Pt, P(), P()),
                    out_specs=(
                        Pt, Pt,
                        tuple(P("trials", "nodes") for _ in plan.levels),
                        Pt, Pt, (), (), (),
                    ),
                    check_rep=False,
                )
            else:
                (axis,) = mesh.axis_names
                run_v = shard_map(
                    run_v, mesh=mesh,
                    in_specs=(P(axis) if per_trial_x0 else P(), P(axis), P(), P()),
                    out_specs=P(axis), check_rep=False,
                )
        jitted = jax.jit(run_v)
        try:
            fn = jitted.lower(*args).compile(compiler_options=_COMPILER_OPTS)
        except Exception:  # options unsupported on this backend
            fn = jitted
        plan.exec_cache[cache_key] = fn
    xf, sends, lm, lt, lc, usages, lretx, lcong = fn(*args)
    if pad:
        xf, sends, lt, lc = xf[:T], sends[:T], lt[:T], lc[:T]
        lm = tuple(m[:T] for m in lm)
        usages = tuple(u[:T] for u in usages)
        lretx = tuple(r[:T] for r in lretx)
        lcong = tuple(cg[:T] for cg in lcong)
    # host-side int64 reduction of the per-graph int32 counters (under
    # node sharding the per-level column count is nd*ceil(B/nd) with
    # zero-contribution duplicates — slice to the true B before summing)
    level_messages = np.stack(
        [np.asarray(m, np.int64)[:, : lp.num_graphs].sum(axis=1)
         for m, lp in zip(lm, plan.levels)],
        axis=1,
    )
    messages = level_messages.sum(axis=1)
    if plan.disseminate:
        messages = messages + n
    return EngineResult(
        x_final=np.asarray(xf),
        messages=messages,
        node_sends=np.asarray(sends, np.int64),
        level_messages=level_messages,
        level_ticks=np.asarray(lt, np.int64),
        level_converged=np.asarray(lc, np.float64),
        edge_usage=[np.asarray(u) for u in usages],
        backend=backend,
        cost=_price_levels(
            cost, plan, n, level_messages, messages, lretx, lcong),
    )
