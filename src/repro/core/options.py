"""Execution options for the plan/execute simulation core.

`ExecOptions` is the single static (hashable) config surface for HOW a
plan is executed — backend, schedule mode, sharding mesh, convergence
check cadence, tick budget — mirroring the dist layer's `SyncConfig` →
`SyncPlan` pattern.  WHAT is simulated stays in positional/semantic
arguments (`eps`, `seeds`, `weighted`, `fixed_ticks_scale`) and the two
sibling dataclasses `FailureModel` / `CostModel` (`core.medium`).

The historical flat kwargs (``backend=``, ``schedule=``, ``mesh=``,
``interpret=``, ``check_every=``, ``max_ticks_per_level=``,
``collect_usage=``, ``loss_p=``) have been REMOVED after their
one-release deprecation window: `execute_plan` / `multiscale_gossip`
now take `options=ExecOptions(...)` and `failures=FailureModel(...)`
only, and a stale flat-kwarg call fails loudly with `TypeError`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

__all__ = ["ExecOptions"]

_ENGINE_BACKENDS = ("lax", "pallas", "matmul")
_SCHEDULES = ("presampled", "per_tick")


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    """Static (hashable) description of how to execute a plan.

    backend: inner pairwise-average kernel — "lax" (reference scan),
        "pallas" (TPU pair_apply kernel), "matmul" (log2(T) MXU
        composition).
    schedule: "presampled" (schedule/value split, the default) or
        "per_tick" (legacy sequential scan, the parity reference).
    mesh: optional `jax.sharding.Mesh` — 1-axis shards the trial axis;
        a 2-axis ``("trials", "nodes")`` mesh also blocks node batches.
    interpret: run Pallas kernels in interpret mode; None = auto
        (interpret off only on real TPUs).
    check_every: convergence-oracle cadence (static scan length).
    max_ticks_per_level: per-level tick budget in eps-oracle mode.
    collect_usage: also return the raw per-level flat exchange
        counters (attribution audits; off on the hot path).
    """

    backend: str = "lax"
    schedule: str = "presampled"
    mesh: Optional[Any] = None
    interpret: Optional[bool] = None
    check_every: int = 64
    max_ticks_per_level: int = 2_000_000
    collect_usage: bool = False

    def __post_init__(self):
        if self.backend not in _ENGINE_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; "
                f"expected one of {_ENGINE_BACKENDS}")
        if self.schedule not in _SCHEDULES:
            raise ValueError(
                f"unknown schedule mode {self.schedule!r}; "
                f"expected one of {_SCHEDULES}")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")
