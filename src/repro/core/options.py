"""Execution options for the plan/execute simulation core.

`ExecOptions` is the single static (hashable) config surface for HOW a
plan is executed — backend, schedule mode, sharding mesh, convergence
check cadence, tick budget — mirroring the dist layer's `SyncConfig` →
`SyncPlan` pattern.  WHAT is simulated stays in positional/semantic
arguments (`eps`, `seeds`, `weighted`, `fixed_ticks_scale`) and the two
sibling dataclasses `FailureModel` / `CostModel` (`core.medium`).

The historical flat kwargs (``backend=``, ``schedule=``, ``mesh=``,
``interpret=``, ``check_every=``, ``max_ticks_per_level=``,
``collect_usage=``, ``loss_p=``) remain accepted by `execute_plan` /
`multiscale_gossip` for one deprecation window: they raise a
`DeprecationWarning` and are folded into `ExecOptions` /
`FailureModel`, producing bitwise-identical results to the new call
form (asserted by tests/test_medium_scenarios.py).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

from .medium import FailureModel

__all__ = ["ExecOptions", "UNSET", "resolve_exec_args"]

# distinguishes "kwarg not passed" from an explicit None (loss_p=None
# and interpret=None are meaningful values)
UNSET: Any = type("_Unset", (), {"__repr__": lambda s: "UNSET"})()

_ENGINE_BACKENDS = ("lax", "pallas", "matmul")
_SCHEDULES = ("presampled", "per_tick")


@dataclasses.dataclass(frozen=True)
class ExecOptions:
    """Static (hashable) description of how to execute a plan.

    backend: inner pairwise-average kernel — "lax" (reference scan),
        "pallas" (TPU pair_apply kernel), "matmul" (log2(T) MXU
        composition).
    schedule: "presampled" (schedule/value split, the default) or
        "per_tick" (legacy sequential scan, the parity reference).
    mesh: optional `jax.sharding.Mesh` — 1-axis shards the trial axis;
        a 2-axis ``("trials", "nodes")`` mesh also blocks node batches.
    interpret: run Pallas kernels in interpret mode; None = auto
        (interpret off only on real TPUs).
    check_every: convergence-oracle cadence (static scan length).
    max_ticks_per_level: per-level tick budget in eps-oracle mode.
    collect_usage: also return the raw per-level flat exchange
        counters (attribution audits; off on the hot path).
    """

    backend: str = "lax"
    schedule: str = "presampled"
    mesh: Optional[Any] = None
    interpret: Optional[bool] = None
    check_every: int = 64
    max_ticks_per_level: int = 2_000_000
    collect_usage: bool = False

    def __post_init__(self):
        if self.backend not in _ENGINE_BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; "
                f"expected one of {_ENGINE_BACKENDS}")
        if self.schedule not in _SCHEDULES:
            raise ValueError(
                f"unknown schedule mode {self.schedule!r}; "
                f"expected one of {_SCHEDULES}")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")


def resolve_exec_args(
    options: Optional[ExecOptions],
    failures: Optional[FailureModel],
    legacy: dict,
    *,
    stacklevel: int = 3,
) -> tuple[ExecOptions, Optional[FailureModel]]:
    """Fold deprecated flat kwargs into (ExecOptions, FailureModel).

    `legacy` maps kwarg name -> value, with UNSET marking "not passed".
    Passing a legacy kwarg warns; passing one alongside an explicit
    `options=` / `failures=` object is ambiguous and raises.
    """
    given = {k: v for k, v in legacy.items() if v is not UNSET}
    if given:
        warnings.warn(
            f"the flat kwargs {sorted(given)} are deprecated; pass "
            "options=ExecOptions(...) and failures=FailureModel(...) "
            "instead (repro.core.options / repro.core.medium)",
            DeprecationWarning, stacklevel=stacklevel,
        )
    loss_p = given.pop("loss_p", UNSET)
    if given:
        if options is not None:
            raise ValueError(
                f"both options=ExecOptions(...) and the deprecated kwargs "
                f"{sorted(given)} were passed; use one call form")
        options = ExecOptions(**given)
    elif options is None:
        options = ExecOptions()
    if loss_p is not UNSET:
        if failures is not None:
            raise ValueError(
                "both failures=FailureModel(...) and the deprecated "
                "loss_p= kwarg were passed; use one call form")
        if loss_p is not None:
            failures = FailureModel(loss_p=float(loss_p))
    return options, failures
