"""Random geometric graph (RGG) and grid topologies (paper §II).

The paper's connectivity model: n nodes uniform in the unit square, edge
iff Euclidean distance <= r(n) = sqrt(c * log(n) / n).  The paper's
experiments use c = 3 (r = sqrt(3 log n / n)), which also guarantees the
geo-density property used in §V (every r x r patch holds Theta(log n)
nodes w.h.p.).

Graphs are stored in CSR adjacency so construction and planning stay
O(nnz) in memory at large n:

  nbr_start : (n+1,) int64   -- row offsets into nbr_flat
  nbr_flat  : (nnz,) int32   -- one entry per directed edge
  degrees   : (n,)   int32
  coords    : (n, 2) float64

A dense padded ``(n, max_deg)`` view remains available as the
`neighbors` property (materialized lazily, cached) for small-n
consumers; large-n code paths use `neighbor_rows` to gather just the
rows they touch.

Two RGG builders produce bitwise-identical CSR (asserted by the parity
suite in tests/test_rgg_builders.py):

* ``method="bucket"`` (default): the geo-density construction — coords
  hash into an r-sized grid, neighbors come from the 9-cell stencil
  with vectorized numpy per bucket block, and the CSR is emitted
  directly, streamed in node-chunks so peak RSS is O(chunk + nnz)
  instead of the historical O(n * max_deg) padded intermediate.
* ``method="reference"``: the historical cKDTree ``query_pairs`` path,
  kept as the oracle (its pair *set* equals the bucket predicate; its
  output is reordered into the shared canonical layout).

Canonical neighbor order (both builders): row u lists partners grouped
by the 3x3 stencil offset of their cell relative to u's cell (row-major
offsets, so same-cell partners sit in the middle run), ascending node id
within each run.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property
from typing import Optional

import numpy as np

__all__ = [
    "Graph",
    "random_geometric_graph",
    "grid_graph",
    "connectivity_radius",
    "induced_subgraph",
    "RGG_METHODS",
]

RGG_METHODS = ("bucket", "reference")

# default node-chunk target of the streamed bucket builder: bounds the
# per-band candidate arrays (~9 * avg_cell_occupancy * chunk entries)
# and keeps the band working set cache-resident — measured sweet spot
# on the single-core CI host (16.9s at n=10^6 vs 116s at chunk=250k)
DEFAULT_CHUNK = 8_000


def connectivity_radius(n: int, c: float = 3.0) -> float:
    """r(n) = sqrt(c log n / n) (paper §II, experiments use c=3)."""
    return float(np.sqrt(c * np.log(n) / n))


@dataclasses.dataclass(frozen=True)
class Graph:
    """CSR-adjacency graph embedded in the unit square."""

    coords: np.ndarray      # (n, 2) float64, positions in [0,1]^2
    nbr_start: np.ndarray   # (n+1,) int64 row offsets into nbr_flat
    nbr_flat: np.ndarray    # (nnz,) int32 one entry per directed edge
    degrees: np.ndarray     # (n,) int32
    radius: float

    @property
    def n(self) -> int:
        return int(self.coords.shape[0])

    @property
    def nnz(self) -> int:
        return int(self.nbr_flat.shape[0])

    @cached_property
    def max_deg(self) -> int:
        return max(1, int(self.degrees.max(initial=0)))

    @property
    def num_edges(self) -> int:
        return int(self.degrees.sum()) // 2

    @cached_property
    def neighbors(self) -> np.ndarray:
        """Dense (n, max_deg) padded view, -1 pad — materialized lazily
        and cached (O(n * max_deg) memory).  Built by boolean-mask
        assignment: the mask enumerates in-degree slots in C order,
        which is exactly the CSR flat order, so one sequential pass
        fills the view — no per-element index matrices."""
        if self.n == 0:
            return np.full((0, 1), -1, np.int32)
        D = self.max_deg
        out = np.full((self.n, D), -1, np.int32)
        mask = np.arange(D)[None, :] < self.degrees[:, None]
        out[mask] = self.nbr_flat
        return out

    def neighbor_rows(self, ids: np.ndarray) -> np.ndarray:
        """Padded (len(ids), D) neighbor rows for just the given nodes,
        D = max degree among them — the large-n row gather used by the
        batched routers instead of the dense `neighbors` view."""
        ids = np.asarray(ids, np.int64)
        deg = self.degrees[ids].astype(np.int64)
        D = max(1, int(deg.max(initial=0)))
        col = np.arange(D)[None, :]
        valid = col < deg[:, None]
        if self.nnz == 0:
            return np.full((len(ids), D), -1, np.int32)
        idx = np.minimum(self.nbr_start[ids][:, None] + col, self.nnz - 1)
        return np.where(valid, self.nbr_flat[idx], np.int32(-1))

    def edge_list(self) -> np.ndarray:
        """(m, 2) int32 array of undirected edges (i < j)."""
        rows = np.repeat(
            np.arange(self.n, dtype=np.int64), self.degrees.astype(np.int64)
        )
        cols = self.nbr_flat.astype(np.int64)
        mask = rows < cols
        return np.stack([rows[mask], cols[mask]], axis=1).astype(np.int32)

    def is_connected(self) -> bool:
        return _num_components(self) == 1

    def subgraph_labels(self) -> np.ndarray:
        """Connected-component label per node (sparse csgraph pass)."""
        return _component_labels(self)

    # dense-era constructors kept for callers that assemble adjacency
    # by hand (tests, synthetic topologies)
    @classmethod
    def from_padded(
        cls, coords: np.ndarray, neighbors: np.ndarray,
        degrees: np.ndarray, radius: float,
    ) -> "Graph":
        """Build from the historical (n, max_deg) padded layout."""
        degrees = np.asarray(degrees, np.int32)
        deg = degrees.astype(np.int64)
        n, D = neighbors.shape
        keep = np.arange(D)[None, :] < deg[:, None]
        nbr_flat = np.asarray(neighbors)[keep].astype(np.int32)
        nbr_start = np.zeros(n + 1, np.int64)
        np.cumsum(deg, out=nbr_start[1:])
        return cls(
            coords=coords, nbr_start=nbr_start, nbr_flat=nbr_flat,
            degrees=degrees, radius=float(radius),
        )

    @classmethod
    def from_pairs(
        cls, coords: np.ndarray, pairs: np.ndarray, radius: float
    ) -> "Graph":
        """Build from an (m, 2) undirected pair list, preserving pair
        order within each row (the historical `_adjacency_from_pairs`
        layout, used by the grid topology and synthetic tests)."""
        n = len(coords)
        nbr_start, nbr_flat, degrees = _csr_from_pairs(n, pairs)
        return cls(
            coords=coords, nbr_start=nbr_start, nbr_flat=nbr_flat,
            degrees=degrees, radius=float(radius),
        )

    # cached dense views must not ride along into pickles (plan cache,
    # process pools) — they are derivable and can be huge
    def __getstate__(self):
        return {
            k: v for k, v in self.__dict__.items()
            if k not in ("neighbors", "max_deg")
        }

    def __setstate__(self, state):
        self.__dict__.update(state)


def _csr_from_pairs(
    n: int, pairs: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR adjacency from an (m, 2) undirected pair list; row order is
    the stable-by-source order of [pairs; flipped pairs] (the historical
    padded layout, flattened)."""
    pairs = np.asarray(pairs)
    if pairs.size == 0:
        return np.zeros(n + 1, np.int64), np.zeros(0, np.int32), \
            np.zeros(n, np.int32)
    src = np.concatenate([pairs[:, 0], pairs[:, 1]]).astype(np.int64)
    dst = np.concatenate([pairs[:, 1], pairs[:, 0]]).astype(np.int64)
    order = np.argsort(src, kind="stable")
    degrees = np.bincount(src, minlength=n).astype(np.int32)
    nbr_start = np.zeros(n + 1, np.int64)
    np.cumsum(degrees, out=nbr_start[1:])
    return nbr_start, dst[order].astype(np.int32), degrees


# --------------------------------------------------------------------------
# bucketed streamed builder (default) + cKDTree reference
# --------------------------------------------------------------------------


def _grid_side(r: float) -> int:
    """Bucket-grid side m with cell width 1/m >= r, so the full radius-r
    neighborhood of any point lies inside the 3x3 cell stencil."""
    if r <= 0:
        return 1
    return max(1, int(1.0 / r))


def _bucket_cells(coords: np.ndarray, m: int) -> tuple[np.ndarray, np.ndarray]:
    """(cx, cy) int64 bucket coordinates of each node."""
    cx = np.clip((coords[:, 0] * m).astype(np.int64), 0, m - 1)
    cy = np.clip((coords[:, 1] * m).astype(np.int64), 0, m - 1)
    return cx, cy

# the canonical per-row run order: 3x3 stencil offsets, row-major
_STENCIL = [(dy, dx) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]


def _excl_cumsum(a: np.ndarray) -> np.ndarray:
    out = np.zeros(len(a) + 1, np.int64)
    np.cumsum(a, out=out[1:])
    return out


def _bucket_csr(
    coords: np.ndarray, r: float, chunk: int = DEFAULT_CHUNK
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Cell-bucketed CSR construction: stream bands of bucket rows,
    compare each band's nodes against their 9-cell stencil with
    vectorized numpy, and assemble each band's CSR rows immediately
    while the band's working set is still cache-hot (no padded
    intermediate, no O(n)-sized temporaries per band).

    Peak extra memory is O(chunk-band candidates + nnz); the per-row
    entry order is the canonical (stencil offset, ascending node id)
    layout shared with `method="reference"`.  `chunk` only tiles the
    work — the output is bitwise-invariant to it (tested).
    """
    n = len(coords)
    m = _grid_side(r)
    cx, cy = _bucket_cells(coords, m)
    cell = cy * m + cx
    # nodes grouped by cell, ascending node id within a cell
    order = np.argsort(cell, kind="stable")
    counts = np.bincount(cell, minlength=m * m).astype(np.int64)
    cstart = _excl_cumsum(counts)
    # cell-sorted coordinate copies: candidate gathers hit a small
    # contiguous window instead of striding the (n, 2) layout
    xs = np.ascontiguousarray(coords[order, 0])
    ys = np.ascontiguousarray(coords[order, 1])
    r2 = r * r

    # bands of whole bucket rows sized to ~chunk nodes each
    row_counts = counts.reshape(m, m).sum(axis=1)
    bands: list[tuple[int, int]] = []
    y0 = 0
    acc = 0
    for y in range(m):
        acc += int(row_counts[y])
        if acc >= max(1, chunk) or y == m - 1:
            bands.append((y0, y + 1))
            y0, acc = y + 1, 0
    if y0 < m:
        bands.append((y0, m))

    # degree per *sorted position*; remapped to node ids at the end
    deg_sorted = np.zeros(n, np.int64)
    band_payload: list[tuple[int, int, np.ndarray]] = []  # (s0, bn, flat)
    for (yb0, yb1) in bands:
        s0 = int(cstart[yb0 * m])
        s1 = int(cstart[yb1 * m]) if yb1 < m else n
        bn = s1 - s0
        if bn == 0:
            continue
        bdeg = np.zeros(bn, np.int64)
        offs: list[tuple[np.ndarray, np.ndarray]] = []  # (su_local, v)
        for (dy, dx) in _STENCIL:
            ya0, ya1 = max(yb0, -dy), min(yb1, m - dy)
            xa0, xa1 = max(0, -dx), min(m, m - dx)
            if ya0 >= ya1 or xa0 >= xa1:
                offs.append((np.zeros(0, np.int32), np.zeros(0, np.int32)))
                continue
            rows = np.arange(ya0, ya1, dtype=np.int64)
            colsx = np.arange(xa0, xa1, dtype=np.int64)
            a_cells = (rows[:, None] * m + colsx[None, :]).ravel()
            b_cells = a_cells + dy * m + dx
            ac, bc = counts[a_cells], counts[b_cells]
            # candidate enumeration without any vector division: one
            # row per (cell, a-slot), each repeated by the partner
            # cell's occupancy
            R = int(ac.sum())
            if R == 0:
                offs.append((np.zeros(0, np.int32), np.zeros(0, np.int32)))
                continue
            acstart = _excl_cumsum(ac)
            rcell = np.repeat(np.arange(len(ac), dtype=np.int64), ac)
            row_ai = np.arange(R, dtype=np.int64) - acstart[rcell]
            su_row = cstart[a_cells][rcell] + row_ai  # strictly increasing
            lens = bc[rcell]
            total = int(lens.sum())
            if total == 0:
                offs.append((np.zeros(0, np.int32), np.zeros(0, np.int32)))
                continue
            lstart = _excl_cumsum(lens)
            rrep = np.repeat(np.arange(R, dtype=np.int64), lens)
            w = np.arange(total, dtype=np.int64) - lstart[rrep]
            su = np.repeat(su_row, lens)            # sorted-position of u
            sv = cstart[b_cells][rcell][rrep] + w   # sorted-position of v
            dxv = xs[su] - xs[sv]
            dyv = ys[su] - ys[sv]
            keep = dxv * dxv + dyv * dyv <= r2
            if dy == 0 and dx == 0:
                keep &= su != sv
            su_k = (su[keep] - s0).astype(np.int32)  # band-local row
            v_k = order[sv[keep]].astype(np.int32)   # ascending per run
            bdeg += np.bincount(su_k, minlength=bn)
            offs.append((su_k, v_k))
        # assemble this band's CSR rows while everything is cache-hot:
        # a row's full neighborhood lives in this band, offsets were
        # visited in canonical order, and each row is one contiguous
        # ascending run per offset, so runs land at
        # bstart[row] + cursor[row] + position-within-run
        bstart = _excl_cumsum(bdeg)
        band_flat = np.empty(int(bdeg.sum()), np.int32)
        cursor = np.zeros(bn, np.int64)
        for su_k, v_k in offs:
            if not len(su_k):
                continue
            head = np.ones(len(su_k), bool)
            head[1:] = su_k[1:] != su_k[:-1]
            run_id = np.cumsum(head) - 1
            run_start = np.nonzero(head)[0]
            within = np.arange(len(su_k), dtype=np.int64) - run_start[run_id]
            pos = bstart[su_k] + cursor[su_k] + within
            band_flat[pos] = v_k
            heads = su_k[head]
            run_len = np.diff(np.concatenate([run_start, [len(su_k)]]))
            cursor[heads] += run_len
        deg_sorted[s0:s1] = bdeg
        band_payload.append((s0, bn, band_flat))

    # permute rows from sorted-position order into node-id order,
    # band by band so transients stay band-sized
    degrees = np.empty(n, np.int64)
    degrees[order] = deg_sorted
    nnz = int(deg_sorted.sum())
    nbr_start = _excl_cumsum(degrees)
    nbr_flat = np.empty(nnz, np.int32)
    for s0, bn, band_flat in band_payload:
        deg_b = deg_sorted[s0:s0 + bn]
        bstart = _excl_cumsum(deg_b)
        node_ids = order[s0:s0 + bn]
        pos = (
            np.arange(len(band_flat), dtype=np.int64)
            - np.repeat(bstart[:-1], deg_b)
            + np.repeat(nbr_start[node_ids], deg_b)
        )
        nbr_flat[pos] = band_flat
    return nbr_start[:n + 1], nbr_flat, degrees.astype(np.int32)


def _reference_csr(
    coords: np.ndarray, r: float
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """cKDTree oracle: same pair set as the bucket predicate, reordered
    into the shared canonical (row, stencil offset, node id) layout."""
    from scipy.spatial import cKDTree

    n = len(coords)
    tree = cKDTree(coords)
    pairs = tree.query_pairs(r, output_type="ndarray").astype(np.int64)
    if len(pairs) == 0:
        return np.zeros(n + 1, np.int64), np.zeros(0, np.int32), \
            np.zeros(n, np.int32)
    src = np.concatenate([pairs[:, 0], pairs[:, 1]])
    dst = np.concatenate([pairs[:, 1], pairs[:, 0]])
    m = _grid_side(r)
    cx, cy = _bucket_cells(coords, m)
    # neighbors are within r <= cell width, so cells differ by at most 1
    o = (cy[dst] - cy[src] + 1) * 3 + (cx[dst] - cx[src] + 1)
    key = (src * 9 + o) * n + dst
    perm = np.argsort(key, kind="stable")
    degrees = np.bincount(src, minlength=n).astype(np.int32)
    nbr_start = np.zeros(n + 1, np.int64)
    np.cumsum(degrees, out=nbr_start[1:])
    return nbr_start, dst[perm].astype(np.int32), degrees


def random_geometric_graph(
    n: int,
    c: float = 3.0,
    seed: int = 0,
    coords: Optional[np.ndarray] = None,
    radius: Optional[float] = None,
    method: str = "bucket",
    chunk: int = DEFAULT_CHUNK,
) -> Graph:
    """Sample an RGG(n, r(n)) in the unit square (paper §II).

    `method="bucket"` (default) is the streamed cell-bucket builder;
    `method="reference"` is the historical cKDTree path kept as the
    bitwise oracle.  Same (seed, n, c) => identical Graph either way.
    """
    if method not in RGG_METHODS:
        raise ValueError(f"unknown rgg method {method!r}")
    rng = np.random.default_rng(seed)
    if coords is None:
        coords = rng.uniform(0.0, 1.0, size=(n, 2))
    r = connectivity_radius(n, c) if radius is None else float(radius)
    if method == "bucket":
        nbr_start, nbr_flat, degrees = _bucket_csr(coords, r, chunk=chunk)
    else:
        nbr_start, nbr_flat, degrees = _reference_csr(coords, r)
    return Graph(
        coords=coords, nbr_start=nbr_start, nbr_flat=nbr_flat,
        degrees=degrees, radius=r,
    )


def grid_graph(side: int, jitter: float = 0.0, seed: int = 0) -> Graph:
    """sqrt(n) x sqrt(n) lattice embedded in the unit square (paper §VIII)."""
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    coords = np.stack(
        [(ii.ravel() + 0.5) / side, (jj.ravel() + 0.5) / side], axis=1
    ).astype(np.float64)
    if jitter > 0:
        rng = np.random.default_rng(seed)
        coords = coords + rng.uniform(-jitter, jitter, coords.shape) / side
        coords = np.clip(coords, 0.0, 1.0)
    idx = np.arange(n).reshape(side, side)
    pairs = np.concatenate(
        [
            np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1),
            np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1),
        ]
    ).astype(np.int32)
    return Graph.from_pairs(coords, pairs, radius=1.5 / side)


def induced_subgraph(g: Graph, node_ids: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Subgraph induced by node_ids; returns (subgraph, node_ids) with local
    indices 0..len-1 mapping to the original ids (paper Alg. 1 line 14).

    Fully vectorized row packing: gather the flat neighborhoods of
    node_ids, remap to local ids, and compact kept entries — each row
    keeps its original neighbor order (the historical per-row loop's
    layout, asserted by the parity test)."""
    node_ids = np.asarray(node_ids, np.int32)
    ids64 = node_ids.astype(np.int64)
    remap = np.full(g.n, -1, np.int32)
    remap[ids64] = np.arange(len(node_ids), dtype=np.int32)
    deg = g.degrees[ids64].astype(np.int64)
    total = int(deg.sum())
    new_start = np.zeros(len(node_ids) + 1, np.int64)
    np.cumsum(deg, out=new_start[1:])
    pos = (
        np.arange(total, dtype=np.int64)
        - np.repeat(new_start[:-1], deg)
        + np.repeat(g.nbr_start[ids64], deg)
    )
    mapped = remap[g.nbr_flat[pos]]
    keep = mapped >= 0
    src_local = np.repeat(
        np.arange(len(node_ids), dtype=np.int64), deg
    )[keep]
    degrees = np.bincount(
        src_local, minlength=len(node_ids)
    ).astype(np.int32)
    nbr_start = np.zeros(len(node_ids) + 1, np.int64)
    np.cumsum(degrees, out=nbr_start[1:])
    return (
        Graph(
            coords=g.coords[ids64],
            nbr_start=nbr_start,
            nbr_flat=mapped[keep],
            degrees=degrees,
            radius=g.radius,
        ),
        node_ids,
    )


def _component_labels(g: Graph) -> np.ndarray:
    """Connected-component label per node via scipy.sparse.csgraph —
    the historical pure-python BFS was O(n) interpreter steps and took
    seconds at n=10^5."""
    if g.n == 0:
        return np.zeros(0, np.int32)
    import scipy.sparse as sp
    from scipy.sparse.csgraph import connected_components

    adj = sp.csr_matrix(
        (
            np.ones(g.nnz, np.int8),
            g.nbr_flat.astype(np.int64),
            g.nbr_start,
        ),
        shape=(g.n, g.n),
    )
    _, labels = connected_components(adj, directed=False)
    return labels.astype(np.int32)


def _num_components(g: Graph) -> int:
    return int(_component_labels(g).max()) + 1 if g.n else 0
