"""Random geometric graph (RGG) and grid topologies (paper §II).

The paper's connectivity model: n nodes uniform in the unit square, edge
iff Euclidean distance <= r(n) = sqrt(c * log(n) / n).  The paper's
experiments use c = 3 (r = sqrt(3 log n / n)), which also guarantees the
geo-density property used in §V (every r x r patch holds Theta(log n)
nodes w.h.p.).

Graphs are stored in a padded-neighbor format so the gossip inner loops
can run as fully-vectorized JAX code with static shapes:

  neighbors : (n, max_deg) int32   -- padded with -1
  degrees   : (n,)         int32
  coords    : (n, 2)       float64
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
from scipy.spatial import cKDTree

__all__ = ["Graph", "random_geometric_graph", "grid_graph", "connectivity_radius"]


def connectivity_radius(n: int, c: float = 3.0) -> float:
    """r(n) = sqrt(c log n / n) (paper §II, experiments use c=3)."""
    return float(np.sqrt(c * np.log(n) / n))


@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded-adjacency graph embedded in the unit square."""

    coords: np.ndarray      # (n, 2) float64, positions in [0,1]^2
    neighbors: np.ndarray   # (n, max_deg) int32, padded with -1
    degrees: np.ndarray     # (n,) int32
    radius: float

    @property
    def n(self) -> int:
        return int(self.coords.shape[0])

    @property
    def max_deg(self) -> int:
        return int(self.neighbors.shape[1])

    @property
    def num_edges(self) -> int:
        return int(self.degrees.sum()) // 2

    def edge_list(self) -> np.ndarray:
        """(m, 2) int32 array of undirected edges (i < j)."""
        rows = np.repeat(np.arange(self.n), self.degrees)
        cols = self.neighbors[self.neighbors >= 0]
        mask = rows < cols
        return np.stack([rows[mask], cols[mask]], axis=1).astype(np.int32)

    def is_connected(self) -> bool:
        return _num_components(self) == 1

    def subgraph_labels(self) -> np.ndarray:
        """Connected-component label per node (BFS over padded adjacency)."""
        return _component_labels(self)


def _adjacency_from_pairs(n: int, pairs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Build padded neighbor arrays from an (m, 2) undirected pair list."""
    if pairs.size == 0:
        return np.full((n, 1), -1, np.int32), np.zeros((n,), np.int32)
    src = np.concatenate([pairs[:, 0], pairs[:, 1]])
    dst = np.concatenate([pairs[:, 1], pairs[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    degrees = np.bincount(src, minlength=n).astype(np.int32)
    max_deg = max(1, int(degrees.max()))
    neighbors = np.full((n, max_deg), -1, np.int32)
    # offsets within each row
    starts = np.zeros(n + 1, np.int64)
    np.cumsum(degrees, out=starts[1:])
    col_idx = np.arange(len(src)) - starts[src]
    neighbors[src, col_idx] = dst
    return neighbors, degrees


def random_geometric_graph(
    n: int,
    c: float = 3.0,
    seed: int = 0,
    coords: Optional[np.ndarray] = None,
    radius: Optional[float] = None,
) -> Graph:
    """Sample an RGG(n, r(n)) in the unit square (paper §II)."""
    rng = np.random.default_rng(seed)
    if coords is None:
        coords = rng.uniform(0.0, 1.0, size=(n, 2))
    r = connectivity_radius(n, c) if radius is None else float(radius)
    tree = cKDTree(coords)
    pairs = tree.query_pairs(r, output_type="ndarray").astype(np.int32)
    neighbors, degrees = _adjacency_from_pairs(n, pairs)
    return Graph(coords=coords, neighbors=neighbors, degrees=degrees, radius=r)


def grid_graph(side: int, jitter: float = 0.0, seed: int = 0) -> Graph:
    """sqrt(n) x sqrt(n) lattice embedded in the unit square (paper §VIII)."""
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    coords = np.stack(
        [(ii.ravel() + 0.5) / side, (jj.ravel() + 0.5) / side], axis=1
    ).astype(np.float64)
    if jitter > 0:
        rng = np.random.default_rng(seed)
        coords = coords + rng.uniform(-jitter, jitter, coords.shape) / side
        coords = np.clip(coords, 0.0, 1.0)
    idx = np.arange(n).reshape(side, side)
    pairs = np.concatenate(
        [
            np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1),
            np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1),
        ]
    ).astype(np.int32)
    neighbors, degrees = _adjacency_from_pairs(n, pairs)
    return Graph(coords=coords, neighbors=neighbors, degrees=degrees, radius=1.5 / side)


def induced_subgraph(g: Graph, node_ids: np.ndarray) -> tuple[Graph, np.ndarray]:
    """Subgraph induced by node_ids; returns (subgraph, node_ids) with local
    indices 0..len-1 mapping to the original ids (paper Alg. 1 line 14)."""
    node_ids = np.asarray(node_ids, np.int32)
    remap = np.full(g.n, -1, np.int32)
    remap[node_ids] = np.arange(len(node_ids), dtype=np.int32)
    nbr = g.neighbors[node_ids]
    nbr_mapped = np.where(nbr >= 0, remap[np.clip(nbr, 0, None)], -1)
    # compact each row: keep only neighbors inside the cell
    keep = nbr_mapped >= 0
    degrees = keep.sum(axis=1).astype(np.int32)
    max_deg = max(1, int(degrees.max())) if len(node_ids) else 1
    neighbors = np.full((len(node_ids), max_deg), -1, np.int32)
    for row in range(len(node_ids)):  # rows are tiny (bounded degree)
        vals = nbr_mapped[row][keep[row]]
        neighbors[row, : len(vals)] = vals
    return (
        Graph(
            coords=g.coords[node_ids],
            neighbors=neighbors,
            degrees=degrees,
            radius=g.radius,
        ),
        node_ids,
    )


def _component_labels(g: Graph) -> np.ndarray:
    labels = np.full(g.n, -1, np.int32)
    current = 0
    for start in range(g.n):
        if labels[start] >= 0:
            continue
        stack = [start]
        labels[start] = current
        while stack:
            u = stack.pop()
            for v in g.neighbors[u, : g.degrees[u]]:
                if labels[v] < 0:
                    labels[v] = current
                    stack.append(int(v))
        current += 1
    return labels


def _num_components(g: Graph) -> int:
    return int(_component_labels(g).max()) + 1 if g.n else 0
