"""Multiscale gossip — the paper's Algorithm 1.

Bottom-up execution over the recursive partition:

  1. level k (finest): randomized gossip inside every cell's induced
     subgraph; elect a representative per cell; reweight its value by
     |cell| * (#present sibling cells) / |parent|  (Alg. 1 line 16).
  2. levels j = k-1 .. 1: representatives of level-(j+1) cells form a
     grid graph per level-j cell (edges between N/S/E/W-adjacent sibling
     cells); gossip runs on all grids of the level in parallel, every
     exchange costing 2 * hops single-hop transmissions via greedy
     geographic routing on the base graph; elect a level-j
     representative per grid.
  3. after the level-1 grid converges, every level-2 representative
     disseminates its value to its cell (n messages total).

`weighted=True` enables the beyond-paper exact-mass variant: values
travel as (w*x, w) pairs (still constant message size), making every
level's fusion exact under perfect per-level gossip regardless of cell
occupancy.  The paper's plain algorithm is `weighted=False`.

`fixed_ticks_scale` > 0 selects MultiscaleGossipFI (§VI): every graph at
a level runs a deterministic number of exchanges derived from the worst
case expected graph size, removing the convergence oracle (and the
synchronization it implies) at the cost of redundant messages.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from .gossip import GossipResult, batched_graphs, gossip_until
from .partition import Partition, build_partition
from .rgg import Graph, induced_subgraph
from .routing import Route, route_to_node

__all__ = ["MultiscaleResult", "LevelReport", "multiscale_gossip"]


@dataclasses.dataclass
class LevelReport:
    level: int
    num_graphs: int
    messages: int
    max_ticks: int
    converged_frac: float
    max_hops: int          # longest routed exchange at this level
    graph_sizes: tuple     # (min, mean, max) nodes per graph


@dataclasses.dataclass
class MultiscaleResult:
    x_final: np.ndarray       # (n,) estimate at every node
    messages: int             # total single-hop transmissions
    levels: list[LevelReport]
    node_sends: np.ndarray    # (n,) transmissions attributed per node
    rep_counts: np.ndarray    # (n,) #times each node served as representative
    disconnected_cells: int   # finest-level cells whose subgraph was disconnected
    partition: Partition

    def error(self, x0: np.ndarray) -> float:
        """Paper's final relative error ||x_final - avg|| / ||x0||."""
        avg = float(np.mean(x0))
        return float(np.linalg.norm(self.x_final - avg) / np.linalg.norm(x0))


def _elect(
    rng: np.random.Generator,
    mode: str,
    member_nodes: np.ndarray,
    coords: np.ndarray,
    center: np.ndarray,
) -> int:
    if mode == "random":
        return int(member_nodes[rng.integers(len(member_nodes))])
    d = np.sum((coords[member_nodes] - center) ** 2, axis=1)
    return int(member_nodes[int(np.argmin(d))])


def _grid_components(num: int, edges: np.ndarray) -> np.ndarray:
    """Union-find component labels for a small local graph."""
    parent = np.arange(num)

    def find(u):
        while parent[u] != u:
            parent[u] = parent[parent[u]]
            u = parent[u]
        return u

    for u, v in edges:
        ru, rv = find(int(u)), find(int(v))
        if ru != rv:
            parent[ru] = rv
    return np.array([find(u) for u in range(num)])


def _connect_components(
    local_edges: list, coords: np.ndarray, num: int
) -> list:
    """Add nearest-pair edges until the local rep graph is connected
    (handles empty sibling cells — paper §VII 'disconnected grids')."""
    if num <= 1:
        return local_edges
    while True:
        comp = _grid_components(num, np.asarray(local_edges, np.int64).reshape(-1, 2))
        labels = np.unique(comp)
        if len(labels) == 1:
            return local_edges
        # connect the first component to its nearest outside vertex
        a = np.where(comp == labels[0])[0]
        b = np.where(comp != labels[0])[0]
        d = np.sum((coords[a][:, None, :] - coords[b][None, :, :]) ** 2, axis=2)
        ia, ib = np.unravel_index(int(np.argmin(d)), d.shape)
        local_edges.append((int(a[ia]), int(b[ib])))


class _OverlayGraph:
    """Duck-typed graph (n / max_deg / neighbors / degrees) for batching."""

    def __init__(self, num: int, edges: np.ndarray, hops: np.ndarray):
        self.n = num
        nbrs: list[list[int]] = [[] for _ in range(num)]
        hp: list[list[int]] = [[] for _ in range(num)]
        for (u, v), h in zip(edges, hops):
            nbrs[u].append(int(v))
            hp[u].append(int(h))
            nbrs[v].append(int(u))
            hp[v].append(int(h))
        self.max_deg = max(1, max((len(r) for r in nbrs), default=1))
        self.neighbors = np.full((num, self.max_deg), -1, np.int32)
        self.edge_hops = np.ones((num, self.max_deg), np.int32)
        self.degrees = np.array([len(r) for r in nbrs], np.int32)
        for u in range(num):
            self.neighbors[u, : len(nbrs[u])] = nbrs[u]
            self.edge_hops[u, : len(hp[u])] = hp[u]


def _fi_ticks(size: int, eps: float, scale: float, quadratic: bool) -> int:
    """Fixed-iterations budget (paper §VII): the theoretical
    epsilon-averaging-time bound for the worst-case graph size at the
    level — Theta(p^2 log 1/eps) ticks for p-node grids, Theta(p log
    1/eps) for the (near-complete) finest cells (Boyd et al. [2])."""
    ln = math.log(1.0 / eps)
    if quadratic:
        budget = 0.5 * size * size * ln
    else:
        budget = 4.0 * size * ln
    return max(32, math.ceil(scale * budget))


def multiscale_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
    weighted: bool = False,
    fixed_ticks_scale: float = 0.0,
    loss_p: Optional[float] = None,
    max_ticks_per_level: int = 2_000_000,
) -> MultiscaleResult:
    rng = np.random.default_rng(seed)
    n = g.n
    part = build_partition(n, k=k, a=a, cell_max=cell_max)
    K = part.k
    node_sends = np.zeros(n, np.int64)
    rep_counts = np.zeros(n, np.int64)
    levels: list[LevelReport] = []
    messages = 0
    V = 2 if weighted else 1

    def pack(vals, ws):
        if weighted:
            return np.stack([vals * ws, ws], axis=-1)
        return vals[..., None]

    # ---------------- level k: gossip inside finest cells ----------------
    cell_of_node = part.cell_of(g.coords, K)
    present_cells = np.unique(cell_of_node)
    members = {int(c): np.where(cell_of_node == c)[0] for c in present_cells}
    subgraphs, sub_ids = [], []
    for c in present_cells:
        sg, ids = induced_subgraph(g, members[int(c)])
        subgraphs.append(sg)
        sub_ids.append(ids)
    disconnected = sum(0 if sg.is_connected() else 1 for sg in subgraphs)

    neighbors, degrees, n_nodes, mask = batched_graphs(subgraphs)
    B, C = mask.shape
    xb = np.zeros((B, C), np.float32)
    for b, ids in enumerate(sub_ids):
        xb[b, : len(ids)] = x0[ids]
    wb = mask.astype(np.float32)  # unit mass per node
    fixed = (
        _fi_ticks(int(n_nodes.max()), eps, fixed_ticks_scale, quadratic=False)
        if fixed_ticks_scale > 0
        else None
    )
    res = gossip_until(
        pack(xb, wb),
        neighbors,
        degrees,
        n_nodes,
        eps=eps,
        seed=int(rng.integers(2**31)),
        max_ticks=max_ticks_per_level,
        fixed_ticks=fixed,
        loss_p=loss_p,
    )
    messages += res.total_messages
    _attribute_base_sends(node_sends, res, sub_ids, neighbors)
    levels.append(_report(K, res, n_nodes, max_hops=1))

    # representatives of finest cells + Alg.1 line 16 reweighting
    centers = part.cell_center(K, present_cells)
    rep_node = np.zeros(len(present_cells), np.int64)
    rep_val = np.zeros((len(present_cells), V), np.float32)
    est = res.estimates()
    for idx, c in enumerate(present_cells):
        ids = sub_ids[idx]
        local = _elect(rng, rep_mode, np.arange(len(ids)), g.coords[ids], centers[idx])
        rep_node[idx] = ids[local]
        rep_counts[ids[local]] += 1
        if weighted:
            # promote the full cell mass: channels * cell size
            rep_val[idx] = res.x[idx, local] * len(ids)
        else:
            rep_val[idx, 0] = est[idx, local]
    if not weighted and K >= 2:
        # reweight by |cell| * m_present / |parent|  (line 16)
        parents = part.parent_cell(K, present_cells)
        cell_sizes = np.array([len(sub_ids[i]) for i in range(len(present_cells))])
        for p in np.unique(parents):
            sel = parents == p
            n_parent = int(cell_sizes[sel].sum())
            m_present = int(sel.sum())
            rep_val[sel, 0] *= cell_sizes[sel] * m_present / n_parent

    cur_cells = present_cells  # flat ids at level K
    cur_level = K

    # ---------------- levels k-1 .. 1: gossip on overlay grids ----------------
    while cur_level > 1:
        j = cur_level - 1  # parent level whose cells host the grids
        parents = part.parent_cell(cur_level, cur_cells)
        cell_pos = {int(c): i for i, c in enumerate(cur_cells)}
        all_edges = part.child_grid_edges(j)
        # group present child cells by parent
        order = np.argsort(parents, kind="stable")
        uniq_parents, starts = np.unique(parents[order], return_index=True)
        groups = np.split(order, starts[1:])

        overlay_graphs, group_members, route_maps, level_max_hops = [], [], [], 1
        for grp in groups:
            cells_here = cur_cells[grp]
            local = {int(c): i for i, c in enumerate(cells_here)}
            edges = [
                (local[int(u)], local[int(v)])
                for u, v in all_edges
                if int(u) in local and int(v) in local
            ]
            rep_xy = g.coords[rep_node[grp]]
            edges = _connect_components(edges, rep_xy, len(grp))
            routes: list[Route] = []
            hops = []
            for u, v in edges:
                r = route_to_node(g, int(rep_node[grp[u]]), int(rep_node[grp[v]]))
                routes.append(r)
                hops.append(max(1, r.hops))
            level_max_hops = max(level_max_hops, max(hops, default=1))
            overlay_graphs.append(
                _OverlayGraph(len(grp), np.asarray(edges, np.int64).reshape(-1, 2),
                              np.asarray(hops, np.int64))
            )
            group_members.append(grp)
            route_maps.append((edges, routes))

        neighbors, degrees, n_nodes, mask = batched_graphs(overlay_graphs)
        Bg, Cg = mask.shape
        edge_hops = np.ones((Bg, Cg, neighbors.shape[2]), np.int32)
        xb = np.zeros((Bg, Cg, V), np.float32)
        for b, og in enumerate(overlay_graphs):
            edge_hops[b, : og.n, : og.max_deg] = og.edge_hops
            xb[b, : og.n] = rep_val[group_members[b]]
        fixed = (
            _fi_ticks(int(n_nodes.max()), eps, fixed_ticks_scale, quadratic=True)
            if fixed_ticks_scale > 0
            else None
        )
        res = gossip_until(
            xb,
            neighbors,
            degrees,
            n_nodes,
            eps=eps,
            seed=int(rng.integers(2**31)),
            edge_hops=edge_hops,
            max_ticks=max_ticks_per_level,
            fixed_ticks=fixed,
            loss_p=loss_p,
        )
        messages += res.total_messages
        _attribute_overlay_sends(node_sends, res, overlay_graphs, route_maps, n)
        levels.append(_report(j, res, n_nodes, max_hops=level_max_hops))

        if j == 1:
            # level-1 grid done: reps of level-2 cells hold the estimate
            final_val_of_cell = dict(
                zip(cur_cells.tolist(), res.estimates()[_flat_index(group_members)])
            )
            break

        # elect a level-j representative per grid; promote gossiped value
        centers = part.cell_center(j, uniq_parents)
        new_rep_node = np.zeros(len(groups), np.int64)
        new_rep_val = np.zeros((len(groups), V), np.float32)
        for b, grp in enumerate(group_members):
            local_sel = _elect(
                rng,
                rep_mode,
                np.arange(len(grp)),
                g.coords[rep_node[grp]],
                centers[b],
            )
            node = int(rep_node[grp[local_sel]])
            new_rep_node[b] = node
            rep_counts[node] += 1
            if weighted:
                # gossiped channels are per-rep averages; promote total mass
                new_rep_val[b] = res.x[b, local_sel] * len(grp)
            else:
                new_rep_val[b, 0] = res.x[b, local_sel, 0]
        rep_node, rep_val = new_rep_node, new_rep_val
        cur_cells = uniq_parents
        cur_level = j

    # ---------------- dissemination down-pass (n messages) ----------------
    x_final = np.zeros(n, np.float32)
    if K == 1:
        # degenerate single-level run == plain randomized gossip; every
        # node already holds its estimate, nothing to disseminate
        x_final[sub_ids[0]] = est[0, : len(sub_ids[0])]
    else:
        lvl2_cells = part.cell_of(g.coords, 2)
        for c, val in final_val_of_cell.items():
            x_final[lvl2_cells == c] = val
        messages += n
        node_sends += 1
    levels.append(
        LevelReport(
            level=0,
            num_graphs=0,
            messages=n if K >= 2 else 0,
            max_ticks=0,
            converged_frac=1.0,
            max_hops=1,
            graph_sizes=(0, 0.0, 0),
        )
    )
    return MultiscaleResult(
        x_final=x_final,
        messages=messages,
        levels=levels,
        node_sends=node_sends,
        rep_counts=rep_counts,
        disconnected_cells=disconnected,
        partition=part,
    )


def _flat_index(group_members: list) -> tuple[np.ndarray, np.ndarray]:
    """(batch_index, local_index) covering all members, ordered so that the
    concatenation matches np.concatenate(group_members)."""
    b = np.concatenate(
        [np.full(len(grp), i, np.int64) for i, grp in enumerate(group_members)]
    )
    l = np.concatenate([np.arange(len(grp), dtype=np.int64) for grp in group_members])
    # reorder to ascending original member id
    order = np.argsort(np.concatenate(group_members), kind="stable")
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    return b[inv], l[inv]


def _report(level: int, res: GossipResult, n_nodes: np.ndarray, max_hops: int) -> LevelReport:
    return LevelReport(
        level=level,
        num_graphs=len(n_nodes),
        messages=int(res.messages.sum()),
        max_ticks=int(res.ticks.max()),
        converged_frac=float(res.converged.mean()),
        max_hops=int(max_hops),
        graph_sizes=(int(n_nodes.min()), float(n_nodes.mean()), int(n_nodes.max())),
    )


def _attribute_base_sends(
    node_sends: np.ndarray,
    res: GossipResult,
    sub_ids: list,
    neighbors: np.ndarray,
) -> None:
    """Single-hop exchanges: initiator and partner each transmit once."""
    usage = res.edge_usage
    for b, ids in enumerate(sub_ids):
        out_counts = usage[b, : len(ids)].sum(axis=1)
        node_sends[ids] += out_counts
        nbr = neighbors[b, : len(ids)]
        u = usage[b, : len(ids)]
        valid = nbr >= 0
        np.add.at(
            node_sends,
            ids[nbr[valid]],
            u[valid],
        )


def _attribute_overlay_sends(
    node_sends: np.ndarray,
    res: GossipResult,
    overlay_graphs: list,
    route_maps: list,
    n: int,
) -> None:
    """Multi-hop exchanges: every node along the route transmits (forward
    senders nodes[0..L-1], reply senders nodes[L..1])."""
    for b, og in enumerate(overlay_graphs):
        edges, routes = route_maps[b]
        usage = res.edge_usage[b]
        # map (u, local neighbor slot) -> edge index
        slot_of = {}
        deg_ptr = [0] * og.n
        for e_idx, (u, v) in enumerate(edges):
            slot_of[(u, og.neighbors[u].tolist().index(v))] = e_idx
            slot_of[(v, og.neighbors[v].tolist().index(u))] = e_idx
        for u in range(og.n):
            for s in range(og.degrees[u]):
                c = int(usage[u, s])
                if c == 0:
                    continue
                e_idx = slot_of[(u, s)]
                node_sends += c * routes[e_idx].send_counts(n)
