"""Multiscale gossip — the paper's Algorithm 1 (compatibility wrapper).

This module is now a thin facade over the plan/execute simulation core:

* `core.plan.build_plan` runs the ahead-of-time pass — recursive
  partition, induced-subgraph batches, overlay grid edges, representative
  election, batched greedy-geographic routes as padded arrays, and
  route-incidence CSR attribution;
* `core.engine.execute_plan` runs all K levels on device in one compiled
  call (batched gossip, Alg.-1 line-16 reweighting, promotion and
  dissemination as gathers), `vmap`-ped over Monte-Carlo trial seeds.

`multiscale_gossip(...)` keeps its historical signature and
`MultiscaleResult` shape; `trials=T` returns a `MultiscaleTrials` with
per-trial arrays from one vmapped execution, and `plan=` reuses a
prebuilt `HierarchyPlan` across calls (trial t of a batched run equals a
single run with seed `seed + t` on the same plan).

Algorithm recap (paper Alg. 1):

  1. level k (finest): randomized gossip inside every cell's induced
     subgraph; elect a representative per cell; reweight its value by
     |cell| * (#present sibling cells) / |parent|  (Alg. 1 line 16).
  2. levels j = k-1 .. 1: representatives form a grid graph per level-j
     cell; every exchange costs 2 * hops single-hop transmissions via
     greedy geographic routing on the base graph.
  3. after the level-1 grid converges, every level-2 representative
     disseminates its value to its cell (n messages total).

`weighted=True` enables the beyond-paper exact-mass variant: values
travel as (w*x, w) pairs (still constant message size), making every
level's fusion exact under perfect per-level gossip regardless of cell
occupancy.  The paper's plain algorithm is `weighted=False`.

`fixed_ticks_scale` > 0 selects MultiscaleGossipFI (§VI): every graph at
a level runs a deterministic number of exchanges derived from the worst
case expected graph size, removing the convergence oracle (and the
synchronization it implies) at the cost of redundant messages.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from .engine import EngineResult, execute_plan, trials_error
from .medium import CostModel, FailureModel, MediumCost
from .options import ExecOptions
from .partition import Partition
from .plan import HierarchyPlan, build_plan
from .rgg import Graph

__all__ = [
    "MultiscaleResult",
    "MultiscaleTrials",
    "LevelReport",
    "multiscale_gossip",
]


@dataclasses.dataclass
class LevelReport:
    level: int
    num_graphs: int
    messages: int
    max_ticks: int
    converged_frac: float
    max_hops: int          # longest routed exchange at this level
    graph_sizes: tuple     # (min, mean, max) nodes per graph


@dataclasses.dataclass
class MultiscaleResult:
    x_final: np.ndarray       # (n,) estimate at every node
    messages: int             # total single-hop transmissions
    levels: list[LevelReport]
    node_sends: np.ndarray    # (n,) transmissions attributed per node
    rep_counts: np.ndarray    # (n,) #times each node served as representative
    disconnected_cells: int   # finest-level cells whose subgraph was disconnected
    partition: Partition
    cost: Optional[MediumCost] = None  # priced medium cost (CostModel runs)

    def error(self, x0: np.ndarray) -> float:
        """Paper's final relative error ||x_final - avg|| / ||x0||."""
        avg = float(np.mean(x0))
        return float(np.linalg.norm(self.x_final - avg) / np.linalg.norm(x0))


@dataclasses.dataclass
class MultiscaleTrials:
    """T Monte-Carlo trials from one vmapped plan execution: trial t is
    bit-equivalent to a single run with seed `seeds[t]` on `plan`."""

    x_final: np.ndarray       # (T, n)
    messages: np.ndarray      # (T,)
    node_sends: np.ndarray    # (T, n)
    seeds: tuple              # per-trial gossip seeds
    levels: list[LevelReport]  # trial-averaged per-level reports
    rep_counts: np.ndarray    # (n,) — shared: election is part of the plan
    disconnected_cells: int
    partition: Partition
    backend: str
    cost: Optional[MediumCost] = None  # per-trial priced cost (CostModel runs)

    @property
    def trials(self) -> int:
        return int(self.x_final.shape[0])

    def error(self, x0: np.ndarray) -> np.ndarray:
        """(T,) per-trial relative error; x0 is (n,) or (T, n)."""
        return trials_error(self.x_final, x0)


def _level_reports(
    plan: HierarchyPlan, res: EngineResult, n: int
) -> list[LevelReport]:
    """Per-level reports (averaged over trials for T > 1)."""
    out = []
    for li, lp in enumerate(plan.levels):
        out.append(LevelReport(
            level=lp.level,
            num_graphs=lp.num_graphs,
            messages=int(res.level_messages[:, li].mean()),
            max_ticks=int(res.level_ticks[:, li].max()),
            converged_frac=float(res.level_converged[:, li].mean()),
            max_hops=lp.max_hops,
            graph_sizes=lp.graph_sizes,
        ))
    out.append(LevelReport(
        level=0, num_graphs=0, messages=n if plan.disseminate else 0,
        max_ticks=0, converged_frac=1.0, max_hops=1, graph_sizes=(0, 0.0, 0),
    ))
    return out


def multiscale_gossip(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
    weighted: bool = False,
    fixed_ticks_scale: float = 0.0,
    trials: int = 1,
    plan: Optional[HierarchyPlan] = None,
    options: Optional[ExecOptions] = None,
    failures: Optional[FailureModel] = None,
    cost: Optional[CostModel] = None,
) -> Union[MultiscaleResult, MultiscaleTrials]:
    """Run multiscale gossip (Alg. 1); see module docstring.

    With `trials=T` all T trials execute in one compiled vmapped call
    (seeds `seed .. seed+T-1`) and a `MultiscaleTrials` is returned.
    Pass `plan=` to reuse a prebuilt `HierarchyPlan` (then `k`, `a`,
    `cell_max`, `rep_mode` are taken from the plan and `seed` only
    drives the gossip randomness).

    `options` (`ExecOptions`) selects backend / schedule / mesh / check
    cadence / tick budget; `failures` (`FailureModel`) carries the
    paper's loss model plus churn / straggler / regional / Byzantine
    scenarios; `cost` (`CostModel`) prices the run onto the wireless
    medium into `.cost` without perturbing the exchange trajectory.
    The historical flat kwargs (``backend=``, ``loss_p=``, ...) were
    removed after their deprecation window — a stale call now raises
    `TypeError`.
    """
    if options is None:
        options = ExecOptions()
    if plan is None:
        plan = build_plan(
            g, k=k, a=a, cell_max=cell_max, seed=seed, rep_mode=rep_mode
        )
    n = g.n
    seeds = tuple(int(seed) + t for t in range(trials))
    res = execute_plan(
        plan, x0, eps=eps, seeds=seeds, weighted=weighted,
        fixed_ticks_scale=fixed_ticks_scale,
        options=options, failures=failures, cost=cost,
    )
    reports = _level_reports(plan, res, n)
    if trials == 1:
        return MultiscaleResult(
            x_final=res.x_final[0],
            messages=int(res.messages[0]),
            levels=reports,
            node_sends=res.node_sends[0],
            rep_counts=plan.rep_counts.copy(),
            disconnected_cells=plan.disconnected_cells,
            partition=plan.partition,
            cost=res.cost,
        )
    return MultiscaleTrials(
        x_final=res.x_final,
        messages=res.messages,
        node_sends=res.node_sends,
        seeds=seeds,
        levels=reports,
        rep_counts=plan.rep_counts.copy(),
        disconnected_cells=plan.disconnected_cells,
        partition=plan.partition,
        backend=options.backend,
        cost=res.cost,
    )
