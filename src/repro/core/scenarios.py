"""Declarative failure-scenario matrix for multiscale gossip.

The paper evaluates robustness with a single knob (per-hop message loss,
§VI-C-2).  Real wireless deployments fail in richer, correlated ways —
nodes churn out mid-gossip, heterogeneous links straggle, a storm takes
out a whole region, a buggy node stops applying updates.  This module
turns those into a declarative matrix of named `Scenario`s, each just a
`FailureModel` (`core.medium`), and replays ONE shared plan under every
scenario: the engine perturbs the presampled exchange schedule and
reruns the value pass, so a scenario run is exactly the reliable run's
schedule with the events injected (same plan, same gossip seeds).

Every scenario reports the achieved relative error (all nodes and
surviving nodes — dead nodes keep their last value, which is the honest
deployment read-out but unfair to the algorithm) and, when a
`CostModel` is passed, the priced medium cost.

Scenario event times are fractions of the finest level's tick budget,
so the matrix runs in fixed-iterations mode (`fixed_ticks_scale > 0`,
the paper's MultiscaleGossipFI) where that budget is well-defined.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .engine import trials_error
from .medium import CostModel, FailureModel, MediumCost, failure_sets
from .options import ExecOptions
from .plan import HierarchyPlan, build_plan
from .rgg import Graph

__all__ = [
    "Scenario",
    "ScenarioResult",
    "scenario_matrix",
    "run_scenario_matrix",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named cell of the failure matrix."""

    name: str
    failures: Optional[FailureModel] = None  # None = reliable baseline
    description: str = ""


def scenario_matrix(
    *,
    loss_p: Optional[float] = None,
    churn_fraction: float = 0.2,
    straggler_fraction: float = 0.3,
    regional_radius: float = 0.25,
    drop_fraction: float = 0.1,
    seed: int = 0,
) -> list[Scenario]:
    """The default 5-scenario matrix: reliable baseline plus one
    scenario per failure family.  `loss_p` (if set) applies to every
    scenario on top of its events — the paper's loss model composes
    with the richer failures."""
    fm = dict(loss_p=loss_p, seed=seed)
    return [
        Scenario(
            "baseline",
            FailureModel(**fm) if loss_p is not None else None,
            "reliable network (paper's default)",
        ),
        Scenario(
            "churn",
            FailureModel(churn_fraction=churn_fraction, churn_time=0.5, **fm),
            f"{churn_fraction:.0%} of nodes leave halfway through the "
            "finest level and stay down",
        ),
        Scenario(
            "stragglers",
            FailureModel(straggler_fraction=straggler_fraction,
                         straggler_success=0.25, **fm),
            f"{straggler_fraction:.0%} slow nodes: their exchanges "
            "succeed 25% of the time at full cost",
        ),
        Scenario(
            "regional",
            FailureModel(regional_radius=regional_radius,
                         regional_window=(0.25, 0.75), **fm),
            f"radius-{regional_radius} outage around a random epicenter "
            "for the middle half of the finest level",
        ),
        Scenario(
            "byzantine",
            FailureModel(drop_fraction=drop_fraction, **fm),
            f"{drop_fraction:.0%} of nodes never apply incoming updates",
        ),
    ]


@dataclasses.dataclass
class ScenarioResult:
    """One scenario's replay: per-trial errors and priced cost."""

    scenario: Scenario
    errors: np.ndarray             # (T,) relative error, all nodes
    survivor_errors: np.ndarray    # (T,) error over non-dead nodes only
    messages: np.ndarray           # (T,) logical single-hop transmissions
    cost: Optional[MediumCost]     # priced medium cost (cost= runs)
    seeds: tuple

    @property
    def err_mean(self) -> float:
        return float(self.errors.mean())

    @property
    def err_std(self) -> float:
        return float(self.errors.std())

    @property
    def energy_mean(self) -> float:
        if self.cost is None:
            return float(self.messages.mean())
        return float(self.cost.energy.mean())


def _survivor_error(x_final, x0, live):
    """Relative error against the TRUE all-node average, measured only
    at surviving nodes (dead nodes freeze their last value)."""
    x0 = np.asarray(x0, np.float64)
    avg = x0.mean(axis=-1, keepdims=True)
    xf = np.asarray(x_final, np.float64)[:, live]
    num = np.linalg.norm(xf - avg, axis=-1)
    den = np.linalg.norm(
        np.broadcast_to(x0, np.asarray(x_final).shape)[:, live], axis=-1)
    return num / np.maximum(den, 1e-30)


def run_scenario_matrix(
    g: Graph,
    x0: np.ndarray,
    scenarios: Optional[Sequence[Scenario]] = None,
    *,
    eps: float = 1e-4,
    trials: int = 4,
    seed: int = 0,
    weighted: bool = False,
    fixed_ticks_scale: float = 1.0,
    options: Optional[ExecOptions] = None,
    cost: Optional[CostModel] = None,
    plan: Optional[HierarchyPlan] = None,
) -> list[ScenarioResult]:
    """Replay every scenario over ONE shared plan and gossip-seed set.

    Runs in fixed-iterations mode (`fixed_ticks_scale > 0` required:
    scenario event times are fractions of the finest level's budget,
    which the eps-oracle mode leaves unbounded).  The reliable baseline
    and every scenario share the plan and the per-trial seeds, so
    differences are attributable to the injected events alone.
    """
    if fixed_ticks_scale <= 0:
        raise ValueError(
            "run_scenario_matrix requires fixed_ticks_scale > 0 "
            "(scenario event times are fractions of the fixed budget)")
    from .multiscale import multiscale_gossip

    if scenarios is None:
        scenarios = scenario_matrix()
    if plan is None:
        plan = build_plan(g, seed=seed)
    out = []
    for sc in scenarios:
        res = multiscale_gossip(
            g, x0, eps=eps, seed=seed, trials=trials, weighted=weighted,
            fixed_ticks_scale=fixed_ticks_scale, plan=plan,
            options=options, failures=sc.failures, cost=cost,
        )
        live = np.ones(g.n, bool)
        if sc.failures is not None and sc.failures.has_scenario:
            sets = failure_sets(sc.failures, g.n, coords=g.coords)
            live &= ~sets["churned"]
            if sc.failures.regional_window[1] > 1.0:
                live &= ~sets["regional"]
        # trials=1 returns a MultiscaleResult with unbatched shapes
        xf = np.atleast_2d(np.asarray(res.x_final))
        out.append(ScenarioResult(
            scenario=sc,
            errors=trials_error(xf, x0),
            survivor_errors=_survivor_error(xf, x0, live),
            messages=np.atleast_1d(np.asarray(res.messages, np.int64)),
            cost=res.cost,
            seeds=getattr(res, "seeds", (int(seed),)),
        ))
    return out
