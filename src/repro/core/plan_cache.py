"""Content-addressed persistent cache for built `HierarchyPlan`s.

A plan is a pure function of (graph spec, partition config, routing
params, plan seed, builder version): hash those into a key, pickle the
built plan under it, and warm runs — repeated fig sweeps, CI smokes,
`benchmarks/large_n.py` — skip both graph generation and plan
construction entirely (the plan embeds its graph).

Key design:

* the spec is canonical JSON over plain scalars — seeded graphs hash
  their (kind, n, c, seed, method) recipe; externally built graphs hash
  a sha256 digest of coords + CSR adjacency instead;
* `PLAN_CACHE_VERSION` is baked into every key: bump it whenever the
  builder's output layout changes and all old entries silently miss
  (versioned invalidation — no migration code);
* `workers` is deliberately NOT part of the key — the parallel build is
  bitwise-identical to the serial one, so it must hit the same entry;
* writes are atomic (tmp file + rename), safe under concurrent runs;
* a hit is bitwise-equal to a fresh build (asserted by
  tests/test_plan_cache.py).

The default cache directory is `$REPRO_PLAN_CACHE` or
`~/.cache/repro/plan_cache`; benchmarks point it at
`benchmarks/artifacts/plan_cache` (gitignored).
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import tempfile
import time
from typing import Any, Optional

import numpy as np

from .plan import HierarchyPlan, build_plan
from .rgg import Graph, random_geometric_graph

__all__ = [
    "PLAN_CACHE_VERSION",
    "default_cache_dir",
    "graph_spec",
    "graph_digest_spec",
    "plan_key",
    "load_plan",
    "store_plan",
    "setup_plan",
]

# bump on any change to plan layout or builder semantics; stale entries
# then miss by construction
PLAN_CACHE_VERSION = 1


def default_cache_dir() -> str:
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "plan_cache"
    )


def _digest_arrays(*arrays: np.ndarray) -> str:
    h = hashlib.sha256()
    for a in arrays:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def graph_spec(
    n: int, *, c: float = 3.0, seed: int = 0,
    radius: Optional[float] = None,
) -> dict:
    """Spec for a seeded `random_geometric_graph` — hashes the recipe,
    not the arrays, so the warm path can skip generation entirely.  The
    builder `method`/`chunk` are excluded: every builder produces the
    same Graph (bitwise, tested)."""
    return {
        "kind": "rgg",
        "n": int(n),
        "c": float(c),
        "seed": int(seed),
        "radius": None if radius is None else float(radius),
    }


def graph_digest_spec(g: Graph) -> dict:
    """Spec for an externally built graph: content digest of coords +
    CSR adjacency."""
    return {
        "kind": "digest",
        "n": g.n,
        "radius": float(g.radius),
        "sha256": _digest_arrays(
            g.coords, g.nbr_start, g.nbr_flat, g.degrees
        ),
    }


def plan_key(
    graph: dict,
    *,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
) -> str:
    """Content hash of everything a build depends on (except `workers`,
    which cannot change the output)."""
    spec = {
        "version": PLAN_CACHE_VERSION,
        "graph": graph,
        "plan": {
            "k": None if k is None else int(k),
            "a": float(a),
            "cell_max": float(cell_max),
            "seed": int(seed),
            "rep_mode": str(rep_mode),
        },
    }
    blob = json.dumps(spec, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _entry_path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"{key}.plan.pkl")


def load_plan(key: str, cache_dir: Optional[str] = None) -> Optional[HierarchyPlan]:
    """Return the cached plan for `key`, or None on a miss (absent,
    unreadable, or a key mismatch from a hash collision / truncation)."""
    path = _entry_path(cache_dir or default_cache_dir(), key)
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
    except (OSError, pickle.UnpicklingError, EOFError, AttributeError):
        return None
    if not isinstance(payload, dict) or payload.get("key") != key:
        return None
    return payload.get("plan")


def store_plan(
    key: str, plan: HierarchyPlan, cache_dir: Optional[str] = None
) -> str:
    """Atomically persist `plan` under `key`; returns the entry path."""
    cache_dir = cache_dir or default_cache_dir()
    os.makedirs(cache_dir, exist_ok=True)
    path = _entry_path(cache_dir, key)
    payload = {"key": key, "version": PLAN_CACHE_VERSION, "plan": plan}
    fd, tmp = tempfile.mkstemp(dir=cache_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(payload, f, protocol=5)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def setup_plan(
    n: Optional[int] = None,
    *,
    g: Optional[Graph] = None,
    c: float = 3.0,
    graph_seed: int = 0,
    radius: Optional[float] = None,
    graph_method: str = "bucket",
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    seed: int = 0,
    rep_mode: str = "random",
    workers: int = 0,
    cache_dir: Optional[str] = None,
    use_cache: bool = True,
    refresh: bool = False,
) -> tuple[HierarchyPlan, dict]:
    """End-to-end cached setup: graph generation + plan build, skipped
    wholesale on a cache hit (the plan embeds its graph).

    Pass either `n` (+ graph params, the seeded-RGG recipe) or a
    prebuilt `g` (hashed by content).  Returns `(plan, info)` where
    info records {cache: "hit"|"miss"|"off", key, graph_gen_s,
    plan_build_s, load_s | store_s, setup_s}.  `refresh=True` forces a
    rebuild (and re-store) even if an entry exists — the benchmark's
    cold path.
    """
    if (n is None) == (g is None):
        raise ValueError("pass exactly one of n= or g=")
    t_all = time.perf_counter()
    if g is None:
        gspec = graph_spec(n, c=c, seed=graph_seed, radius=radius)
    else:
        gspec = graph_digest_spec(g)
    key = plan_key(
        gspec, k=k, a=a, cell_max=cell_max, seed=seed, rep_mode=rep_mode
    )
    info: dict[str, Any] = {"key": key, "graph_gen_s": 0.0}
    if use_cache and not refresh:
        t0 = time.perf_counter()
        plan = load_plan(key, cache_dir=cache_dir)
        if plan is not None:
            info.update(
                cache="hit",
                load_s=round(time.perf_counter() - t0, 6),
                plan_build_s=dict(plan.build_seconds or {}),
                setup_s=round(time.perf_counter() - t_all, 6),
            )
            return plan, info
    if g is None:
        t0 = time.perf_counter()
        g = random_geometric_graph(
            n, c=c, seed=graph_seed, radius=radius, method=graph_method
        )
        info["graph_gen_s"] = round(time.perf_counter() - t0, 6)
    plan = build_plan(
        g, k=k, a=a, cell_max=cell_max, seed=seed, rep_mode=rep_mode,
        workers=workers,
    )
    info["plan_build_s"] = dict(plan.build_seconds or {})
    if use_cache:
        t0 = time.perf_counter()
        store_plan(key, plan, cache_dir=cache_dir)
        info["store_s"] = round(time.perf_counter() - t0, 6)
        info["cache"] = "miss"
    else:
        info["cache"] = "off"
    info["setup_s"] = round(time.perf_counter() - t_all, 6)
    return plan, info
