"""Synchronous multiscale gossip — the TPU-native production fast path.

The asynchronous single-pair simulation (`multiscale.py` / the
plan-execute engine) is faithful to the paper but hostile to the MXU.
Here each level's gossip is executed as synchronous rounds of
doubly-stochastic mixing,

    x_cells <- W_cells^R @ x_cells      (all cells batched),

via the `cell_mixing` Pallas kernel (DESIGN.md §3).  Expected-value
equivalence with asynchronous pairwise gossip is standard (Boyd et al.);
message accounting per synchronous round is 2 transmissions per base
edge (or 2*hops per overlay edge).

Topology, routing, and promotion structure all come from the shared
`core.plan.HierarchyPlan` (rep_mode="first": deterministic election),
so this path and the asynchronous engine execute the same hierarchy.

Node values may be d-dimensional — this is the entry point used by
`repro.dist` to prototype gradient-vector averaging at network scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .plan import HierarchyPlan, build_plan
from .rgg import Graph

__all__ = ["SyncMultiscaleResult", "synchronous_multiscale"]


@dataclasses.dataclass
class SyncMultiscaleResult:
    x_final: np.ndarray     # (n, d)
    messages: int
    rounds_per_level: list[tuple[int, int]]  # (level, rounds)

    def error(self, x0: np.ndarray) -> float:
        avg = x0.mean(axis=0, keepdims=True)
        return float(
            np.linalg.norm(self.x_final - avg) / max(np.linalg.norm(x0), 1e-30)
        )


def _mix_until(w, x, mask, counts, eps, max_rounds, chunk, kernel_kwargs):
    """Apply W repeatedly (chunked) until every cell is within eps of its
    mean. Returns (x, rounds)."""
    from repro.kernels.cell_mixing import cell_mixing

    live = mask[..., None].astype(np.float32)
    mean = (x * live).sum(1, keepdims=True) / np.maximum(
        live.sum(1, keepdims=True), 1.0
    )
    tol = eps * np.maximum(
        np.sqrt(((x * live) ** 2).sum((1, 2))), 1e-30
    )
    rounds = 0
    cur = x
    while rounds < max_rounds:
        err = np.sqrt((((cur - mean) * live) ** 2).sum((1, 2)))
        if (err <= tol).all():
            break
        cur = np.asarray(cell_mixing(w, cur, rounds=chunk, **kernel_kwargs))
        rounds += chunk
    return cur, rounds


def _level_exchange_cost(lp) -> int:
    """Single-hop transmissions per synchronous round at this level:
    2 per base edge, 2*hops per overlay edge."""
    if lp.kind == "cells":
        return int(lp.degrees.sum())  # = 2 * #edges
    hops = lp.hop_flat[lp.edge_pos_i]
    return int(2 * hops.sum())


def synchronous_multiscale(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    chunk: int = 8,
    max_rounds: int = 4096,
    use_pallas: bool = False,
    interpret: bool = False,
    plan: Optional[HierarchyPlan] = None,
) -> SyncMultiscaleResult:
    """Weighted (exact-mass) multiscale averaging with synchronous mixing.

    x0 may be (n,) scalars or (n, d) vectors (gradient prototyping).
    """
    from repro.kernels.cell_mixing import mixing_matrix

    x0 = np.asarray(x0, np.float32)
    if x0.ndim == 1:
        x0 = x0[:, None]
    n, d = x0.shape
    if plan is None:
        plan = build_plan(g, k=k, a=a, cell_max=cell_max, rep_mode="first")
    kernel_kwargs = dict(use_pallas=use_pallas, interpret=interpret)
    messages = 0
    rounds_log = []

    xb = None
    for li, lp in enumerate(plan.levels):
        B, C = lp.node_mask.shape
        if lp.kind == "cells":
            # channels: [w*x (d), w] for exact-mass fusion
            xb = np.zeros((B, C, d + 1), np.float32)
            live = lp.node_mask
            xb[..., :d][live] = x0[lp.slot_node[live]]
            xb[..., d][live] = 1.0
        w = mixing_matrix(lp.neighbors, lp.degrees, lp.n_nodes)
        xb, rounds = _mix_until(
            w, xb, lp.node_mask, lp.n_nodes, eps, max_rounds, chunk,
            kernel_kwargs,
        )
        messages += _level_exchange_cost(lp) * rounds
        rounds_log.append((lp.level, rounds))
        if lp.rep_slot is not None:
            # promote the representative's total cell mass to the parent grid
            rep = xb[np.arange(B), lp.rep_slot]            # (B, d+1)
            rep = rep * lp.n_nodes[:, None].astype(np.float32)
            B2, C2 = plan.levels[li + 1].node_mask.shape
            nxt = np.zeros((B2, C2, d + 1), np.float32)
            nxt[lp.next_graph, lp.next_slot] = rep
            xb = nxt

    est = xb[..., :d] / np.maximum(xb[..., d:], 1e-30)
    x_final = est[plan.final_graph, plan.final_slot]
    if plan.disseminate:
        messages += n
    return SyncMultiscaleResult(
        x_final=x_final, messages=messages, rounds_per_level=rounds_log
    )
