"""Synchronous multiscale gossip — the TPU-native production fast path.

The asynchronous single-pair simulation (`multiscale.py`) is faithful to
the paper but hostile to the MXU.  Here each level's gossip is executed
as synchronous rounds of doubly-stochastic mixing,

    x_cells <- W_cells^R @ x_cells      (all cells batched),

via the `cell_mixing` Pallas kernel (DESIGN.md §3).  Expected-value
equivalence with asynchronous pairwise gossip is standard (Boyd et al.);
message accounting per synchronous round is 2 transmissions per base
edge (or 2*hops per overlay edge).

Node values may be d-dimensional — this is the entry point used by
`repro.dist` to prototype gradient-vector averaging at network scale.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .gossip import batched_graphs
from .multiscale import _OverlayGraph, _connect_components  # shared topology logic
from .partition import build_partition
from .rgg import Graph, induced_subgraph
from .routing import route_to_node

__all__ = ["SyncMultiscaleResult", "synchronous_multiscale"]


@dataclasses.dataclass
class SyncMultiscaleResult:
    x_final: np.ndarray     # (n, d)
    messages: int
    rounds_per_level: list[tuple[int, int]]  # (level, rounds)

    def error(self, x0: np.ndarray) -> float:
        avg = x0.mean(axis=0, keepdims=True)
        return float(
            np.linalg.norm(self.x_final - avg) / max(np.linalg.norm(x0), 1e-30)
        )


def _mix_until(w, x, mask, counts, eps, max_rounds, chunk, kernel_kwargs):
    """Apply W repeatedly (chunked) until every cell is within eps of its
    mean. Returns (x, rounds)."""
    from repro.kernels.cell_mixing import cell_mixing

    live = mask[..., None].astype(np.float32)
    mean = (x * live).sum(1, keepdims=True) / np.maximum(
        live.sum(1, keepdims=True), 1.0
    )
    tol = eps * np.maximum(
        np.sqrt(((x * live) ** 2).sum((1, 2))), 1e-30
    )
    rounds = 0
    cur = x
    while rounds < max_rounds:
        err = np.sqrt((((cur - mean) * live) ** 2).sum((1, 2)))
        if (err <= tol).all():
            break
        cur = np.asarray(cell_mixing(w, cur, rounds=chunk, **kernel_kwargs))
        rounds += chunk
    return cur, rounds


def synchronous_multiscale(
    g: Graph,
    x0: np.ndarray,
    *,
    eps: float = 1e-4,
    k: Optional[int] = None,
    a: float = 2.0 / 3.0,
    cell_max: float = 8.0,
    chunk: int = 8,
    max_rounds: int = 4096,
    use_pallas: bool = False,
    interpret: bool = False,
) -> SyncMultiscaleResult:
    """Weighted (exact-mass) multiscale averaging with synchronous mixing.

    x0 may be (n,) scalars or (n, d) vectors (gradient prototyping).
    """
    from repro.kernels.cell_mixing import mixing_matrix

    x0 = np.asarray(x0, np.float32)
    if x0.ndim == 1:
        x0 = x0[:, None]
    n, d = x0.shape
    part = build_partition(n, k=k, a=a, cell_max=cell_max)
    K = part.k
    kernel_kwargs = dict(use_pallas=use_pallas, interpret=interpret)
    messages = 0
    rounds_log = []

    # ---- finest level ----
    cell_of_node = part.cell_of(g.coords, K)
    present = np.unique(cell_of_node)
    subgraphs, sub_ids = [], []
    for c in present:
        sg, ids = induced_subgraph(g, np.where(cell_of_node == c)[0])
        subgraphs.append(sg)
        sub_ids.append(ids)
    neighbors, degrees, n_nodes, mask = batched_graphs(subgraphs)
    w = mixing_matrix(neighbors, degrees, n_nodes)
    B, C = mask.shape
    # channels: [w*x (d), w] for exact-mass fusion
    xb = np.zeros((B, C, d + 1), np.float32)
    for b, ids in enumerate(sub_ids):
        xb[b, : len(ids), :d] = x0[ids]
        xb[b, : len(ids), d] = 1.0
    edges_per_graph = np.array([sg.num_edges for sg in subgraphs])
    xb, rounds = _mix_until(w, xb, mask, n_nodes, eps, max_rounds, chunk, kernel_kwargs)
    messages += int(2 * edges_per_graph.sum() * rounds)
    rounds_log.append((K, rounds))

    # representatives: first node of each cell (synchronous variant uses
    # deterministic election); promote total cell mass
    rep_node = np.array([ids[0] for ids in sub_ids])
    rep_val = np.stack(
        [xb[b, 0] * len(sub_ids[b]) for b in range(B)]
    )  # (B, d+1): (sum wx, sum w)

    cur_cells, cur_level = present, K
    while cur_level > 1:
        j = cur_level - 1
        parents = part.parent_cell(cur_level, cur_cells)
        all_edges = part.child_grid_edges(j)
        order = np.argsort(parents, kind="stable")
        uniq_parents, starts = np.unique(parents[order], return_index=True)
        groups = np.split(order, starts[1:])
        overlay, members, hop_sums = [], [], []
        for grp in groups:
            cells_here = cur_cells[grp]
            local = {int(c): i for i, c in enumerate(cells_here)}
            edges = [
                (local[int(u)], local[int(v)])
                for u, v in all_edges
                if int(u) in local and int(v) in local
            ]
            edges = _connect_components(edges, g.coords[rep_node[grp]], len(grp))
            hops = [
                max(1, route_to_node(g, int(rep_node[grp[u]]), int(rep_node[grp[v]])).hops)
                for u, v in edges
            ]
            overlay.append(
                _OverlayGraph(
                    len(grp),
                    np.asarray(edges, np.int64).reshape(-1, 2),
                    np.asarray(hops, np.int64),
                )
            )
            members.append(grp)
            hop_sums.append(sum(hops))
        neighbors, degrees, n_nodes, mask = batched_graphs(overlay)
        w = mixing_matrix(neighbors, degrees, n_nodes)
        Bg, Cg = mask.shape
        xb = np.zeros((Bg, Cg, d + 1), np.float32)
        for b, grp in enumerate(members):
            xb[b, : len(grp)] = rep_val[grp]
        xb, rounds = _mix_until(
            w, xb, mask, n_nodes, eps, max_rounds, chunk, kernel_kwargs
        )
        messages += int(2 * np.asarray(hop_sums).sum() * rounds)
        rounds_log.append((j, rounds))
        if j == 1:
            final_cells, final_vals = cur_cells, xb[0, : len(members[0])]
            final_members = members[0]
            break
        rep_node = np.array([int(rep_node[grp[0]]) for grp in members])
        rep_val = np.stack(
            [xb[b, 0] * len(members[b]) for b in range(len(members))]
        )
        cur_cells, cur_level = uniq_parents, j

    # dissemination
    x_final = np.zeros((n, d), np.float32)
    if K == 1:
        for b, ids in enumerate(sub_ids):
            est = xb[b, : len(ids), :d] / np.maximum(xb[b, : len(ids), d:], 1e-30)
            x_final[ids] = est
    else:
        lvl2 = part.cell_of(g.coords, 2)
        for pos, grp_idx in enumerate(final_members):
            c = int(final_cells[grp_idx])
            est = final_vals[pos, :d] / max(float(final_vals[pos, d]), 1e-30)
            x_final[lvl2 == c] = est
        messages += n
    return SyncMultiscaleResult(
        x_final=x_final, messages=messages, rounds_per_level=rounds_log
    )
