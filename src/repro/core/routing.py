"""Greedy geographic routing (paper §II, Dimakis et al. [11]).

A message addressed to a target (x, y) location is forwarded, at each
hop, to the neighbor closest to the target; the node closer to the
target than all of its neighbors is the final recipient.  For RGGs with
the connectivity radius this succeeds w.h.p.; as an engineering fallback
(finite n), a stuck route that has not reached the intended node is
completed with a BFS shortest path and flagged.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from .rgg import Graph

__all__ = ["Route", "greedy_route", "route_to_node", "route_table"]


@dataclasses.dataclass(frozen=True)
class Route:
    nodes: np.ndarray   # node ids along the path, nodes[0] = source
    hops: int           # len(nodes) - 1
    greedy_ok: bool     # False if BFS fallback was needed

    def send_counts(self, n: int) -> np.ndarray:
        """Per-node single-hop sends for one request+reply exchange.

        Forward pass: nodes[0..L-1] each transmit once; reply pass:
        nodes[L..1] each transmit once (2L transmissions total).
        """
        sends = np.zeros(n, np.int64)
        if self.hops > 0:
            np.add.at(sends, self.nodes[:-1], 1)
            np.add.at(sends, self.nodes[1:], 1)
        return sends


def greedy_route(
    g: Graph, src: int, target_xy: np.ndarray, max_hops: Optional[int] = None
) -> Route:
    """Route from `src` toward the point `target_xy`; returns the path to
    the node that is locally closest to the target."""
    if max_hops is None:
        max_hops = 4 * g.n
    coords = g.coords
    path = [int(src)]
    cur = int(src)
    d_cur = float(np.sum((coords[cur] - target_xy) ** 2))
    for _ in range(max_hops):
        deg = g.degrees[cur]
        if deg == 0:
            break
        nbrs = g.neighbors[cur, :deg]
        d = np.sum((coords[nbrs] - target_xy) ** 2, axis=1)
        best = int(np.argmin(d))
        if d[best] >= d_cur:
            break  # cur is the local minimizer: final recipient
        cur = int(nbrs[best])
        d_cur = float(d[best])
        path.append(cur)
    return Route(nodes=np.asarray(path, np.int32), hops=len(path) - 1, greedy_ok=True)


def route_to_node(g: Graph, src: int, dst: int) -> Route:
    """Greedy-route from src to the location of dst; BFS fallback if the
    greedy walk terminates elsewhere (rare on connected RGGs)."""
    r = greedy_route(g, src, g.coords[dst])
    if int(r.nodes[-1]) == int(dst):
        return r
    bfs = _bfs_path(g, src, dst)
    if bfs is None:  # disconnected: report the greedy attempt
        return Route(nodes=r.nodes, hops=r.hops, greedy_ok=False)
    return Route(nodes=bfs, hops=len(bfs) - 1, greedy_ok=False)


def _bfs_path(g: Graph, src: int, dst: int) -> Optional[np.ndarray]:
    prev = np.full(g.n, -1, np.int64)
    prev[src] = src
    q = deque([int(src)])
    while q:
        u = q.popleft()
        if u == dst:
            break
        for v in g.neighbors[u, : g.degrees[u]]:
            v = int(v)
            if prev[v] < 0:
                prev[v] = u
                q.append(v)
    if prev[dst] < 0:
        return None
    path = [int(dst)]
    while path[-1] != src:
        path.append(int(prev[path[-1]]))
    return np.asarray(path[::-1], np.int32)


def route_table(g: Graph, pairs: np.ndarray) -> list[Route]:
    """Routes for each (u, v) pair (used to precompute overlay-edge costs)."""
    return [route_to_node(g, int(u), int(v)) for u, v in pairs]
