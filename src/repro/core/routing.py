"""Greedy geographic routing (paper §II, Dimakis et al. [11]).

A message addressed to a target (x, y) location is forwarded, at each
hop, to the neighbor closest to the target; the node closer to the
target than all of its neighbors is the final recipient.  For RGGs with
the connectivity radius this succeeds w.h.p.; as an engineering fallback
(finite n), a stuck route that has not reached the intended node is
completed with a BFS shortest path and flagged.

Two router implementations share the same semantics:

* scalar (`greedy_route` / `route_to_node`) — one walk at a time, the
  reference implementation;
* batched (`batched_greedy_routes` / `batched_routes_to_nodes`) —
  vectorized frontier stepping over E routes at once (all overlay edges
  of a hierarchy level in one call), with a batched level-synchronous
  BFS fallback that reproduces the scalar FIFO BFS hop-for-hop.  The
  batched form returns padded `(E, L+1)` path arrays, the format the
  plan/execute simulation core (`core.plan` / `core.engine`) consumes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from .rgg import Graph

__all__ = [
    "Route",
    "BatchedRoutes",
    "greedy_route",
    "route_to_node",
    "route_table",
    "batched_greedy_routes",
    "batched_routes_to_nodes",
    "accumulate_route_sends",
]


@dataclasses.dataclass(frozen=True)
class Route:
    nodes: np.ndarray   # node ids along the path, nodes[0] = source
    hops: int           # len(nodes) - 1
    greedy_ok: bool     # False if BFS fallback was needed

    def send_counts(self, n: int) -> np.ndarray:
        """Per-node single-hop sends for one request+reply exchange.

        Forward pass: nodes[0..L-1] each transmit once; reply pass:
        nodes[L..1] each transmit once (2L transmissions total).
        """
        sends = np.zeros(n, np.int64)
        if self.hops > 0:
            np.add.at(sends, self.nodes[:-1], 1)
            np.add.at(sends, self.nodes[1:], 1)
        return sends


def greedy_route(
    g: Graph, src: int, target_xy: np.ndarray, max_hops: Optional[int] = None
) -> Route:
    """Route from `src` toward the point `target_xy`; returns the path to
    the node that is locally closest to the target."""
    if max_hops is None:
        max_hops = 4 * g.n
    coords = g.coords
    path = [int(src)]
    cur = int(src)
    d_cur = float(np.sum((coords[cur] - target_xy) ** 2))
    for _ in range(max_hops):
        deg = g.degrees[cur]
        if deg == 0:
            break
        s = g.nbr_start[cur]
        nbrs = g.nbr_flat[s:s + deg]
        d = np.sum((coords[nbrs] - target_xy) ** 2, axis=1)
        best = int(np.argmin(d))
        if d[best] >= d_cur:
            break  # cur is the local minimizer: final recipient
        cur = int(nbrs[best])
        d_cur = float(d[best])
        path.append(cur)
    return Route(nodes=np.asarray(path, np.int32), hops=len(path) - 1, greedy_ok=True)


def route_to_node(g: Graph, src: int, dst: int) -> Route:
    """Greedy-route from src to the location of dst; BFS fallback if the
    greedy walk terminates elsewhere (rare on connected RGGs)."""
    r = greedy_route(g, src, g.coords[dst])
    if int(r.nodes[-1]) == int(dst):
        return r
    bfs = _bfs_path(g, src, dst)
    if bfs is None:  # disconnected: report the greedy attempt
        return Route(nodes=r.nodes, hops=r.hops, greedy_ok=False)
    return Route(nodes=bfs, hops=len(bfs) - 1, greedy_ok=False)


def _bfs_path(g: Graph, src: int, dst: int) -> Optional[np.ndarray]:
    prev = np.full(g.n, -1, np.int64)
    prev[src] = src
    q = deque([int(src)])
    while q:
        u = q.popleft()
        if u == dst:
            break
        for v in g.nbr_flat[g.nbr_start[u]:g.nbr_start[u] + g.degrees[u]]:
            v = int(v)
            if prev[v] < 0:
                prev[v] = u
                q.append(v)
    if prev[dst] < 0:
        return None
    path = [int(dst)]
    while path[-1] != src:
        path.append(int(prev[path[-1]]))
    return np.asarray(path[::-1], np.int32)


def route_table(g: Graph, pairs: np.ndarray) -> list[Route]:
    """Routes for each (u, v) pair (used to precompute overlay-edge costs)."""
    return [route_to_node(g, int(u), int(v)) for u, v in pairs]


# ---------------------------------------------------------------------------
# Batched routing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BatchedRoutes:
    """E routes as padded arrays: nodes[e, 0] is the source, nodes[e, t]
    the node after t hops, -1 past the end."""

    nodes: np.ndarray      # (E, Lmax + 1) int32, padded with -1
    hops: np.ndarray       # (E,) int32
    greedy_ok: np.ndarray  # (E,) bool — False where the BFS fallback ran

    def __len__(self) -> int:
        return int(self.nodes.shape[0])

    def route(self, e: int) -> Route:
        L = int(self.hops[e])
        return Route(
            nodes=self.nodes[e, : L + 1].astype(np.int32),
            hops=L,
            greedy_ok=bool(self.greedy_ok[e]),
        )


def batched_greedy_routes(
    g: Graph,
    srcs: np.ndarray,
    targets_xy: np.ndarray,
    max_hops: Optional[int] = None,
) -> BatchedRoutes:
    """Greedy-route E sources toward E target locations simultaneously.

    Vectorized frontier stepping: one numpy step advances every live
    route by one hop.  Semantics (tie-breaking included) match
    `greedy_route` exactly: rows of `g.neighbors` are compact, so the
    argmin over the padded row with +inf on padding picks the same slot
    the scalar argmin over the first `deg` entries does.
    """
    E = len(srcs)
    if max_hops is None:
        max_hops = 4 * g.n
    cx, cy = g.coords[:, 0], g.coords[:, 1]
    cur = np.asarray(srcs, np.int64).copy()
    targets = np.asarray(targets_xy, np.float64).reshape(E, 2)
    tx, ty = targets[:, 0], targets[:, 1]
    d_cur = (cx[cur] - tx) ** 2 + (cy[cur] - ty) ** 2
    hops = np.zeros(E, np.int64)
    cols = [cur.astype(np.int32)]
    # the frontier compresses to still-moving routes each step, so the
    # per-step cost tracks the number of live walks, not E; the dense
    # padded view is materialized once (cached on the Graph) — a plain
    # row gather per step beats re-packing CSR rows every iteration
    dense = g.neighbors
    act = np.where(g.degrees[cur] > 0)[0]
    for _ in range(max_hops):
        if len(act) == 0:
            break
        nbrs = dense[cur[act]]                       # (A, D)
        valid = nbrs >= 0
        nb = np.where(valid, nbrs, 0)
        d = (cx[nb] - tx[act, None]) ** 2 + (cy[nb] - ty[act, None]) ** 2
        d[~valid] = np.inf
        best = np.argmin(d, axis=1)
        arange = np.arange(len(act))
        d_best = d[arange, best]
        mv = d_best < d_cur[act]
        if not mv.any():
            break
        moved = act[mv]
        new_cur = nbrs[arange, best][mv].astype(np.int64)
        cur[moved] = new_cur
        d_cur[moved] = d_best[mv]
        hops[moved] += 1
        col = np.full(E, -1, np.int32)
        col[moved] = new_cur
        cols.append(col)
        act = moved[g.degrees[new_cur] > 0]
    nodes = np.stack(cols, axis=1) if cols else np.full((E, 1), -1, np.int32)
    return BatchedRoutes(
        nodes=nodes, hops=hops.astype(np.int32), greedy_ok=np.ones(E, bool)
    )


def _batched_bfs(g: Graph, srcs: np.ndarray, dsts: np.ndarray) -> list:
    """Level-synchronous BFS for F (src, dst) pairs at once, reproducing
    the scalar FIFO BFS (`_bfs_path`) hop-for-hop: each discovered node's
    parent is its first discoverer in FIFO order, tracked via discovery
    ranks (rank * max_deg + neighbor-slot is the FIFO key)."""
    F, n, D = len(srcs), g.n, g.max_deg
    srcs = np.asarray(srcs, np.int64)
    dsts = np.asarray(dsts, np.int64)
    prev = np.full((F, n), -1, np.int64)
    rank = np.zeros((F, n), np.int64)
    prev[np.arange(F), srcs] = srcs
    next_rank = np.ones(F, np.int64)
    frontier_f, frontier_v = np.arange(F), srcs.copy()
    found = prev[np.arange(F), dsts] >= 0
    dense = g.neighbors  # cached; rows compact, so slots == CSR offsets
    while len(frontier_f):
        keep = ~found[frontier_f]
        ff, fv = frontier_f[keep], frontier_v[keep]
        if len(ff) == 0:
            break
        nbrs = dense[fv]                             # (M, D)
        mi, slot = np.nonzero(nbrs >= 0)
        cf, cu, cv = ff[mi], fv[mi], nbrs[mi, slot].astype(np.int64)
        undisc = prev[cf, cv] < 0
        cf, cu, cv, slot = cf[undisc], cu[undisc], cv[undisc], slot[undisc]
        if len(cf) == 0:
            break
        key = rank[cf, cu] * D + slot                # unique FIFO key per (f, u, slot)
        flat = cf * n + cv
        order = np.lexsort((key, flat))
        flat_s = flat[order]
        first = np.ones(len(flat_s), bool)
        first[1:] = flat_s[1:] != flat_s[:-1]        # min key per (f, v)
        sel = order[first]
        wf, wu, wv, wkey = cf[sel], cu[sel], cv[sel], key[sel]
        order2 = np.lexsort((wkey, wf))              # FIFO append order per f
        wf, wu, wv = wf[order2], wu[order2], wv[order2]
        counts = np.bincount(wf, minlength=F)
        starts = np.zeros(F, np.int64)
        np.cumsum(counts[:-1], out=starts[1:])
        pos = np.arange(len(wf)) - starts[wf]
        prev[wf, wv] = wu
        rank[wf, wv] = next_rank[wf] + pos
        next_rank += counts
        found = prev[np.arange(F), dsts] >= 0
        frontier_f, frontier_v = wf, wv
    paths = []
    for f in range(F):
        if prev[f, dsts[f]] < 0:
            paths.append(None)
            continue
        p = [int(dsts[f])]
        while p[-1] != int(srcs[f]):
            p.append(int(prev[f, p[-1]]))
        paths.append(np.asarray(p[::-1], np.int32))
    return paths


# serial batching width for the greedy walker: 16k pairs x ~200 slots
# x 8B keeps each step's padded temporaries ~25MB (cache/allocator
# friendly on the same host DEFAULT_CHUNK was tuned for)
_ROUTE_CHUNK = 16_384


def _routes_chunk(payload, lohi) -> BatchedRoutes:
    """fork_map task: route one contiguous slice of the pair list (the
    payload graph/pairs arrive copy-on-write via the forked pool)."""
    g, pairs = payload
    lo, hi = lohi
    return batched_routes_to_nodes(g, pairs[lo:hi])


def _merge_batched_routes(parts: list[BatchedRoutes]) -> BatchedRoutes:
    """Concatenate per-chunk results in chunk order.  Routes for distinct
    pairs are independent, and every path array is (-1)-padded to
    max(hops)+1, so re-padding chunk results to the global width
    reproduces the serial output bitwise."""
    width = max(p.nodes.shape[1] for p in parts)
    nodes = np.full((sum(len(p) for p in parts), width), -1, np.int32)
    row = 0
    for p in parts:
        nodes[row:row + len(p), : p.nodes.shape[1]] = p.nodes
        row += len(p)
    return BatchedRoutes(
        nodes=nodes,
        hops=np.concatenate([p.hops for p in parts]),
        greedy_ok=np.concatenate([p.greedy_ok for p in parts]),
    )


def batched_routes_to_nodes(
    g: Graph, pairs: np.ndarray, workers: int = 0
) -> BatchedRoutes:
    """Batched `route_to_node` for an (E, 2) array of (src, dst) pairs:
    vectorized greedy walks for all pairs, then one batched BFS pass over
    the (rare) pairs whose greedy walk terminated elsewhere.

    ``workers > 1`` shards the pair list across a fork pool
    (`core.parallel.fork_map`); the chunk-order merge is bitwise-equal
    to the serial path.  Serial calls over more than `_ROUTE_CHUNK`
    pairs are chunked the same way in-process: every greedy step's
    temporaries are (live_pairs, max_deg) float64, so bounding the
    batch keeps them allocator- and cache-friendly — same result, one
    walk per pair either way."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    E = len(pairs)
    if workers > 1 and E >= 2 * workers:
        from .parallel import fork_map

        bounds = np.linspace(0, E, workers + 1).astype(np.int64)
        tasks = [
            (int(bounds[i]), int(bounds[i + 1])) for i in range(workers)
        ]
        parts = fork_map(
            _routes_chunk, tasks, workers=workers, payload=(g, pairs)
        )
        return _merge_batched_routes(parts)
    if E > _ROUTE_CHUNK:
        g.neighbors  # materialize the shared dense view once, not per chunk
        parts = [
            batched_routes_to_nodes(g, pairs[lo:lo + _ROUTE_CHUNK])
            for lo in range(0, E, _ROUTE_CHUNK)
        ]
        return _merge_batched_routes(parts)
    srcs, dsts = pairs[:, 0], pairs[:, 1]
    greedy = batched_greedy_routes(g, srcs, g.coords[dsts])
    final = greedy.nodes[np.arange(E), greedy.hops]
    fail = final != dsts
    if not fail.any():
        return greedy
    fidx = np.where(fail)[0]
    bfs_paths = _batched_bfs(g, srcs[fidx], dsts[fidx])
    hops = greedy.hops.copy()
    ok = np.ones(E, bool)
    ok[fidx] = False
    repl = {}
    for f, path in zip(fidx, bfs_paths):
        if path is None:   # disconnected: keep the greedy attempt (flagged)
            continue
        repl[int(f)] = path
        hops[f] = len(path) - 1
    Lmax = int(hops.max())
    nodes = np.full((E, Lmax + 1), -1, np.int32)
    w = min(greedy.nodes.shape[1], Lmax + 1)
    nodes[:, :w] = greedy.nodes[:, :w]
    for f, path in repl.items():
        nodes[f] = -1
        nodes[f, : len(path)] = path
    return BatchedRoutes(nodes=nodes, hops=hops.astype(np.int32), greedy_ok=ok)


def accumulate_route_sends(
    node_sends: np.ndarray, nodes: np.ndarray, hops: np.ndarray,
    weight: Optional[np.ndarray] = None,
) -> None:
    """Scatter-add per-node sends for request+reply traversals of padded
    routes: nodes[0..L-1] and nodes[L..1] each transmit once per use
    (`weight[e]` uses of route e, default 1) — the batched counterpart of
    `Route.send_counts`."""
    E, W = nodes.shape
    if E == 0 or W < 2:
        return
    col = np.arange(W)[None, :]
    fwd = col < hops[:, None]            # senders nodes[0..L-1]
    rep = (col >= 1) & (col <= hops[:, None])  # senders nodes[L..1]
    if weight is None:
        np.add.at(node_sends, nodes[fwd], 1)
        np.add.at(node_sends, nodes[rep], 1)
    else:
        wmat = np.broadcast_to(weight[:, None], (E, W))
        np.add.at(node_sends, nodes[fwd], wmat[fwd])
        np.add.at(node_sends, nodes[rep], wmat[rep])
