"""Fork-based process-pool helper for plan construction.

Plan building is host-side numpy; the shardable stages (routing chunks,
per-node partition labelling, per-edge cell filtering) are data-parallel
over disjoint index ranges with deterministic chunk-order merges, so the
parallel result is bitwise-identical to the serial one (asserted by the
parity tests).

The pool uses the ``fork`` start method so workers inherit the large
payload arrays (graph CSR, coords) copy-on-write instead of pickling
them per task; the payload is published via a module global immediately
before the pool is created.  On platforms without ``fork`` (or with
``workers <= 1``) everything runs serially in-process — ``workers`` is a
correctness-neutral knob.
"""
from __future__ import annotations

import multiprocessing as mp
from typing import Any, Callable, Sequence

__all__ = ["fork_map", "have_fork"]

_PAYLOAD: Any = None


def have_fork() -> bool:
    return "fork" in mp.get_all_start_methods()


def _worker_call(packed):
    fn, task = packed
    return fn(_PAYLOAD, task)


def fork_map(
    fn: Callable[[Any, Any], Any],
    tasks: Sequence[Any],
    *,
    workers: int = 0,
    payload: Any = None,
) -> list:
    """``[fn(payload, t) for t in tasks]``, fanned over a fork pool when
    ``workers > 1``.  `fn` must be a module-level function (pickled by
    reference); `payload` is shared copy-on-write, tasks should be small
    index ranges.  Results come back in task order regardless of which
    worker ran them."""
    tasks = list(tasks)
    if workers <= 1 or len(tasks) <= 1 or not have_fork():
        return [fn(payload, t) for t in tasks]
    global _PAYLOAD
    ctx = mp.get_context("fork")
    _PAYLOAD = payload
    try:
        with ctx.Pool(processes=min(workers, len(tasks))) as pool:
            return pool.map(
                _worker_call, [(fn, t) for t in tasks], chunksize=1
            )
    finally:
        _PAYLOAD = None
