"""Randomized pairwise gossip (Boyd et al. [2]) — the black box used by
multiscale gossip (paper §III, Alg. 1 lines 9/15).

The engine is batched and fully jittable: B independent graphs (e.g. all
cells of one hierarchy level) gossip in lockstep, each with its own
convergence flag, so one `lax.while_loop` simulates a whole level.  The
asynchronous time model is standard: at each tick a uniformly random
node of each not-yet-converged graph wakes, picks a uniformly random
neighbor, and the pair averages.  Messages are counted per directed edge
so multi-hop overlay costs and per-node/relay attribution can be
computed afterwards.

Values may carry V channels (V=2 supports the mass-weighted variant,
where a pair (w*x, w) is averaged and the estimate is their ratio; the
paper's plain algorithm uses V=1).

Optional per-hop message loss (paper §VI-C-2): each single-hop
transmission of an exchange succeeds w.p. `loss_p`; a lost request
aborts the exchange, a lost reply leaves only the contacted node
updated (mass distortion — exactly the failure the paper analyzes).

Schedule / value split (`schedule="presampled"`, the default): every
exchange decision depends only on ``(key, t)``, never on the values, so
each `check_every` chunk first presamples its full ``(T, B)`` exchange
schedule in one batched RNG pass (`core.schedule.sample_schedule` —
usage and message accounting become one scatter-add / one reduction
over the presampled arrays), then applies the pair list with the chosen
value backend:

* ``backend="lax"`` — `kernels.pair_apply.pair_apply_ref`: a scan whose
  body is just two gathers, one average, and two conditional writes
  (the legacy tick with all sampling hoisted out);
* ``backend="pallas"`` — the `kernels.pair_apply` TPU kernel walks the
  schedule with cell state streamed through VMEM in blocks (no HBM
  round-trips within a block); its f32 op sequence matches the oracle
  exactly, so results are bitwise-identical to the lax backend (non-TPU
  hosts dispatch to the oracle; the kernel itself is validated in
  interpret mode by the kernel tests);
* ``backend="matmul"`` — `core.schedule.compose_schedule` folds the
  chunk's elementary pair-average matrices with a log2(T) tree of
  batched matmuls and applies the result via `kernels.cell_mixing`
  (MXU work; values agree up to f32 rounding because matrix
  composition reassociates the sums — integer accounting is still
  exact).

``schedule="per_tick"`` keeps the legacy sequential scan (sampling
interleaved with value updates) as the bitwise-parity reference path;
it supports the lax backend and the historical pallas
eye-rebuild-then-scan branch.

Adjacency is CSR (`core.schedule.CsrGraphs`): one flat entry per
directed edge instead of ``(B, C, D)`` dense padding, with usage
counted in a flat ``(nnz+1,)`` buffer via a 1-D scatter on the sampled
`pos` field.  `gossip_until` keeps the historical dense host API — it
packs dense inputs with `dense_to_csr` and scatters flat usage back to
``(B, C, D)`` for `GossipResult`.

Node sharding (`node_shard=(cols, ok)`): a shard owns columns `cols` of
the global batch (clipped duplicates masked by `ok`).  Each shard
samples the full global schedule — threefry streams have no prefix
property, so local draws would diverge from the unsharded run — and
slices its columns, making per-graph results bitwise independent of the
sharding.  Once a graph converges its exchanges freeze (writes become
identity, accounting masks to zero), so shards may run different
while-loop trip counts without affecting any output.

`gossip_core` is the pure-JAX function (usable inside a larger jit /
vmap — the plan/execute engine in `core.engine` vmaps it over
Monte-Carlo trial seeds); `gossip_until` is the host-facing wrapper.

Shapes (static under jit):
  x         : (B, C, V)   node values, padded with 0
  adj       : CsrGraphs   start (B,C) / nbr,hops (nnz+1,) / degrees / n_nodes
  node_mask : (B, C)      live-node mask
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .medium import _TAG_RETX, _TAG_STRAGGLER, CostModel, FailureCtx
from .schedule import (
    CsrGraphs,
    compose_schedule,
    dense_to_csr,
    flat_usage_to_dense,
    sample_schedule,
    sample_tick,
)

__all__ = ["GossipResult", "gossip_core", "gossip_until", "batched_graphs",
           "GOSSIP_BACKENDS"]

GOSSIP_BACKENDS = ("lax", "pallas", "matmul")


@dataclasses.dataclass
class GossipResult:
    x: np.ndarray            # (B, C, V) final values
    ticks: np.ndarray        # (B,) exchanges attempted per graph
    converged: np.ndarray    # (B,) bool
    edge_usage: np.ndarray   # (B, C, D) int32: #exchanges initiated i->j
    messages: np.ndarray     # (B,) total single-hop transmissions

    @property
    def total_messages(self) -> int:
        return int(self.messages.sum())

    def estimates(self) -> np.ndarray:
        """(B, C) per-node estimates (ratio of channels if V == 2)."""
        if self.x.shape[-1] == 1:
            return self.x[..., 0]
        # channel 1 is a positive mass (node counts) in the weighted variant
        return self.x[..., 0] / np.maximum(self.x[..., 1], 1e-30)


def _one_tick(state, t, adj, key, loss_p):
    """Legacy tick: sample-and-apply interleaved (the parity reference).
    Sampling is shared with the presampled path (`schedule.sample_tick`)
    so the two stay draw-for-draw identical by construction."""
    x, usage, msgs, done = state
    B = adj.degrees.shape[0]
    bidx = jnp.arange(B)
    s = sample_tick(t, key, adj, loss_p, x.dtype)
    active = (~done) & s.valid
    xi = x[bidx, s.i]
    xj = x[bidx, s.j]
    avg = 0.5 * (xi + xj)
    upd_j = (active & s.fwd_ok)[:, None]           # j updates iff request arrived
    upd_i = (active & s.fwd_ok & s.rep_ok)[:, None]  # i updates iff reply arrived
    x = x.at[bidx, s.j].set(jnp.where(upd_j, avg, xj))
    x = x.at[bidx, s.i].set(jnp.where(upd_i, avg, xi))
    usage = usage.at[s.pos].add(active.astype(jnp.int32))
    msgs = msgs + jnp.where(active, s.cost, 0)
    return (x, usage, msgs, done), None


def gossip_core(
    x0,
    adj: CsrGraphs,
    node_mask,
    eps,
    key,
    *,
    max_ticks: int,
    check_every: int,
    loss_p: Optional[float],
    backend: str = "lax",
    schedule: str = "presampled",
    interpret: bool = False,
    node_shard=None,
    failure_ctx: Optional[FailureCtx] = None,
    cost_model: Optional[CostModel] = None,
    hop_cap: int = 1,
):
    """Pure-JAX batched gossip loop; composable under jit and vmap.

    Returns (x, usage, msgs, done, ticks) where usage is the flat
    ``(nnz+1,)`` per-directed-edge counter aligned with `adj`; with
    `cost_model` set, two extra per-graph arrays are appended —
    (retransmissions, congestion_pairs) — priced from the presampled
    schedule with RNG streams disjoint from the exchange streams, so
    x/usage/msgs/done/ticks are bitwise-independent of the cost model.
    `backend` selects the inner pairwise-average kernel and `schedule`
    the presampled vs legacy per-tick execution (see module docstring);
    the random exchange sequence, usage, and message counts are
    backend- and schedule-independent.  `eps` and `max_ticks` may be
    traced scalars (the plan/execute engine passes them at runtime so
    eps-oracle and fixed-iteration runs share one compilation);
    `check_every` must be static (scan length).

    `failure_ctx` (a `medium.FailureCtx`) perturbs the presampled
    schedule — churned/regional nodes' exchanges vanish (a live
    initiator contacting a down partner wastes the forward leg),
    straggler exchanges fail w.p. 1 - straggler_success at full cost,
    Byzantine slots never apply updates.  This DOES change trajectory
    and accounting (that is the point); requires
    ``schedule="presampled"``.

    `node_shard=(cols, ok)` runs only the given global batch columns:
    `x0`/`node_mask` are the local ``(Bs, C, …)`` slices, sampling stays
    global (see module docstring), and the returned x/msgs/done/ticks
    are local while usage stays global-flat (adds land only at the
    shard's own edges).
    """
    if backend not in GOSSIP_BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if schedule not in ("presampled", "per_tick"):
        raise ValueError(f"unknown schedule mode {schedule!r}")
    if schedule == "per_tick" and backend == "matmul":
        raise ValueError("backend='matmul' requires schedule='presampled'")
    if node_shard is not None and schedule != "presampled":
        raise ValueError("node_shard requires schedule='presampled'")
    if (failure_ctx is not None or cost_model is not None):
        if schedule != "presampled":
            raise ValueError(
                "failure scenarios / cost pricing require "
                "schedule='presampled'")
        if node_shard is not None:
            raise ValueError(
                "failure scenarios / cost pricing are not supported on "
                "the (trials, nodes) mesh")
    live = node_mask.astype(x0.dtype)[..., None]  # (B, C, 1)
    denom = jnp.maximum(live.sum(1), 1.0)
    mean = (x0 * live).sum(1) / denom             # (B, V)
    x0_norm = jnp.sqrt(((x0 * live) ** 2).sum((1, 2)))
    tol = eps * jnp.maximum(x0_norm, 1e-30)

    def err(x):
        d = (x - mean[:, None, :]) * live
        return jnp.sqrt((d**2).sum((1, 2)))

    if schedule == "per_tick":
        chunk = _per_tick_chunk(
            adj, key, loss_p, check_every, backend, interpret, err, tol,
        )
    else:
        chunk = _presampled_chunk(
            adj, key, loss_p, check_every, backend, interpret, err, tol,
            node_shard, failure_ctx, cost_model, hop_cap,
        )

    def cond(carry):
        return (~jnp.all(carry[3])) & (carry[-1] < max_ticks)

    usage0 = jnp.zeros(adj.nbr.shape, jnp.int32)
    msgs0 = jnp.zeros(x0.shape[:1], jnp.int32)
    done0 = err(x0) <= tol  # already-converged graphs (e.g. 1-node cells)
    ticks0 = jnp.zeros(x0.shape[:1], jnp.int32)
    if cost_model is not None:
        # per-graph cost accumulators: sampled extra attempts (int32,
        # exact) and concurrency pair counts (f32: a surcharge tally,
        # not an exact-accounting channel)
        extras = (jnp.zeros(x0.shape[:1], jnp.int32),
                  jnp.zeros(x0.shape[:1], jnp.float32))
    else:
        extras = ()
    carry = (x0, usage0, msgs0, done0, ticks0) + extras \
        + (jnp.array(0, jnp.int32),)
    out = jax.lax.while_loop(cond, chunk, carry)
    return out[:-1]  # drop the tick counter t0


def _presampled_chunk(adj, key, loss_p, check_every, backend, interpret,
                      err, tol, node_shard=None, failure_ctx=None,
                      cost_model=None, hop_cap=1):
    """Chunk body for the schedule/value split: one batched RNG pass for
    the whole chunk, accounting as a single scatter-add + reduction,
    then the value pass over the presampled pair list.

    `failure_ctx` perturbs the schedule before the value pass (scenario
    injection); `cost_model` adds pure reductions over the schedule
    (sampled retransmissions, concurrency pairs) whose RNG streams are
    folded from tags disjoint from every tick index, so the exchange
    draws — and therefore x/usage/msgs — are untouched.
    """
    from repro.kernels.pair_apply import pair_apply, pair_apply_ref

    cost_on = cost_model is not None
    sample_retx = (cost_on and cost_model.sample
                   and cost_model.retransmit_p < 1.0)
    track_cong = cost_on and cost_model.congestion_alpha > 0.0

    def chunk(carry):
        if cost_on:
            x, usage, msgs, done, ticks, retx, congp, t0 = carry
        else:
            x, usage, msgs, done, ticks, t0 = carry
        C = x.shape[1]
        ts = t0 + jnp.arange(check_every)
        s = sample_schedule(ts, key, adj, loss_p, x.dtype)
        if node_shard is not None:
            cols, ok = node_shard
            s = type(s)(*(f[:, cols] for f in s))
            s = s._replace(valid=s.valid & ok[None, :])
        active = s.valid & ~done[None, :]   # done is frozen within a chunk
        if failure_ctx is None:
            attempt = active
            cost_t = s.cost
            upd_j = active & s.fwd_ok
            upd_i = upd_j & s.rep_ok
        else:
            fc = failure_ctx
            bcols = jnp.arange(active.shape[1])[None, :]
            when = ts[:, None]
            churn_now = when >= fc.churn_tick
            reg_now = (when >= fc.reg_t0) & (when < fc.reg_t1)
            down_i = (fc.churned[bcols, s.i] & churn_now) | (
                fc.regional[bcols, s.i] & reg_now)
            down_j = (fc.churned[bcols, s.j] & churn_now) | (
                fc.regional[bcols, s.j] & reg_now)
            attempt = active & ~down_i      # a down initiator never wakes
            delivered = attempt & ~down_j
            slow = fc.straggler[bcols, s.i] | fc.straggler[bcols, s.j]
            if fc.straggler_success < 1.0:
                ku = jax.random.fold_in(
                    jax.random.fold_in(key, _TAG_STRAGGLER), t0)
                u = jax.random.uniform(ku, active.shape)
                delivered = delivered & (
                    ~slow | (u < fc.straggler_success))
            upd_j = delivered & s.fwd_ok & ~fc.byz[bcols, s.j]
            upd_i = delivered & s.fwd_ok & s.rep_ok & ~fc.byz[bcols, s.i]
            # a wasted contact of a down partner still transmits the
            # forward leg; straggler stalls burn the full exchange cost
            cost_t = jnp.where(attempt & ~down_j, s.cost, adj.hops[s.pos])
        usage = usage.at[s.pos].add(attempt.astype(jnp.int32))
        hops_t = jnp.where(attempt, cost_t, 0)
        msgs = msgs + hops_t.sum(0)
        if sample_retx:
            # iid Geometric(p) per single-hop transmission: extra
            # attempts per hop slot, masked to the hops actually sent.
            # The stream is fold_in(key, TAG) -> fold_in(., t0): tagged
            # before the tick fold, disjoint from exchange draws.
            kr = jax.random.fold_in(jax.random.fold_in(key, _TAG_RETX), t0)
            q = 1.0 - cost_model.retransmit_p
            u = jnp.maximum(
                jax.random.uniform(kr, (*hops_t.shape, 2 * hop_cap)), 1e-12)
            g = jnp.floor(jnp.log(u) / jnp.log(q)).astype(jnp.int32)
            m = jnp.arange(2 * hop_cap)[None, None, :] < hops_t[..., None]
            retx = retx + jnp.where(m, g, 0).sum((0, 2))
        if track_cong:
            conc = attempt.sum(1)  # concurrent exchanges at each tick
            congp = congp + (
                attempt * jnp.maximum(conc - 1, 0)[:, None]
            ).sum(0).astype(jnp.float32)
        if backend == "lax":
            x = pair_apply_ref(x, s.i, s.j, upd_i, upd_j)
        elif backend == "pallas":
            # non-TPU hosts take the bitwise-identical oracle; the TPU
            # kernel walks the schedule with the state in VMEM
            x = pair_apply(x, s.i, s.j, upd_i, upd_j,
                           use_pallas=not interpret, interpret=interpret)
        else:  # matmul: associative composition, applied on the MXU
            from repro.kernels.cell_mixing import cell_mixing

            m = compose_schedule(C, s.i, s.j, upd_i, upd_j, x.dtype)
            x = cell_mixing(m, x, rounds=1, use_pallas=not interpret,
                            interpret=interpret)
        ticks = ticks + jnp.where(done, 0, check_every)
        done = done | (err(x) <= tol)
        out = (x, usage, msgs, done, ticks)
        if cost_on:
            out = out + (retx, congp)
        return out + (t0 + check_every,)

    return chunk


def _per_tick_chunk(adj, key, loss_p, check_every, backend, interpret,
                    err, tol):
    """Legacy chunk body: the sequential sample-and-apply scan."""
    B, C = adj.degrees.shape

    def tick(s, t):
        return _one_tick(s, t, adj, key, loss_p)

    # historical pallas branch: the chunk's pair averages accumulate into
    # a mixing matrix (identity + row averages — _one_tick applied to
    # rows of I) applied with the Pallas batched matmul kernel.  The
    # identity seed is built once here, not per while-loop iteration.
    eye = None
    if backend == "pallas":
        eye = jnp.broadcast_to(jnp.eye(C, dtype=jnp.float32), (B, C, C))

    def chunk(carry):
        x, usage, msgs, done, ticks, t0 = carry
        ts = t0 + jnp.arange(check_every)
        if backend == "lax":
            (x, usage, msgs, done), _ = jax.lax.scan(
                tick, (x, usage, msgs, done), ts
            )
        else:
            from repro.kernels.cell_mixing import cell_mixing

            (m, usage, msgs, done), _ = jax.lax.scan(
                tick, (eye.astype(x.dtype), usage, msgs, done), ts
            )
            x = cell_mixing(m, x, rounds=1, use_pallas=True,
                            interpret=interpret)
        ticks = ticks + jnp.where(done, 0, check_every)
        done = done | (err(x) <= tol)
        return (x, usage, msgs, done, ticks, t0 + check_every)

    return chunk


@partial(
    jax.jit,
    static_argnames=("max_ticks", "check_every", "loss_p", "backend",
                     "schedule", "interpret"),
)
def _gossip_loop(
    x0,
    adj,
    node_mask,
    eps,
    key,
    max_ticks: int,
    check_every: int,
    loss_p: Optional[float],
    backend: str = "lax",
    schedule: str = "presampled",
    interpret: bool = False,
):
    return gossip_core(
        x0, adj, node_mask, eps, key,
        max_ticks=max_ticks, check_every=check_every, loss_p=loss_p,
        backend=backend, schedule=schedule, interpret=interpret,
    )


def gossip_until(
    x0: np.ndarray,
    neighbors: np.ndarray,
    degrees: np.ndarray,
    n_nodes: np.ndarray,
    *,
    eps: float,
    seed: int = 0,
    edge_hops: Optional[np.ndarray] = None,
    node_mask: Optional[np.ndarray] = None,
    max_ticks: int = 2_000_000,
    check_every: int = 64,
    fixed_ticks: Optional[int] = None,
    loss_p: Optional[float] = None,
    backend: str = "lax",
    schedule: str = "presampled",
    interpret: bool = False,
) -> GossipResult:
    """Run batched randomized gossip to eps-accuracy (or `fixed_ticks`).

    `fixed_ticks` implements the paper's fixed-iterations variant
    (MultiscaleGossipFI, §VI): exactly that many exchanges per graph, no
    convergence oracle.  Convergence is re-checked every `check_every`
    ticks, so up to that many extra exchanges can occur after the true
    crossing (convergence detection is not free in reality either).
    `backend`/`schedule`/`interpret` select the inner pairwise-average
    kernel and execution mode (see module docstring).

    The host API stays dense — ``(B, C, D)`` padded neighbors in, dense
    `edge_usage` out; the CSR packing is internal.
    """
    x0 = np.asarray(x0)
    if x0.ndim == 2:
        x0 = x0[..., None]
    B, C, V = x0.shape
    D = neighbors.shape[2]
    if node_mask is None:
        node_mask = np.arange(C)[None, :] < np.asarray(n_nodes)[:, None]
    adj_np = dense_to_csr(neighbors, degrees, n_nodes, edge_hops)
    adj = CsrGraphs(*(jnp.asarray(a) for a in adj_np))
    key = jax.random.PRNGKey(seed)
    if fixed_ticks is not None:
        eps_eff = -1.0  # negative tol: the oracle never fires
        check = max(1, min(check_every, int(fixed_ticks)))
        max_t = ((int(fixed_ticks) + check - 1) // check) * check
    else:
        eps_eff, max_t, check = float(eps), int(max_ticks), int(check_every)
    x, usage, msgs, done, ticks = _gossip_loop(
        jnp.asarray(x0, jnp.float32),
        adj,
        jnp.asarray(node_mask, bool),
        jnp.asarray(eps_eff, jnp.float32),
        key,
        max_ticks=max_t,
        check_every=check,
        loss_p=loss_p,
        backend=backend,
        schedule=schedule,
        interpret=interpret,
    )
    return GossipResult(
        x=np.asarray(x),
        ticks=np.asarray(ticks),
        converged=np.asarray(done),
        edge_usage=flat_usage_to_dense(np.asarray(usage), degrees, D),
        messages=np.asarray(msgs),
    )


def batched_graphs(
    graphs: list,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad a list of `rgg.Graph`-like (neighbors, degrees) into batch form.

    Returns (neighbors (B,C,D), degrees (B,C), n_nodes (B,), node_mask).
    """
    B = len(graphs)
    C = max(1, max(g.n for g in graphs))
    D = max(1, max(g.max_deg for g in graphs))
    neighbors = np.full((B, C, D), -1, np.int32)
    degrees = np.zeros((B, C), np.int32)
    n_nodes = np.zeros((B,), np.int32)
    for b, g in enumerate(graphs):
        neighbors[b, : g.n, : g.max_deg] = g.neighbors
        degrees[b, : g.n] = g.degrees
        n_nodes[b] = g.n
    node_mask = np.arange(C)[None, :] < n_nodes[:, None]
    return neighbors, degrees, n_nodes, node_mask
