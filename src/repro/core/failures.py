"""Transmission-failure models (paper §VI-C).

* Handshake model: every single-hop transmission independently succeeds
  w.p. p, and link-level ACK/retransmission repeats it until delivery.
  The algorithm trajectory is UNCHANGED — only the cost inflates: each
  logical transmission costs Geometric(p) physical ones.  Sampled
  exactly as T + NegBinomial(T, p) (sum of T iid geometrics).

* Message-loss model: a transmission failing means the message is gone.
  This changes the trajectory and destroys mass; it is implemented
  inside the gossip engine (`FailureModel(loss_p=...)`) and path
  averaging (`loss_p=`), per §VI-C-2.

There is ONE pricing path: `core.medium.price_messages` /
`CostModel(retransmit_p=...)`, which price per trial and per level
(and, threaded through `execute_plan`, directly on the presampled
schedule with congestion and hop-distance awareness).  `handshake_cost`
survives only as a thin scalar wrapper over it, preserving the
historical API (same validation, same seeded draws) for old callers.
"""
from __future__ import annotations

import numpy as np

__all__ = ["handshake_cost"]


def handshake_cost(
    transmissions: int, p: float, rng: np.random.Generator | None = None
) -> int:
    """Physical transmissions needed to deliver `transmissions` messages
    when each attempt succeeds w.p. p with retransmission until success.

    Thin wrapper over `core.medium.price_messages` (the single pricing
    path): the handshake total ``T + NegBinomial(T, p)`` is exactly its
    ``physical_transmissions``.  Bitwise-compatible with the historical
    scalar implementation — identical validation message, identical
    draws for a given rng (one NegBinomial(T, p) variate), and the
    historical fixed-seed default ``default_rng(0)`` when no rng is
    passed (`price_messages` itself refuses a hidden default; the
    legacy scalar API keeps it for reproducibility of old scripts).
    """
    from .medium import CostModel, price_messages

    if not 0.0 < p <= 1.0:
        raise ValueError(f"success probability must be in (0, 1], got {p}")
    if p == 1.0 or transmissions == 0:
        return int(transmissions)
    cost = price_messages(
        int(transmissions),
        CostModel(retransmit_p=p),
        rng=rng or np.random.default_rng(0),
    )
    return int(cost.physical_transmissions[0])
