"""Transmission-failure models (paper §VI-C).

* Handshake model: every single-hop transmission independently succeeds
  w.p. p, and link-level ACK/retransmission repeats it until delivery.
  The algorithm trajectory is UNCHANGED — only the cost inflates: each
  logical transmission costs Geometric(p) physical ones.  Sampled
  exactly as T + NegBinomial(T, p) (sum of T iid geometrics).

* Message-loss model: a transmission failing means the message is gone.
  This changes the trajectory and destroys mass; it is implemented
  inside the gossip engine (`FailureModel(loss_p=...)`) and path
  averaging (`loss_p=`), per §VI-C-2.

.. deprecated::
   `handshake_cost` is superseded by `core.medium.price_messages` /
   `CostModel(retransmit_p=...)`, which price per trial and per level
   (and, threaded through `execute_plan`, directly on the presampled
   schedule with congestion and hop-distance awareness).  It is kept
   for the historical scalar API.
"""
from __future__ import annotations

import numpy as np

__all__ = ["handshake_cost"]


def handshake_cost(
    transmissions: int, p: float, rng: np.random.Generator | None = None
) -> int:
    """Physical transmissions needed to deliver `transmissions` messages
    when each attempt succeeds w.p. p with retransmission until success."""
    if not 0.0 < p <= 1.0:
        raise ValueError(f"success probability must be in (0, 1], got {p}")
    if p == 1.0 or transmissions == 0:
        return int(transmissions)
    rng = rng or np.random.default_rng(0)
    return int(transmissions) + int(rng.negative_binomial(transmissions, p))
