"""Presampled exchange schedules for randomized gossip.

Every exchange decision in the asynchronous gossip model — which node
wakes, which neighbor it draws, whether each hop of the request/reply
survives, what the exchange costs — depends only on ``(key, t)``, never
on the node values; only the pair-average recursion itself is
sequential.  (Boyd et al. [2] and the paper's §VII fixed-iterations
analysis both treat the exchange sequence as an i.i.d. schedule for
exactly this reason.)  This module exploits that split:

* `sample_tick` is the sampling half of one legacy gossip tick — the
  exact ops, in the exact order, of the historical per-tick scan body,
  so its draws are bitwise-reproducible against the legacy path;
* `sample_schedule` vmaps it over a whole `check_every` chunk of tick
  indices: one batched RNG pass produces the full ``(T, B)`` schedule
  (waking node, neighbor slot, partner, per-hop loss outcomes, hop
  cost) at once.  `jax.vmap` does not change threefry's per-key
  streams, so the presampled schedule is bit-identical to T sequential
  `sample_tick` calls;
* `compose_schedule` turns a presampled pair list into the chunk's
  ``(B, C, C)`` mixing matrix with a log2(T) tree of batched matmuls
  (MXU-friendly), replacing the historical eye-rebuild-then-scan.
  Matrix composition reassociates the f32 sums, so values produced
  through it agree with the sequential recursion only up to f32
  rounding — integer accounting (usage, cost) is schedule-only and
  stays exact.

Adjacency is CSR (`CsrGraphs`): the ``(B, C, D)`` dense padded arrays
of the historical path wasted O(B*C*D) memory on the degree spread; the
flat layout stores one entry per directed edge (plus a single trailing
sentinel so edgeless batches stay well-formed) and lets usage counters
live in a flat ``(nnz+1,)`` buffer.  A sampled tick carries `pos`, the
flat index of the drawn edge, so accounting is a 1-D scatter-add.

The value half — applying the presampled pair list to ``(B, C, V)``
cell state — lives in `repro.kernels.pair_apply` (jnp oracle + Pallas
TPU kernel that streams the schedule through SMEM in cell blocks).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CsrGraphs",
    "ExchangeSchedule",
    "dense_to_csr",
    "flat_usage_to_dense",
    "sample_tick",
    "sample_schedule",
    "compose_schedule",
]


class CsrGraphs(NamedTuple):
    """CSR adjacency for a batch of B padded graphs.

    Rows are the ``B*C`` (graph, slot) pairs in row-major order; row
    ``(b, c)`` owns flat entries ``start[b, c] : start[b, c] +
    degrees[b, c]``.  One trailing sentinel entry (``nbr=0, hops=1``)
    keeps the flat arrays non-empty and gives empty rows an in-bounds
    gather target — a draw against a zero-degree row is already marked
    invalid by the schedule, so the garbage neighbor is never applied.
    """

    start: jax.Array    # (B, C) int32 flat offset of each row
    nbr: jax.Array      # (nnz+1,) int32 neighbor slot within the graph
    hops: jax.Array     # (nnz+1,) int32 per-edge routing hops
    degrees: jax.Array  # (B, C) int32
    n_nodes: jax.Array  # (B,) int32


def dense_to_csr(neighbors, degrees, n_nodes, edge_hops=None) -> CsrGraphs:
    """Pack ``(B, C, D)`` padded adjacency into a host-side `CsrGraphs`.

    Entry order within a row is the dense row order (slots < degree), so
    a jidx drawn uniformly in [0, deg) addresses the same neighbor in
    both layouts — the CSR schedule is draw-for-draw identical to the
    dense one.
    """
    neighbors = np.asarray(neighbors)
    degrees = np.asarray(degrees, np.int32)
    B, C, D = neighbors.shape
    if edge_hops is None:
        edge_hops = np.ones((B, C, D), np.int32)
    keep = np.arange(D)[None, None, :] < degrees[:, :, None]
    cs = np.concatenate([[0], np.cumsum(degrees.ravel(), dtype=np.int64)])
    start = cs[:-1].reshape(B, C).astype(np.int32)
    nbr = np.concatenate([neighbors[keep].astype(np.int32), [0]])
    hops = np.concatenate([np.asarray(edge_hops)[keep].astype(np.int32), [1]])
    return CsrGraphs(
        start=start, nbr=nbr, hops=hops, degrees=degrees,
        n_nodes=np.asarray(n_nodes, np.int32),
    )


def flat_usage_to_dense(usage, degrees, D=None) -> np.ndarray:
    """Scatter flat ``(nnz+1,)`` usage counters back to ``(B, C, D)``.

    The host-side inverse of the CSR layout; padding slots get 0, the
    sentinel entry is dropped.
    """
    usage = np.asarray(usage)
    degrees = np.asarray(degrees, np.int64)
    B, C = degrees.shape
    if D is None:
        D = max(1, int(degrees.max(initial=0)))
    nnz = int(degrees.sum())
    deg_flat = degrees.ravel()
    row = np.repeat(np.arange(B * C), deg_flat)
    col = np.arange(nnz) - np.repeat(
        np.concatenate([[0], np.cumsum(deg_flat)])[:-1], deg_flat
    )
    out = np.zeros((B * C, D), usage.dtype)
    out[row, col] = usage[:nnz]
    return out.reshape(B, C, D)


class ExchangeSchedule(NamedTuple):
    """Value-independent draws for a block of gossip ticks.

    Leading axis is the tick index within the chunk (absent for a
    single `sample_tick`); all fields are per-graph ``(…, B)``.
    `valid` excludes the per-chunk `done` freeze, which is the caller's
    to apply (it is constant within a chunk): ``active = valid & ~done``.
    """

    i: jax.Array       # waking node
    jidx: jax.Array    # neighbor slot drawn at i
    j: jax.Array       # contacted node (garbage when not `valid`)
    valid: jax.Array   # bool: i has neighbors
    fwd_ok: jax.Array  # bool: request delivered over every hop
    rep_ok: jax.Array  # bool: reply delivered over every hop
    cost: jax.Array    # int32 single-hop transmissions if the tick is active
    pos: jax.Array     # int32 flat CSR index of the drawn directed edge


def truncated_failure_hops(u, p, h):
    """Hops transmitted for a message over h hops with per-hop success p.

    Successes before first failure: S = floor(log u / log p); delivered
    iff S >= h (transmits h); else transmits S + 1.  Returns
    (delivered, hops_transmitted).
    """
    s = jnp.where(p < 1.0, jnp.floor(jnp.log(u) / jnp.log(jnp.maximum(p, 1e-12))), jnp.inf)
    delivered = s >= h
    return delivered, jnp.where(delivered, h, s + 1.0).astype(jnp.int32)


def sample_tick(
    t,
    key,
    adj: CsrGraphs,
    loss_p: Optional[float],
    dtype=jnp.float32,
) -> ExchangeSchedule:
    """Draw one tick's exchange decisions for all B graphs.

    This is the sampling half of the legacy per-tick scan body — ops
    and RNG consumption order are kept identical so the presampled and
    per-tick paths are bitwise-interchangeable.  Draws are over the
    global batch: a node-sharded caller samples the full ``(B,)``
    schedule and slices its columns, which keeps every shard's draws
    bit-identical to the unsharded run (threefry streams have no prefix
    property, so sampling only local columns would diverge).
    """
    B, C = adj.degrees.shape
    bidx = jnp.arange(B)
    kt = jax.random.fold_in(key, t)
    ki, kj, kf, kr = jax.random.split(kt, 4)
    # pick a waking node per graph (uniform over live nodes)
    u = jax.random.uniform(ki, (B,))
    i = jnp.minimum((u * adj.n_nodes).astype(jnp.int32), adj.n_nodes - 1)
    deg_i = jnp.take_along_axis(adj.degrees, i[:, None], axis=1)[:, 0]
    v = jax.random.uniform(kj, (B,))
    jidx = jnp.minimum((v * deg_i).astype(jnp.int32), jnp.maximum(deg_i - 1, 0))
    pos = adj.start[bidx, i] + jidx
    j = adj.nbr[pos]
    valid = deg_i > 0  # compact rows: deg>0 iff the slot holds a real edge
    hops = adj.hops[pos]

    if loss_p is None:
        fwd_ok = jnp.ones((B,), bool)
        rep_ok = jnp.ones((B,), bool)
        cost = 2 * hops
    else:
        p = jnp.asarray(loss_p, dtype)
        fwd_ok, fwd_hops = truncated_failure_hops(
            jax.random.uniform(kf, (B,)), p, hops
        )
        rep_ok, rep_hops = truncated_failure_hops(
            jax.random.uniform(kr, (B,)), p, hops
        )
        cost = fwd_hops + jnp.where(fwd_ok, rep_hops, 0)
    return ExchangeSchedule(
        i=i, jidx=jidx, j=j, valid=valid,
        fwd_ok=fwd_ok, rep_ok=rep_ok, cost=cost, pos=pos,
    )


def sample_schedule(
    ts,
    key,
    adj: CsrGraphs,
    loss_p: Optional[float],
    dtype=jnp.float32,
) -> ExchangeSchedule:
    """Presample a whole chunk: one batched RNG pass over tick indices
    `ts` producing an `ExchangeSchedule` with leading axis len(ts)."""

    def one(t):
        return sample_tick(t, key, adj, loss_p, dtype)

    return jax.vmap(one)(ts)


def compose_schedule(num_slots: int, i, j, upd_i, upd_j, dtype=jnp.float32):
    """Compose a presampled pair list into one (B, C, C) mixing matrix.

    Tick t's elementary matrix E_t is the identity with rows i_t / j_t
    replaced by the pair average 0.5 (e_i + e_j) where the respective
    update fires (the same conditional row updates the per-tick scan
    applies to x).  The chunk matrix E_T @ … @ E_1 is folded with a
    log2(T) tree of batched matmuls — each round one (T/2, B, C, C)
    batched GEMM, MXU work instead of T sequential row scatters.

    Memory: materializes (T, B, C, C); intended for the small per-cell
    matrices of the simulation hierarchy (C up to a few dozen).
    """
    T, B = i.shape
    C = num_slots
    eye = jnp.eye(C, dtype=dtype)
    e_i = eye[i]                       # (T, B, C) one-hot rows
    e_j = eye[j]
    avg = 0.5 * (e_i + e_j)
    rows_i = jnp.where(upd_i[..., None], avg, e_i)
    rows_j = jnp.where(upd_j[..., None], avg, e_j)
    tidx = jnp.arange(T)[:, None]
    bidx = jnp.arange(B)[None, :]
    E = jnp.broadcast_to(eye, (T, B, C, C))
    # same write order as the scan: partner row, then initiator row
    E = E.at[tidx, bidx, j].set(rows_j)
    E = E.at[tidx, bidx, i].set(rows_i)
    P = 1 << max(T - 1, 0).bit_length()
    if P != T:
        E = jnp.concatenate([E, jnp.broadcast_to(eye, (P - T, B, C, C))], 0)
    while E.shape[0] > 1:
        # fold adjacent pairs: later-tick matrix multiplies from the left
        E = jnp.einsum("tbij,tbjk->tbik", E[1::2], E[0::2])
    return E[0]
