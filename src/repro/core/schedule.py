"""Presampled exchange schedules for randomized gossip.

Every exchange decision in the asynchronous gossip model — which node
wakes, which neighbor it draws, whether each hop of the request/reply
survives, what the exchange costs — depends only on ``(key, t)``, never
on the node values; only the pair-average recursion itself is
sequential.  (Boyd et al. [2] and the paper's §VII fixed-iterations
analysis both treat the exchange sequence as an i.i.d. schedule for
exactly this reason.)  This module exploits that split:

* `sample_tick` is the sampling half of one legacy gossip tick — the
  exact ops, in the exact order, of the historical per-tick scan body,
  so its draws are bitwise-reproducible against the legacy path;
* `sample_schedule` vmaps it over a whole `check_every` chunk of tick
  indices: one batched RNG pass produces the full ``(T, B)`` schedule
  (waking node, neighbor slot, partner, per-hop loss outcomes, hop
  cost) at once.  `jax.vmap` does not change threefry's per-key
  streams, so the presampled schedule is bit-identical to T sequential
  `sample_tick` calls;
* `compose_schedule` turns a presampled pair list into the chunk's
  ``(B, C, C)`` mixing matrix with a log2(T) tree of batched matmuls
  (MXU-friendly), replacing the historical eye-rebuild-then-scan.
  Matrix composition reassociates the f32 sums, so values produced
  through it agree with the sequential recursion only up to f32
  rounding — integer accounting (usage, cost) is schedule-only and
  stays exact.

The value half — applying the presampled pair list to ``(B, C, V)``
cell state — lives in `repro.kernels.pair_apply` (jnp oracle + Pallas
TPU kernel that walks the schedule in VMEM).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "ExchangeSchedule",
    "sample_tick",
    "sample_schedule",
    "compose_schedule",
]


class ExchangeSchedule(NamedTuple):
    """Value-independent draws for a block of gossip ticks.

    Leading axis is the tick index within the chunk (absent for a
    single `sample_tick`); all fields are per-graph ``(…, B)``.
    `valid` excludes the per-chunk `done` freeze, which is the caller's
    to apply (it is constant within a chunk): ``active = valid & ~done``.
    """

    i: jax.Array       # waking node
    jidx: jax.Array    # neighbor slot drawn at i
    j: jax.Array       # contacted node, clipped to >= 0 (see `valid`)
    valid: jax.Array   # bool: i has neighbors and the slot is real
    fwd_ok: jax.Array  # bool: request delivered over every hop
    rep_ok: jax.Array  # bool: reply delivered over every hop
    cost: jax.Array    # int32 single-hop transmissions if the tick is active


def truncated_failure_hops(u, p, h):
    """Hops transmitted for a message over h hops with per-hop success p.

    Successes before first failure: S = floor(log u / log p); delivered
    iff S >= h (transmits h); else transmits S + 1.  Returns
    (delivered, hops_transmitted).
    """
    s = jnp.where(p < 1.0, jnp.floor(jnp.log(u) / jnp.log(jnp.maximum(p, 1e-12))), jnp.inf)
    delivered = s >= h
    return delivered, jnp.where(delivered, h, s + 1.0).astype(jnp.int32)


def sample_tick(
    t,
    key,
    neighbors,
    degrees,
    n_nodes,
    edge_hops,
    loss_p: Optional[float],
    dtype=jnp.float32,
) -> ExchangeSchedule:
    """Draw one tick's exchange decisions for all B graphs.

    This is the sampling half of the legacy per-tick scan body — ops
    and RNG consumption order are kept identical so the presampled and
    per-tick paths are bitwise-interchangeable.
    """
    B, C, D = neighbors.shape
    bidx = jnp.arange(B)
    kt = jax.random.fold_in(key, t)
    ki, kj, kf, kr = jax.random.split(kt, 4)
    # pick a waking node per graph (uniform over live nodes)
    u = jax.random.uniform(ki, (B,))
    i = jnp.minimum((u * n_nodes).astype(jnp.int32), n_nodes - 1)
    deg_i = jnp.take_along_axis(degrees, i[:, None], axis=1)[:, 0]
    v = jax.random.uniform(kj, (B,))
    jidx = jnp.minimum((v * deg_i).astype(jnp.int32), jnp.maximum(deg_i - 1, 0))
    j = neighbors[bidx, i, jidx]
    valid = (deg_i > 0) & (j >= 0)
    hops = edge_hops[bidx, i, jidx]

    if loss_p is None:
        fwd_ok = jnp.ones((B,), bool)
        rep_ok = jnp.ones((B,), bool)
        cost = 2 * hops
    else:
        p = jnp.asarray(loss_p, dtype)
        fwd_ok, fwd_hops = truncated_failure_hops(
            jax.random.uniform(kf, (B,)), p, hops
        )
        rep_ok, rep_hops = truncated_failure_hops(
            jax.random.uniform(kr, (B,)), p, hops
        )
        cost = fwd_hops + jnp.where(fwd_ok, rep_hops, 0)
    return ExchangeSchedule(
        i=i, jidx=jidx, j=jnp.maximum(j, 0), valid=valid,
        fwd_ok=fwd_ok, rep_ok=rep_ok, cost=cost,
    )


def sample_schedule(
    ts,
    key,
    neighbors,
    degrees,
    n_nodes,
    edge_hops,
    loss_p: Optional[float],
    dtype=jnp.float32,
) -> ExchangeSchedule:
    """Presample a whole chunk: one batched RNG pass over tick indices
    `ts` producing an `ExchangeSchedule` with leading axis len(ts)."""

    def one(t):
        return sample_tick(
            t, key, neighbors, degrees, n_nodes, edge_hops, loss_p, dtype
        )

    return jax.vmap(one)(ts)


def compose_schedule(num_slots: int, i, j, upd_i, upd_j, dtype=jnp.float32):
    """Compose a presampled pair list into one (B, C, C) mixing matrix.

    Tick t's elementary matrix E_t is the identity with rows i_t / j_t
    replaced by the pair average 0.5 (e_i + e_j) where the respective
    update fires (the same conditional row updates the per-tick scan
    applies to x).  The chunk matrix E_T @ … @ E_1 is folded with a
    log2(T) tree of batched matmuls — each round one (T/2, B, C, C)
    batched GEMM, MXU work instead of T sequential row scatters.

    Memory: materializes (T, B, C, C); intended for the small per-cell
    matrices of the simulation hierarchy (C up to a few dozen).
    """
    T, B = i.shape
    C = num_slots
    eye = jnp.eye(C, dtype=dtype)
    e_i = eye[i]                       # (T, B, C) one-hot rows
    e_j = eye[j]
    avg = 0.5 * (e_i + e_j)
    rows_i = jnp.where(upd_i[..., None], avg, e_i)
    rows_j = jnp.where(upd_j[..., None], avg, e_j)
    tidx = jnp.arange(T)[:, None]
    bidx = jnp.arange(B)[None, :]
    E = jnp.broadcast_to(eye, (T, B, C, C))
    # same write order as the scan: partner row, then initiator row
    E = E.at[tidx, bidx, j].set(rows_j)
    E = E.at[tidx, bidx, i].set(rows_i)
    P = 1 << max(T - 1, 0).bit_length()
    if P != T:
        E = jnp.concatenate([E, jnp.broadcast_to(eye, (P - T, B, C, C))], 0)
    while E.shape[0] > 1:
        # fold adjacent pairs: later-tick matrix multiplies from the left
        E = jnp.einsum("tbij,tbjk->tbik", E[1::2], E[0::2])
    return E[0]
