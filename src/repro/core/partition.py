"""Recursive multiscale partition of the unit square (paper §III, §V).

Convention follows the paper: level 1 is the TOP (one cell = the unit
square); level k is the FINEST.  A cell holding q nodes (in expectation)
is split into q^(1-a) subcells, i.e. q^((1-a)/2) per side, with the
subdivision constant a = 2/3 justified in §V-C.  Because every cell at a
level has equal area, the level-j partition is a regular S_j x S_j grid,
with S_1 = 1 and S_{j+1} = S_j * split_j.

Auto-k (paper Thm 1 part 2): choose the smallest k such that the finest
cells hold between m and M nodes, n^((2/3)^(k-1)) <= M, giving
k = Theta(log log n).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = ["Partition", "build_partition", "auto_levels"]

DEFAULT_A = 2.0 / 3.0


def auto_levels(n: int, a: float = DEFAULT_A, cell_max: float = 8.0) -> int:
    """Smallest k with n^(a^(k-1)) <= cell_max  (=> k = Theta(log log n))."""
    if n <= cell_max:
        return 1
    # a^(k-1) * ln n <= ln cell_max
    k = 1 + math.ceil(
        math.log(math.log(cell_max) / math.log(n)) / math.log(a)
    )
    return max(2, k)


@dataclasses.dataclass(frozen=True)
class Partition:
    """Multiscale grid partition. sides[j-1] = S_j for level j in 1..k."""

    n: int
    a: float
    sides: tuple[int, ...]

    @property
    def k(self) -> int:
        return len(self.sides)

    def num_cells(self, level: int) -> int:
        return self.sides[level - 1] ** 2

    def expected_cell_size(self, level: int) -> float:
        return self.n / self.num_cells(level)

    def cell_of(self, coords: np.ndarray, level: int) -> np.ndarray:
        """Flat cell index (row-major) of each coordinate at `level`."""
        s = self.sides[level - 1]
        ij = np.minimum((coords * s).astype(np.int64), s - 1)
        return (ij[:, 1] * s + ij[:, 0]).astype(np.int64)

    def cell_center(self, level: int, cell: np.ndarray) -> np.ndarray:
        """(len(cell), 2) centers of flat cell indices at `level`."""
        s = self.sides[level - 1]
        cell = np.asarray(cell, np.int64)
        row, col = cell // s, cell % s
        return np.stack([(col + 0.5) / s, (row + 0.5) / s], axis=1)

    def parent_cell(self, level: int, cell: np.ndarray) -> np.ndarray:
        """Flat index at `level - 1` of the parent of each cell at `level`."""
        s_child = self.sides[level - 1]
        s_par = self.sides[level - 2]
        f = s_child // s_par
        cell = np.asarray(cell, np.int64)
        row, col = cell // s_child, cell % s_child
        return (row // f) * s_par + (col // f)

    def child_grid_edges(self, parent_level: int) -> np.ndarray:
        """Overlay-grid edges between level-(parent_level+1) cells.

        Two child cells share an edge iff they are N/S/E/W adjacent AND
        belong to the same parent cell (paper §III).  Returns an (m, 2)
        array of flat child-cell indices.
        """
        child_level = parent_level + 1
        s = self.sides[child_level - 1]
        f = s // self.sides[parent_level - 1]
        idx = np.arange(s * s, dtype=np.int64).reshape(s, s)
        edges = []
        # horizontal neighbors, excluding pairs straddling a parent boundary
        left, right = idx[:, :-1], idx[:, 1:]
        cols = np.arange(s - 1)
        same_parent = ((cols + 1) % f) != 0
        edges.append(
            np.stack([left[:, same_parent].ravel(), right[:, same_parent].ravel()], 1)
        )
        up, down = idx[:-1, :], idx[1:, :]
        rows = np.arange(s - 1)
        same_parent = ((rows + 1) % f) != 0
        edges.append(
            np.stack([up[same_parent, :].ravel(), down[same_parent, :].ravel()], 1)
        )
        return np.concatenate(edges).astype(np.int64)


def build_partition(
    n: int,
    k: Optional[int] = None,
    a: float = DEFAULT_A,
    cell_max: float = 8.0,
) -> Partition:
    """Construct the multiscale partition for an n-node deployment.

    With k=None the number of levels is chosen automatically per Thm 1
    part 2.  With k=2 and a=1/2 this yields the paper's two-level variant
    (§VI-B): n^(1/4) x n^(1/4) cells of ~sqrt(n) nodes each.
    """
    if k is None:
        k = auto_levels(n, a, cell_max)
    sides = [1]
    for _ in range(2, k + 1):
        q = n / sides[-1] ** 2  # expected nodes per cell at current level
        split = max(2, round(q ** ((1.0 - a) / 2.0)))
        sides.append(sides[-1] * split)
    return Partition(n=n, a=a, sides=tuple(sides))
