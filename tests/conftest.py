"""Shared test configuration.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
smoke tests and benchmarks must see the real single CPU device.  The
multi-device distribution tests spawn subprocesses that set
XLA_FLAGS=--xla_force_host_platform_device_count=<N> before importing
jax (see tests/test_dist_multidevice.py).
"""
import numpy as np
import pytest

# Optional-hypothesis policy lives in one place: tests/hypothesis_compat.py
# (offline container -> property tests skip, everything else runs).
from hypothesis_compat import HAVE_HYPOTHESIS, HealthCheck, settings

if HAVE_HYPOTHESIS:
    # Single-core CPU container: keep property tests small and undeadlined.
    settings.register_profile(
        "ci",
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")


# ---- test tiering (markers registered in pyproject.toml) ----
# `slow`: the multi-device subprocess tests (each spawns a fresh
# interpreter with 8 emulated devices) and the vmap-/backend-parity
# tests that re-run the simulation engine several times.  Everything
# else is `tier1`.  tools/ci.sh runs `-m "not slow"`; the CI workflow's
# second job runs `-m slow`; a bare pytest invocation runs both tiers.
SLOW_FILES = {"test_dist_multidevice.py"}
SLOW_TESTS = {
    "test_trials_vmap_matches_sequential",
    "test_pallas_backend_matches_lax",
    "test_engine_matmul_backend",
    "test_engine_single_device_mesh_matches_unsharded",
    "test_plan_methods_execute_identically",
}


def pytest_collection_modifyitems(config, items):
    for item in items:
        base = item.name.split("[")[0]
        if item.path.name in SLOW_FILES or base in SLOW_TESTS:
            item.add_marker(pytest.mark.slow)
        else:
            item.add_marker(pytest.mark.tier1)


@pytest.fixture(scope="session")
def rgg500():
    from repro.core import random_geometric_graph

    return random_geometric_graph(500, seed=7)


@pytest.fixture(scope="session")
def x0_500():
    return np.random.default_rng(3).normal(0.0, 1.0, 500)
