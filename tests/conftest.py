"""Shared test configuration.

NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
smoke tests and benchmarks must see the real single CPU device.  The
multi-device distribution tests spawn subprocesses that set
XLA_FLAGS=--xla_force_host_platform_device_count=<N> before importing
jax (see tests/test_dist_multidevice.py).
"""
import numpy as np
import pytest

# Optional-hypothesis policy lives in one place: tests/hypothesis_compat.py
# (offline container -> property tests skip, everything else runs).
from hypothesis_compat import HAVE_HYPOTHESIS, HealthCheck, settings

if HAVE_HYPOTHESIS:
    # Single-core CPU container: keep property tests small and undeadlined.
    settings.register_profile(
        "ci",
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def rgg500():
    from repro.core import random_geometric_graph

    return random_geometric_graph(500, seed=7)


@pytest.fixture(scope="session")
def x0_500():
    return np.random.default_rng(3).normal(0.0, 1.0, 500)
