"""Cross-matrix cell construction (abstract, no compile) + serving
engine + synchronous multiscale integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, reduce_config
from repro.configs.registry import cell_is_runnable


@pytest.fixture(scope="module")
def tiny_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_cell_builds_abstractly(arch_id, shape_name, tiny_mesh):
    """Every (arch x shape) cell's step fn, abstract args, and sharding
    trees must construct without allocation (the dry-run's front half).
    """
    from repro.launch.specs import build_cell

    cfg = get_config(arch_id)
    runnable, reason = cell_is_runnable(cfg, shape_name)
    if not runnable:
        assert "quadratic" in reason
        pytest.skip(reason)
    cell = build_cell(cfg, shape_name, tiny_mesh)
    assert cell.mode == SHAPES[shape_name][2]
    # abstract args and shardings are structurally aligned
    flat_a = jax.tree.leaves(cell.args_abs)
    flat_s = jax.tree.leaves(
        cell.in_shardings, is_leaf=lambda x: hasattr(x, "spec")
    )
    assert len(flat_a) == len(flat_s)
    assert all(isinstance(a, jax.ShapeDtypeStruct) for a in flat_a)
    assert cell.meta["num_params"] > 0


def test_skip_matrix_matches_assignment():
    """long_500k runs exactly for the SSM + hybrid archs."""
    runnable = {
        a: cell_is_runnable(get_config(a), "long_500k")[0] for a in ARCH_IDS
    }
    assert runnable == {
        "whisper-tiny": False,
        "recurrentgemma-9b": True,
        "yi-6b": False,
        "gemma-7b": False,
        "gemma2-27b": False,
        "llama3.2-3b": False,
        "llama4-maverick-400b-a17b": False,
        "grok-1-314b": False,
        "qwen2-vl-72b": False,
        "rwkv6-3b": True,
    }


def test_generator_batched_greedy_and_sampled():
    from repro.models import Transformer
    from repro.serve import Generator

    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(2, cfg.vocab_size, (3, 4)).astype(np.int32)
    gen = Generator(cfg, params, max_len=32, temperature=0.0, eos_id=-1)
    out = gen.generate(prompts, steps=6, seed=0)
    assert out.shape == (3, 6)
    # greedy generation is deterministic
    out2 = gen.generate(prompts, steps=6, seed=99)
    np.testing.assert_array_equal(out, out2)
    gen_t = Generator(cfg, params, max_len=32, temperature=1.0, eos_id=-1)
    out3 = gen_t.generate(prompts, steps=6, seed=0)
    assert out3.shape == (3, 6)


def test_synchronous_multiscale_matches_async_accuracy(rgg500, x0_500):
    from repro.core import multiscale_gossip, synchronous_multiscale

    sync = synchronous_multiscale(rgg500, x0_500, eps=1e-4)
    assert sync.error(np.asarray(x0_500)[:, None]) <= 2e-3
    # vector payloads (gradient prototyping)
    xv = np.random.default_rng(0).normal(0, 1, (500, 16))
    res = synchronous_multiscale(rgg500, xv, eps=1e-4)
    assert res.error(xv) <= 2e-3
    assert res.messages > 0


def test_loss_chunking_invariance():
    """loss_fn must not depend on the chunk size."""
    from repro.models import Transformer, loss_fn

    cfg = reduce_config(get_config("yi-6b"))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32),
    }
    l1 = float(loss_fn(params, cfg, batch, loss_chunk=4))
    l2 = float(loss_fn(params, cfg, batch, loss_chunk=24))
    l3 = float(loss_fn(params, cfg, batch, loss_chunk=7))  # uneven => pad
    np.testing.assert_allclose(l1, l2, rtol=2e-5)
    np.testing.assert_allclose(l1, l3, rtol=2e-5)


def test_moe_token_chunking_invariance():
    """moe_ffn output must not depend on token_chunk (same routing)."""
    import dataclasses

    from repro.models.layers import init_tree
    from repro.models.moe import moe_ffn, moe_params

    cfg = dataclasses.replace(
        reduce_config(get_config("grok-1-314b")), dtype="float32",
        moe_capacity_factor=8.0,  # no drops => chunking-invariant
    )
    params = init_tree(moe_params(cfg, 1), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(1).normal(0, 1, (2, 8, cfg.d_model)), jnp.float32
    )
    full = moe_ffn(params, cfg, x, dp=None, token_chunk=16)
    chunked = moe_ffn(params, cfg, x, dp=None, token_chunk=8)
    np.testing.assert_allclose(
        np.asarray(full), np.asarray(chunked), rtol=2e-4, atol=2e-5
    )
