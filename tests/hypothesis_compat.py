"""Optional-hypothesis shim for the property-test files.

This container has no network access, so `hypothesis` may be absent.
Import `given` / `settings` / `st` from here instead of from
hypothesis: with hypothesis installed these are the real objects; when
it is missing, the shim's `given` replaces the property test with a
cleanly-skipped placeholder (zero-arg, so pytest never tries to resolve
the strategy parameters as fixtures) and the rest of the suite runs.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False
    HealthCheck = None

    class _AnyStrategy:
        """Accepts any `st.<name>(...)` call; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():  # pragma: no cover - placeholder body
                pass

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn


strategies = st  # both `import st` and `import strategies as st` work
