"""Async (one-step-delayed) gradient sync: delay equivalence, EF
conservation under overlap, warmup semantics, state plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data import SyntheticLM
from repro.dist import (
    CompressionConfig, SyncConfig, async_execute_sync, build_sync_plan,
    execute_sync, init_inflight, init_residual,
)
from repro.models import Transformer
from repro.optim import adamw, apply_updates, sgdm
from repro.train import init_decentralized_state, make_decentralized_step

R = 8


def _const_grads(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(R, 5, 3)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(R, 7)), jnp.float32),
    }


def _params0():
    return {
        "a": jnp.ones((R, 5, 3), jnp.float32),
        "b": jnp.full((R, 7), -0.5, jnp.float32),
    }


def _apply(opt, params, opt_state, grads, lr):
    upd, new_opt = jax.vmap(
        lambda g, o, p: opt.update(g, o, p, lr)
    )(grads, opt_state, params)
    return apply_updates(params, upd), new_opt


@pytest.mark.parametrize("cfg,opt_name", [
    (SyncConfig("multiscale", exact_fusion=True, overlap="one_step"), "sgdm"),
    (SyncConfig("multiscale", rotation_period=3, rotation_seed=7,
                overlap="one_step"), "sgdm"),
    (SyncConfig("ring", rounds=(8,), overlap="one_step"), "adamw"),
    (SyncConfig("allreduce", overlap="one_step"), "adamw"),
])
def test_one_step_delay_matches_serialized_constant_stream(cfg, opt_name):
    """The equivalence contract: on a constant gradient stream the
    overlapped trajectory is EXACTLY the serialized trajectory delayed
    by one step — same mixing (rotation index t-1 for step-t's delayed
    grads), same learning rate, bitwise-equal iterates post-warmup."""
    plan = build_sync_plan(cfg, R)
    assert plan.overlapped
    opt = {"sgdm": sgdm(), "adamw": adamw()}[opt_name]
    G, T, lr = _const_grads(), 5, 0.1

    # serialized reference
    p_s = _params0()
    o_s = jax.vmap(opt.init)(p_s)
    traj = []
    for t in range(T):
        mixed, _ = execute_sync(plan, G, None, t)
        p_s, o_s = _apply(opt, p_s, o_s, mixed, lr)
        traj.append(jax.tree.map(np.asarray, p_s))

    # overlapped: step 0 is warmup (zero buffer in flight, update
    # discarded); step t >= 1 applies the step-(t-1) mix
    p_o = _params0()
    o_o = jax.vmap(opt.init)(p_o)
    inflight = init_inflight(G)
    for t in range(T + 1):
        applied, inflight, _ = async_execute_sync(plan, G, inflight, None, t)
        p_new, o_new = _apply(opt, p_o, o_o, applied, lr)
        if t > 0:  # the warmup step discards its (zero-gradient) update
            p_o, o_o = p_new, o_new
            for k in G:
                np.testing.assert_array_equal(
                    traj[t - 1][k], np.asarray(p_o[k])
                )


def test_ef_residual_conservation_under_overlap():
    """Error-feedback accounting stays conserving through the async
    pipeline: at every step, applied mass + residual mass + in-flight
    mass equals the total injected gradient mass (nothing is created or
    destroyed by delaying the sync one step)."""
    cfg = SyncConfig("multiscale", exact_fusion=True, overlap="one_step",
                     compression=CompressionConfig("topk", 0.25))
    plan = build_sync_plan(cfg, R)
    G = _const_grads(seed=3)
    inflight = init_inflight(G)
    residuals = init_residual(G)
    applied_mass = {k: np.zeros(G[k].shape[1:], np.float64) for k in G}
    for t in range(12):
        applied, inflight, residuals = async_execute_sync(
            plan, G, inflight, residuals, t
        )
        for k in G:
            # exact fusion preserves the replica mean of the payload, so
            # accumulating the applied mean tracks transmitted mass
            applied_mass[k] += np.asarray(applied[k], np.float64).mean(0)
            injected = (t + 1) * np.asarray(G[k], np.float64).mean(0)
            in_system = (
                applied_mass[k]
                + np.asarray(residuals[k], np.float64).mean(0)
                + np.asarray(inflight[k], np.float64).mean(0)
            )
            np.testing.assert_allclose(in_system, injected,
                                       rtol=1e-4, atol=1e-5)


def test_overlap_config_validation():
    with pytest.raises(ValueError, match="overlap mode"):
        SyncConfig("multiscale", overlap="two_step")
    # a single replica has nothing to overlap with
    assert build_sync_plan(
        SyncConfig("allreduce", overlap="one_step"), 1
    ).overlap == "none"


def _tiny_setup(sync):
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    Rr = 4
    opt = sgdm()
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (Rr,) + p.shape), base
    )
    state = init_decentralized_state(params_r, opt, sync=sync)
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 1e-2, sync, Rr))
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=Rr * 2, seed=5)

    def batch_at(s):
        b = data.batch_at(s)
        return {
            k: jnp.asarray(v.reshape(Rr, 2, *v.shape[1:])) for k, v in b.items()
        }

    return state, step, batch_at


def test_overlap_warmup_step_is_noop_then_trains():
    sync = SyncConfig("multiscale", overlap="one_step")
    state, step, batch_at = _tiny_setup(sync)
    assert "prev_grads" in state
    p0 = np.asarray(state["params"]["embed"]).copy()
    state, m = step(state, batch_at(0))
    # warmup: no delayed gradients yet — params and opt must be untouched
    np.testing.assert_array_equal(p0, np.asarray(state["params"]["embed"]))
    assert float(m["sync_overlap_fraction"]) == 0.0
    # the freshly produced gradients are now in flight
    assert float(jnp.abs(state["prev_grads"]["embed"]).max()) > 0
    for s in range(1, 4):
        state, m = step(state, batch_at(s))
        assert float(m["sync_overlap_fraction"]) == 1.0
        assert np.isfinite(float(m["loss"]))
    assert not np.array_equal(p0, np.asarray(state["params"]["embed"]))
    # gossip still holds the replicas inside the consensus ball
    assert float(m["consensus_distance"]) < 1e-2


def test_overlap_requires_prev_grads_state():
    sync = SyncConfig("multiscale", overlap="one_step")
    serial = SyncConfig("multiscale")
    state, _, batch_at = _tiny_setup(serial)  # state sized WITHOUT overlap
    cfg = reduce_config(get_config("llama3.2-3b"))
    step = make_decentralized_step(cfg, sgdm(), lambda s: 1e-2, sync, 4)
    with pytest.raises(ValueError, match="in-flight"):
        step(state, batch_at(0))


def test_serialized_step_reports_zero_overlap_fraction():
    sync = SyncConfig("multiscale")
    state, step, batch_at = _tiny_setup(sync)
    assert "prev_grads" not in state
    _, m = step(state, batch_at(0))
    assert float(m["sync_overlap_fraction"]) == 0.0
