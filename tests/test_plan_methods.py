"""Vectorized (CSR fast-path) plan builder vs the reference builder.

`build_plan(method="reference")` keeps the historical per-cell /
per-group loop construction; `method="vectorized"` (the default) is the
large-n rewrite.  The two must be BITWISE-interchangeable: every level's
CSR arrays, routes, and election outcomes identical — and therefore the
executed simulation (messages, usage counters, x) identical too, for
both the lax backend and the pallas kernel in interpret mode.
"""
import numpy as np
import pytest

from repro.core import ExecOptions, build_plan, execute_plan
from repro.core.plan import PLAN_METHODS

_LP_ARRAY_FIELDS = (
    "nbr_start", "nbr_flat", "hop_flat", "degrees", "n_nodes", "node_mask",
    "slot_node", "row_node", "partner_flat", "edge_b", "edge_i", "edge_si",
    "edge_j", "edge_sj", "edge_pos_i", "edge_pos_j", "inc_node", "inc_edge",
    "inc_count", "rep_slot", "rep_node", "line16", "next_graph", "next_slot",
)


def _plans(rgg500):
    return {m: build_plan(rgg500, seed=0, method=m) for m in PLAN_METHODS}


def test_plan_methods_bitwise_identical(rgg500):
    plans = _plans(rgg500)
    ref, vec = plans["reference"], plans["vectorized"]
    assert len(ref.levels) == len(vec.levels)
    for lr, lv in zip(ref.levels, vec.levels):
        assert (lr.level, lr.kind, lr.max_hops, lr.max_deg) == \
               (lv.level, lv.kind, lv.max_hops, lv.max_deg)
        for f in _LP_ARRAY_FIELDS:
            a, b = getattr(lr, f), getattr(lv, f)
            if a is None or b is None:
                assert a is b, (lr.level, f)
                continue
            np.testing.assert_array_equal(a, b, err_msg=f"L{lr.level}.{f}")
        if lr.routes is None:
            assert lv.routes is None
        else:
            np.testing.assert_array_equal(lr.routes.nodes, lv.routes.nodes)
            np.testing.assert_array_equal(lr.routes.hops, lv.routes.hops)
    np.testing.assert_array_equal(ref.rep_counts, vec.rep_counts)
    assert ref.disconnected_cells == vec.disconnected_cells
    np.testing.assert_array_equal(ref.final_graph, vec.final_graph)
    np.testing.assert_array_equal(ref.final_slot, vec.final_slot)
    # build_seconds carries the per-stage breakdown on both paths
    for plan in plans.values():
        assert set(plan.build_seconds) >= {
            "partition", "cells", "overlay", "routes", "incidence", "total"
        }


@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_plan_methods_execute_identically(rgg500, x0_500, backend):
    """fig3-sized end-to-end: messages, flat usage counters, and x are
    identical between the two builders under the presampled engine."""
    plans = _plans(rgg500)
    results = {
        m: execute_plan(
            p, x0_500, eps=1e-4, seeds=(0,), weighted=True,
            options=ExecOptions(
                backend=backend, interpret=True, collect_usage=True,
            ),
        )
        for m, p in plans.items()
    }
    ref, vec = results["reference"], results["vectorized"]
    np.testing.assert_array_equal(ref.messages, vec.messages)
    np.testing.assert_array_equal(ref.x_final, vec.x_final)
    np.testing.assert_array_equal(ref.node_sends, vec.node_sends)
    np.testing.assert_array_equal(ref.level_ticks, vec.level_ticks)
    for ur, uv in zip(ref.edge_usage, vec.edge_usage):
        np.testing.assert_array_equal(ur, uv)


def test_plan_method_validation(rgg500):
    with pytest.raises(ValueError):
        build_plan(rgg500, seed=0, method="dense")
