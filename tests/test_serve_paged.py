"""Serving-fleet subsystem: paged KV cache, continuous batching, and
the gossip control plane.

Parity contract: with a contiguous identity page map and
pages_per_slot * page_size == dense max_len, the gathered paged layout
reproduces the dense cache exactly and masked entries contribute exact
zeros to the softmax, so paged and dense decode agree BITWISE on the
lax path (global-attention configs; local/sliding-window layers keep a
window-sized dense buffer, so they are excluded from the bitwise
claim).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduce_config


@pytest.fixture(scope="module")
def llama():
    from repro.models import Transformer

    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    return cfg, model.init(jax.random.PRNGKey(0))


# ------------------------------ page table ------------------------------


def test_page_table_alloc_free():
    from repro.serve import PageTable

    t = PageTable(num_pages=8, page_size=4, num_slots=2, pages_per_slot=4)
    assert t.free_pages == 8 and (t.page_map == t.trash).all()
    t.alloc(0, 10)  # ceil(10/4) = 3 pages
    assert t.slot_pages(0) == 3 and t.free_pages == 5
    assert (t.page_map[0, :3] != t.trash).all()
    assert (t.page_map[0, 3:] == t.trash).all()
    with pytest.raises(ValueError):
        t.alloc(0, 4)  # double alloc
    with pytest.raises(ValueError):
        t.alloc(1, 100)  # > pages_per_slot capacity
    t.alloc(1, 16)
    assert t.free_pages == 1
    t = PageTable(num_pages=4, page_size=4, num_slots=2, pages_per_slot=4)
    t.alloc(0, 16)
    assert not t.can_alloc(4)
    with pytest.raises(ValueError):
        t.alloc(1, 4)  # out of pages
    assert t.free(0) == 4
    assert t.free_pages == 4 and (t.page_map == t.trash).all()
    assert t.can_alloc(16)


# --------------------------- paged vs dense -----------------------------


def test_paged_decode_bitwise_matches_dense(llama):
    """Teacher-forced step-by-step logits parity, exact to the bit."""
    from repro.models import (
        decode_step, init_cache, init_paged_cache, paged_decode_step,
    )

    cfg, params = llama
    B, max_len, ps = 2, 32, 8
    P = max_len // ps
    cache_d = init_cache(params, cfg, batch=B, max_len=max_len, dp=None)
    cache_p = init_paged_cache(cfg, B, B * P, ps)
    page_map = jax.numpy.arange(B * P, dtype=jax.numpy.int32).reshape(B, P)
    wmask = jax.numpy.ones(B, bool)
    toks = np.random.default_rng(0).integers(
        2, cfg.vocab_size, (B, 10)
    ).astype(np.int32)
    dstep = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t, dp=None))
    pstep = jax.jit(
        lambda p, c, t, s: paged_decode_step(p, cfg, c, t, page_map, s, wmask)
    )
    for t in range(toks.shape[1]):
        tk = jax.numpy.asarray(toks[:, t])
        ld, cache_d = dstep(params, cache_d, tk)
        lp, cache_p = pstep(
            params, cache_p, tk, jax.numpy.full((B,), t, jax.numpy.int32)
        )
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))


def test_paged_decode_recurrent_arch_matches_dense():
    """rwkv per-slot state path (write-mask select, slot-0 reset)."""
    from repro.models import (
        Transformer, decode_step, init_cache, init_paged_cache,
        paged_decode_step,
    )

    cfg = reduce_config(get_config("rwkv6-3b"))
    params = Transformer(cfg, model_axis=1).init(jax.random.PRNGKey(0))
    B, ps, P = 2, 8, 4
    cache_d = init_cache(params, cfg, batch=B, max_len=P * ps, dp=None)
    cache_p = init_paged_cache(cfg, B, B * P, ps)
    page_map = jax.numpy.arange(B * P, dtype=jax.numpy.int32).reshape(B, P)
    wmask = jax.numpy.ones(B, bool)
    toks = np.random.default_rng(1).integers(
        2, cfg.vocab_size, (B, 6)
    ).astype(np.int32)
    for t in range(toks.shape[1]):
        tk = jax.numpy.asarray(toks[:, t])
        ld, cache_d = decode_step(params, cfg, cache_d, tk, dp=None)
        lp, cache_p = paged_decode_step(
            params, cfg, cache_p, tk, page_map,
            jax.numpy.full((B,), t, jax.numpy.int32), wmask,
        )
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp))


# -------------------------- continuous batching -------------------------


def _engine(cfg, params, num_slots, *, page_size=4, pages_per_slot=8,
            max_prompt_len=8, seed=0):
    from repro.serve import BatchingEngine, ModelBackend, PageTable

    num_pages = num_slots * pages_per_slot
    table = PageTable(num_pages=num_pages, page_size=page_size,
                      num_slots=num_slots, pages_per_slot=pages_per_slot)
    backend = ModelBackend(cfg, params, num_slots=num_slots,
                           num_pages=num_pages, page_size=page_size,
                           max_prompt_len=max_prompt_len)
    return BatchingEngine(backend, table, eos_id=-1, seed=seed)


def test_engine_matches_generator_greedy(llama):
    """Full generate-loop parity: batched engine tokens == Generator's
    dense-cache greedy output (no retire/refill pressure: 3 slots,
    3 requests)."""
    from repro.serve import Generator

    cfg, params = llama
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, (3, 4)
    ).astype(np.int32)
    eng = _engine(cfg, params, num_slots=3)
    for b in range(3):
        eng.submit(prompts[b], 6)
    done = {r.rid: r for r in eng.run()}
    ref = Generator(cfg, params, max_len=32, temperature=0.0,
                    eos_id=-1).generate(prompts, steps=6, seed=0)
    for b in range(3):
        assert done[b].tokens == ref[b].tolist()


def test_engine_retire_refill_midstream(llama):
    """2 slots, 4 requests of uneven lengths: slots retire and refill
    mid-stream (reusing pages + slot state) and every request's tokens
    still equal an isolated single-request run."""
    cfg, params = llama
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, (3, 4)
    ).astype(np.int32)
    eng = _engine(cfg, params, num_slots=2)
    lens = [5, 3, 7, 4]
    for i, n in enumerate(lens):
        eng.submit(prompts[i % 3], n)
    done = eng.run()
    assert len(done) == 4
    # refill actually happened: more requests than slots
    assert max(r.slot for r in done) <= 1
    for r in done:
        solo = _engine(cfg, params, num_slots=1)
        solo.submit(r.prompt, r.max_new_tokens)
        (ref,) = solo.run()
        assert r.tokens == ref.tokens, f"rid {r.rid}"
        assert len(r.tokens) == r.max_new_tokens
    # all pages returned
    assert eng.table.free_pages == eng.table.num_pages


def test_engine_admission_backpressure():
    """Head-of-line admission blocks on page availability; the queue
    drains as slots retire (SimBackend: no device work)."""
    from repro.serve import BatchingEngine, PageTable, SimBackend

    table = PageTable(num_pages=8, page_size=4, num_slots=4,
                      pages_per_slot=4)
    eng = BatchingEngine(SimBackend(4), table, eos_id=-1)
    for _ in range(4):
        eng.submit(np.zeros(4, np.int32), 12)  # 4 pages each; pool fits 2
    ev = eng.step()
    assert ev["admitted"] == 2 and eng.queue_depth == 2
    assert eng.load_vector()["free_pages"] == 0.0
    done = eng.run()
    assert len(done) == 4
    assert eng.table.free_pages == 8


# ------------------------- generator satellites -------------------------


def test_generator_post_eos_masking(llama):
    """Once a slot emits eos, every later position is eos and only live
    slots count toward throughput."""
    from repro.serve import Generator

    cfg, params = llama
    prompts = np.random.default_rng(0).integers(
        2, cfg.vocab_size, (3, 4)
    ).astype(np.int32)
    gen = Generator(cfg, params, max_len=32, temperature=0.0, eos_id=-1)
    free_run = gen.generate(prompts, steps=8, seed=0)
    # adopt a token the model actually emits mid-stream as the eos id
    eos = int(free_run[0, 2])
    gen_eos = Generator(cfg, params, max_len=32, temperature=0.0,
                        eos_id=eos)
    out = gen_eos.generate(prompts, steps=8, seed=0)
    stats = gen_eos.last_stats
    for b in range(out.shape[0]):
        hits = np.nonzero(out[b] == eos)[0]
        if hits.size:
            assert (out[b, hits[0]:] == eos).all()
    assert (out[0] == eos).any()
    assert stats["live_tokens"] < stats["emitted_tokens"]
    assert stats["emitted_tokens"] == out.size


# ---------------------------- control plane -----------------------------


def test_control_plane_convergence_and_accounting():
    from repro.serve import LOAD_FIELDS, ControlPlane

    R = 16
    rng = np.random.default_rng(0)
    loads = rng.uniform(0.0, 10.0, (R, len(LOAD_FIELDS)))
    scores = rng.uniform(0.0, 2.0, R)
    cp = ControlPlane(R, full_view=True, seed=0, eps=1e-4)
    rr = cp.round(loads, scores, round_idx=0)
    # every replica's fleet-mean estimate within eps-scale of the truth
    assert np.abs(rr.summary - loads.mean(0)).max() < 1e-2
    # ... and its full per-replica load table (the p2c routing input)
    assert np.abs(rr.table - scores[None, :]).max() < 1e-2
    # cost accounting: one packet per exchange carries the whole payload
    assert rr.payload_values == len(LOAD_FIELDS) + R
    assert rr.control_bytes == rr.messages * rr.payload_values * 4
    assert rr.level_messages.sum() <= rr.messages  # + dissemination
    assert len(rr.level_messages) == len(cp.plan.levels)
    rr2 = cp.round(loads, scores, round_idx=1)
    assert rr2.messages == rr.messages  # same FI schedule length
    assert cp.rounds_run == 2
    assert cp.total_bytes == rr.control_bytes + rr2.control_bytes


def test_control_plane_rejects_bad_inputs():
    from repro.serve import LOAD_FIELDS, ControlPlane

    cp = ControlPlane(8, full_view=True, seed=0)
    with pytest.raises(ValueError):
        cp.round(np.zeros((4, len(LOAD_FIELDS))), np.zeros(8))
    with pytest.raises(ValueError):
        cp.round(np.zeros((8, len(LOAD_FIELDS))), None)
    with pytest.raises(ValueError):
        ControlPlane(8, fixed_ticks_scale=0.0)


# ------------------------------- fleet ----------------------------------


def test_fleet_gossip_routing_tracks_oracle():
    """N=16 simulated replicas: p2c over gossiped estimates reaches
    >= 0.9x the centralized least-loaded oracle's throughput and beats
    random routing, while paying a bounded control-plane byte cost."""
    from repro.serve import FleetConfig, run_fleet

    results = {}
    for router in ("p2c_gossip", "oracle", "random"):
        cfg = FleetConfig(replicas=16, ticks=120, router=router, seed=0)
        results[router] = run_fleet(cfg)
    p2c, oracle, rand = (
        results["p2c_gossip"], results["oracle"], results["random"]
    )
    assert p2c.throughput >= 0.9 * oracle.throughput
    assert p2c.admission_latency_mean <= rand.admission_latency_mean
    assert p2c.control_rounds == 120 // 4
    assert p2c.control_bytes == p2c.control_rounds * p2c.bytes_per_round
    assert oracle.control_bytes == 0 and rand.control_bytes == 0
    assert p2c.completed > 0 and p2c.tokens > 0
