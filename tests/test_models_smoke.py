"""Per-architecture smoke tests: reduced same-family config, one forward
+ one train-gradient step + a few decode steps on CPU; asserts shapes
and finiteness (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_config
from repro.models import Transformer, decode_step, forward, init_cache, loss_fn


def _batch(cfg, B=2, S=16, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.mrope_sections is not None:
        pos = np.broadcast_to(np.arange(S)[None, :, None], (B, S, 3)).copy()
        batch["positions"] = jnp.asarray(pos, jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_grad(arch_id):
    cfg = reduce_config(get_config(arch_id))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    loss, grads = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, cfg, batch)))(params)
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all()) for g in flat)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_steps(arch_id):
    cfg = reduce_config(get_config(arch_id))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(1))
    B = 2
    frames = (
        jnp.asarray(
            np.random.default_rng(2).normal(0, 1, (B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32,
        )
        if cfg.encoder_layers
        else None
    )
    cache = init_cache(params, cfg, batch=B, max_len=32, frames=frames)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(4):
        logits, cache = step(params, cache, tok)
        assert logits.shape == (B, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(cache["step"]) == 4


def test_decode_matches_prefill_for_dense():
    """Teacher-forced decode logits must match full-forward logits."""
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S, key=5)
    full = forward(params, cfg, batch)  # (B,S,V)
    cache = init_cache(params, cfg, batch=B, max_len=16)
    outs = []
    for t in range(S):
        logits, cache = decode_step(params, cfg, cache, batch["tokens"][:, t])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_matches_prefill_for_ssm():
    cfg = reduce_config(get_config("rwkv6-3b"))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(4))
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S, key=6)
    full = forward(params, cfg, batch)
    cache = init_cache(params, cfg, batch=B, max_len=16)
    outs = []
    for t in range(S):
        logits, cache = decode_step(params, cfg, cache, batch["tokens"][:, t])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_matches_prefill_for_hybrid():
    # fp32 config: checks the recurrence semantics exactly (bf16 parity
    # is rounding-limited through the RG-LRU state and tested at the
    # unit level in fp32 too)
    import dataclasses

    cfg = dataclasses.replace(
        reduce_config(get_config("recurrentgemma-9b")), dtype="float32"
    )
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(5))
    B, S = 1, 8
    batch = _batch(cfg, B=B, S=S, key=7)
    full = forward(params, cfg, batch)
    cache = init_cache(params, cfg, batch=B, max_len=16)
    outs = []
    for t in range(S):
        logits, cache = decode_step(params, cfg, cache, batch["tokens"][:, t])
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_rglru_chunked_scan_consistency():
    """chunk=2 vs single-chunk associative scan must agree (fp32)."""
    import dataclasses

    from repro.models.layers import init_tree
    from repro.models.rglru import rglru_block, rglru_params

    cfg = dataclasses.replace(
        reduce_config(get_config("recurrentgemma-9b")), dtype="float32"
    )
    params = init_tree(rglru_params(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(0).normal(0, 1, (2, 8, cfg.d_model)), jnp.float32
    )
    a = rglru_block(params, cfg, x, chunk=2)
    b = rglru_block(params, cfg, x, chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_chunked_attention_matches_full():
    from repro.models.attention import attention
    cfg = reduce_config(get_config("yi-6b"))
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(6))
    p = jax.tree.map(lambda a: a[0], params["groups"][0]["b0"])["attn"]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (2, 64, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(64)[None], (2, 64))
    full = attention(p, cfg, x, pos, chunk_threshold=8192)
    chunked = attention(p, cfg, x, pos, chunk_threshold=16)
    np.testing.assert_allclose(
        np.asarray(full, np.float32), np.asarray(chunked, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_param_counts_full_configs():
    """Full (non-reduced) configs: parameter counts in the right ballpark
    (catches misconfigured dims without materializing weights)."""
    expected = {
        "yi-6b": (5.5e9, 7.5e9),
        "gemma-7b": (7.5e9, 10e9),
        "gemma2-27b": (25e9, 30e9),
        "llama3.2-3b": (2.8e9, 4.0e9),
        "qwen2-vl-72b": (68e9, 76e9),
        "rwkv6-3b": (2.5e9, 4.0e9),
        "recurrentgemma-9b": (8.0e9, 11e9),
        "grok-1-314b": (290e9, 330e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expected.items():
        n = Transformer(get_config(arch)).num_params
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"
