"""Gossip engine invariants: mass conservation, convergence, message
accounting, failure semantics."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import batched_graphs, gossip_until, random_geometric_graph


def _ring(n):
    class G:
        pass

    g = G()
    g.n = n
    g.max_deg = 2
    g.neighbors = np.stack(
        [(np.arange(n) - 1) % n, (np.arange(n) + 1) % n], axis=1
    ).astype(np.int32)
    g.degrees = np.full(n, 2, np.int32)
    return g


def test_mass_conservation_reliable():
    g = random_geometric_graph(200, seed=2)
    x0 = np.random.default_rng(0).normal(0, 1, 200).astype(np.float32)
    res = gossip_until(
        x0[None, :], g.neighbors[None], g.degrees[None],
        np.array([200], np.int32), eps=1e-5, seed=0,
    )
    assert res.converged.all()
    # pairwise averaging preserves the sum exactly (up to f32 rounding)
    np.testing.assert_allclose(res.x[0, :, 0].sum(), x0.sum(), rtol=1e-4, atol=1e-3)


def test_convergence_to_mean():
    g = random_geometric_graph(300, seed=3)
    x0 = np.random.default_rng(1).normal(0, 1, 300).astype(np.float32)
    res = gossip_until(
        x0[None, :], g.neighbors[None], g.degrees[None],
        np.array([300], np.int32), eps=1e-4, seed=1,
    )
    est = res.estimates()[0]
    assert np.linalg.norm(est - x0.mean()) <= 1.1e-4 * np.linalg.norm(x0) + 1e-5


def test_batched_independent_convergence():
    gs = [_ring(8), _ring(32), _ring(64)]
    neighbors, degrees, n_nodes, mask = batched_graphs(gs)
    rng = np.random.default_rng(2)
    x0 = np.where(mask, rng.normal(0, 1, mask.shape), 0.0).astype(np.float32)
    res = gossip_until(x0, neighbors, degrees, n_nodes, eps=1e-3, seed=2)
    assert res.converged.all()
    # smaller rings must not pay for the biggest ring's convergence
    assert res.ticks[0] <= res.ticks[2]
    for b, g in enumerate(gs):
        m = x0[b, : g.n].mean()
        d = res.x[b, : g.n, 0] - m
        assert np.linalg.norm(d) <= 1.1e-3 * np.linalg.norm(x0[b, : g.n]) + 1e-6


def test_message_accounting_matches_usage():
    g = _ring(16)
    x0 = np.random.default_rng(3).normal(0, 1, 16).astype(np.float32)
    hops = np.full((1, 16, 2), 3, np.int32)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([16], np.int32), eps=1e-3, seed=3, edge_hops=hops,
    )
    assert res.messages[0] == 2 * 3 * res.edge_usage[0].sum()


def test_fixed_ticks_exact_budget():
    g = _ring(16)
    x0 = np.random.default_rng(4).normal(0, 1, 16).astype(np.float32)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([16], np.int32), eps=1e-3, seed=4, fixed_ticks=100,
    )
    # budget padded up to the check_every multiple
    assert res.ticks[0] >= 100
    assert res.edge_usage[0].sum() == res.ticks[0]


def test_weighted_channels_ratio():
    g = _ring(32)
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, 32).astype(np.float32)
    w = rng.uniform(0.5, 2.0, 32).astype(np.float32)
    x0 = np.stack([x * w, w], axis=-1)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([32], np.int32), eps=1e-6, seed=5,
    )
    est = res.estimates()[0]
    expected = (x * w).sum() / w.sum()
    np.testing.assert_allclose(est, expected, rtol=1e-3, atol=1e-5)


def test_loss_p_one_equals_reliable():
    g = _ring(24)
    x0 = np.random.default_rng(6).normal(0, 1, 24).astype(np.float32)[None]
    a = gossip_until(
        x0, g.neighbors[None], g.degrees[None], np.array([24], np.int32),
        eps=1e-4, seed=6,
    )
    b = gossip_until(
        x0, g.neighbors[None], g.degrees[None], np.array([24], np.int32),
        eps=1e-4, seed=6, loss_p=1.0,
    )
    np.testing.assert_array_equal(a.x, b.x)
    assert a.messages[0] == b.messages[0]


def test_loss_model_distorts_mass():
    g = _ring(64)
    x0 = np.random.default_rng(7).normal(0, 1, 64).astype(np.float32)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([64], np.int32), eps=1e-6, seed=7, loss_p=0.5,
        fixed_ticks=2000,
    )
    # under heavy loss the sum drifts (paper §VI-C-2: signal energy lost)
    assert abs(res.x[0, :, 0].sum() - x0.sum()) > 1e-4
    # and each exchange costs at most the reliable 2 hops
    assert res.messages[0] <= 2 * res.edge_usage[0].sum()


@given(seed=st.integers(0, 2**16), n=st.sampled_from([5, 17, 40]))
@settings(max_examples=10)
def test_property_mass_conserved(seed, n):
    g = _ring(n)
    rng = np.random.default_rng(seed)
    x0 = rng.normal(0, 1, n).astype(np.float32)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([n], np.int32), eps=-1.0, seed=seed, fixed_ticks=256,
    )
    np.testing.assert_allclose(
        res.x[0, :, 0].sum(), x0.sum(), rtol=2e-4, atol=2e-3
    )


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10)
def test_property_values_stay_in_convex_hull(seed):
    n = 20
    g = _ring(n)
    rng = np.random.default_rng(seed)
    x0 = rng.normal(0, 1, n).astype(np.float32)
    res = gossip_until(
        x0[None], g.neighbors[None], g.degrees[None],
        np.array([n], np.int32), eps=-1.0, seed=seed, fixed_ticks=128,
    )
    x = res.x[0, :, 0]
    assert x.min() >= x0.min() - 1e-5 and x.max() <= x0.max() + 1e-5
