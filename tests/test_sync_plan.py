"""SyncPlan construction: validation, rotation schedule, wire accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import (
    CompressionConfig, SyncConfig, build_sync_plan, execute_sync,
    plan_wire_bytes, rotation_schedule, suggest_levels, tree_payload_bytes,
    wire_fraction,
)


# --------------------------- config validation ---------------------------


def test_rounds_levels_length_mismatch_raises_at_construction():
    with pytest.raises(ValueError, match="rounds .* entries but levels"):
        SyncConfig("multiscale", levels=(2, 2, 2), rounds=(1, 2))


def test_ring_rejects_per_level_rounds():
    with pytest.raises(ValueError, match="single global round count"):
        SyncConfig("ring", rounds=(4, 4))


def test_non_product_levels_raise_at_plan_time_with_clear_message():
    with pytest.raises(ValueError, match="factor 9 replicas but R=8"):
        build_sync_plan(SyncConfig("multiscale", levels=(3, 3)), 8)


def test_negative_rotation_period_rejected():
    with pytest.raises(ValueError, match="rotation_period"):
        SyncConfig("multiscale", rotation_period=-1)


def test_compression_scheme_string_coerces():
    cfg = SyncConfig("multiscale", compression="int8")
    assert cfg.compression == CompressionConfig("int8")


# ------------------------------- the plan --------------------------------


def test_plan_is_hashable_and_resolved():
    R = 32
    plan = build_sync_plan(SyncConfig("multiscale"), R)
    assert hash(plan) is not None
    assert plan.levels == suggest_levels(R)
    assert len(plan.rounds) == len(plan.levels)
    assert plan.rotation is None
    # identical configs resolve to equal (jit-cache-friendly) plans
    assert plan == build_sync_plan(SyncConfig("multiscale"), R)


def test_plan_static_under_jit_single_trace():
    R = 8
    plan = build_sync_plan(SyncConfig("multiscale", rotation_period=3), R)
    traces = []

    @jax.jit
    def f(g, s):
        traces.append(1)
        return execute_sync(plan, g, None, s)[0]

    g = {"x": jnp.ones((R, 4))}
    f(g, 0)
    f(g, 1)  # step is traced — rotation change must NOT retrigger tracing
    assert len(traces) == 1


def test_rotation_schedule_deterministic_and_inverse():
    perms, invs = rotation_schedule(16, period=5, seed=7)
    perms2, invs2 = rotation_schedule(16, period=5, seed=7)
    np.testing.assert_array_equal(perms, perms2)
    np.testing.assert_array_equal(invs, invs2)
    for t in range(5):
        np.testing.assert_array_equal(invs[t][perms[t]], np.arange(16))
    # a different seed gives a different schedule
    assert not np.array_equal(perms, rotation_schedule(16, 5, seed=8)[0])


def test_rotation_only_built_for_gossip_strategies():
    for strat in ("allreduce", "hierarchical"):
        plan = build_sync_plan(SyncConfig(strat, rotation_period=4), 8)
        assert plan.rotation is None, strat
    plan = build_sync_plan(SyncConfig("ring", rotation_period=4), 8)
    assert plan.rotation is not None and len(plan.rotation) == 4


# ---------------------------- wire accounting ----------------------------


def test_wire_bytes_scale_with_compression():
    R = 8
    g = {"a": jnp.zeros((R, 64)), "b": jnp.zeros((R, 4, 8))}
    assert tree_payload_bytes(g) == (64 + 32) * 4
    dense = build_sync_plan(SyncConfig("multiscale"), R)
    int8 = build_sync_plan(SyncConfig("multiscale", compression="int8"), R)
    assert plan_wire_bytes(int8, g) == pytest.approx(
        0.25 * plan_wire_bytes(dense, g)
    )
    assert wire_fraction(int8.compression) == 0.25


def test_transmissions_ordering_matches_paper():
    """Flat ring gossip is the chatty baseline; the hierarchy beats it."""
    R = 64
    ring = build_sync_plan(SyncConfig("ring"), R)
    multi = build_sync_plan(SyncConfig("multiscale"), R)
    allred = build_sync_plan(SyncConfig("allreduce"), R)
    assert allred.transmissions < multi.transmissions < ring.transmissions
    assert build_sync_plan(SyncConfig("allreduce"), 1).transmissions == 0
