"""Gradient compression + error feedback invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.dist.compression import (
    CompressionConfig, compress, decompress, init_residual, wire_fraction,
)


def _grads(R=4, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(R, 64)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(R, 8, 16)), jnp.float32),
    }


def test_none_is_identity():
    g = _grads()
    r = init_residual(g)
    p, r2 = compress(g, r, CompressionConfig("none"))
    assert p is g and r2 is r


def test_topk_keeps_largest_and_residual_holds_rest():
    g = _grads()
    r = init_residual(g)
    cfg = CompressionConfig("topk", topk_fraction=0.25)
    p, r2 = compress(g, r, cfg)
    for k in g:
        sent = np.asarray(p[k])
        res = np.asarray(r2[k])
        # sent + residual == original (exact decomposition)
        np.testing.assert_allclose(sent + res, np.asarray(g[k]), atol=1e-6)
        flat = sent.reshape(sent.shape[0], -1)
        nz = (flat != 0).sum(axis=1)
        kk = max(1, int(0.25 * flat.shape[1]))
        assert (nz <= kk + 1).all() and (nz >= 1).all()


def test_int8_quantization_error_bounded():
    g = _grads()
    cfg = CompressionConfig("int8")
    p, r2 = compress(g, init_residual(g), cfg)
    for k in g:
        gmax = np.abs(np.asarray(g[k])).max()
        err = np.abs(np.asarray(p[k]) - np.asarray(g[k])).max()
        assert err <= gmax / 127.0 + 1e-6
    assert wire_fraction(cfg) == 0.25


def test_error_feedback_converges_mean():
    """With error feedback, repeated compressed averaging still moves all
    mass eventually: sum of (sent_t) over steps -> sum of grads."""
    g = _grads(seed=3)
    cfg = CompressionConfig("topk", topk_fraction=0.1)
    res = init_residual(g)
    total_sent = jax.tree.map(jnp.zeros_like, g)
    for _ in range(60):
        sent, res = compress(g, res, cfg)
        total_sent = jax.tree.map(lambda a, s: a + s, total_sent, sent)
        # note: same g each step, so total_sent ~ t*g - residual
    for k in g:
        drift = np.abs(np.asarray(res[k])).max()
        scale = np.abs(np.asarray(g[k])).max()
        assert drift <= 12 * scale  # residual stays bounded (EF property)


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_error_feedback_conserves_accumulator_bitwise(scheme):
    """Sent tensor + new residual reconstructs the (gradient + residual)
    accumulator BITWISE: topk entries are exact copies/leftovers; int8's
    acc - dequant subtraction is Sterbenz-exact (nonzero quantization
    levels satisfy dequant/2 <= acc <= 2*dequant; zero levels leave acc
    itself as residual).  No mass is created or destroyed by a sync."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        g = _grads(seed=seed)
        res = jax.tree.map(
            lambda x: jnp.asarray(
                rng.normal(size=x.shape) * 0.1, jnp.float32), g,
        )
        p, r2 = compress(g, res, CompressionConfig(scheme, topk_fraction=0.25))
        for k in g:
            acc = np.asarray(g[k]) + np.asarray(res[k])
            recon = np.asarray(p[k]) + np.asarray(r2[k])
            np.testing.assert_array_equal(recon, acc)


@given(frac=st.floats(0.05, 0.9), seed=st.integers(0, 50))
@settings(max_examples=10)
def test_property_decomposition_exact(frac, seed):
    g = _grads(seed=seed)
    cfg = CompressionConfig("topk", topk_fraction=frac)
    p, r2 = compress(g, init_residual(g), cfg)
    for k in g:
        np.testing.assert_allclose(
            np.asarray(p[k]) + np.asarray(r2[k]), np.asarray(g[k]), atol=1e-6
        )
    assert wire_fraction(cfg) <= 1.0
