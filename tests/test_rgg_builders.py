"""Bitwise parity suite for the streamed bucket graph builder vs the
cKDTree reference (ISSUE 8): same seed => identical CSR, invariant to
the streaming chunk size, plus parity tests for the vectorized
`induced_subgraph` row packing and the csgraph-based component labels
(each checked against a per-row / BFS reference reimplementation of the
historical code)."""
import numpy as np
import pytest

from repro.core.rgg import (
    Graph,
    grid_graph,
    induced_subgraph,
    random_geometric_graph,
    _component_labels,
)


def _assert_same_graph(a: Graph, b: Graph):
    np.testing.assert_array_equal(a.nbr_start, b.nbr_start)
    np.testing.assert_array_equal(a.nbr_flat, b.nbr_flat)
    np.testing.assert_array_equal(a.degrees, b.degrees)
    np.testing.assert_array_equal(a.coords, b.coords)
    assert a.radius == b.radius


@pytest.mark.parametrize("n", [64, 500, 5000])
@pytest.mark.parametrize("seed", [0, 7])
def test_bucket_matches_reference(n, seed):
    gb = random_geometric_graph(n, seed=seed, method="bucket")
    gr = random_geometric_graph(n, seed=seed, method="reference")
    _assert_same_graph(gb, gr)


@pytest.mark.parametrize("chunk", [17, 200, 10_000_000])
def test_bucket_chunk_invariant(chunk):
    base = random_geometric_graph(500, seed=3, method="bucket")
    other = random_geometric_graph(500, seed=3, method="bucket", chunk=chunk)
    _assert_same_graph(base, other)


def test_bucket_matches_reference_disconnected():
    # sub-connectivity radius => many components; the repair path in
    # plan building consumes exactly these graphs
    for seed in (0, 5):
        gb = random_geometric_graph(
            300, seed=seed, radius=0.03, method="bucket"
        )
        gr = random_geometric_graph(
            300, seed=seed, radius=0.03, method="reference"
        )
        _assert_same_graph(gb, gr)
        assert not gb.is_connected()


def test_bucket_matches_reference_grid_coords():
    # lattice coordinates stress exact on-the-boundary distances
    # (d == r bitwise) and equal-occupancy buckets
    gg = grid_graph(12)
    gb = random_geometric_graph(
        gg.n, coords=gg.coords, radius=gg.radius, method="bucket"
    )
    gr = random_geometric_graph(
        gg.n, coords=gg.coords, radius=gg.radius, method="reference"
    )
    _assert_same_graph(gb, gr)
    # grid_graph's radius (1.5 / side) also captures the diagonals, so
    # interior nodes see the full 8-neighborhood here
    assert int(gb.degrees.max()) == 8


def test_unknown_method_raises():
    with pytest.raises(ValueError):
        random_geometric_graph(64, method="nope")


def test_dense_neighbors_view_matches_csr():
    g = random_geometric_graph(500, seed=7)
    nb = g.neighbors
    assert nb.shape == (g.n, g.max_deg)
    for u in range(0, g.n, 53):
        row = nb[u][nb[u] >= 0]
        np.testing.assert_array_equal(
            row, g.nbr_flat[g.nbr_start[u]:g.nbr_start[u + 1]]
        )
        assert (nb[u][g.degrees[u]:] == -1).all()


def test_neighbor_rows_gather():
    g = random_geometric_graph(500, seed=7)
    ids = np.array([0, 17, 400, 17])
    rows = g.neighbor_rows(ids)
    assert rows.shape[1] == max(1, int(g.degrees[ids].max()))
    for i, u in enumerate(ids):
        d = int(g.degrees[u])
        np.testing.assert_array_equal(
            rows[i, :d], g.nbr_flat[g.nbr_start[u]:g.nbr_start[u] + d]
        )
        assert (rows[i, d:] == -1).all()


def test_graph_pickle_drops_cached_dense():
    import pickle

    g = random_geometric_graph(200, seed=1)
    _ = g.neighbors, g.max_deg  # materialize the cached views
    g2 = pickle.loads(pickle.dumps(g))
    assert "neighbors" not in g2.__dict__ and "max_deg" not in g2.__dict__
    _assert_same_graph(g, g2)
    np.testing.assert_array_equal(g.neighbors, g2.neighbors)


def test_induced_subgraph_matches_row_loop():
    # per-row loop reference == the historical compaction loop's layout
    g = random_geometric_graph(500, seed=7)
    ids = np.sort(
        np.random.default_rng(0).choice(g.n, 211, replace=False)
    ).astype(np.int32)
    sub, back = induced_subgraph(g, ids)
    np.testing.assert_array_equal(back, ids)
    remap = np.full(g.n, -1, np.int32)
    remap[ids] = np.arange(len(ids), dtype=np.int32)
    flat_rows = []
    for u in ids:
        row = g.nbr_flat[g.nbr_start[u]:g.nbr_start[u + 1]]
        mapped = remap[row]
        flat_rows.append(mapped[mapped >= 0])
    np.testing.assert_array_equal(sub.nbr_flat, np.concatenate(flat_rows))
    np.testing.assert_array_equal(
        sub.degrees, np.array([len(r) for r in flat_rows], np.int32)
    )
    np.testing.assert_array_equal(sub.coords, g.coords[ids])


def test_component_labels_match_bfs():
    # csgraph labels partition the nodes exactly like the historical
    # python BFS (label values may differ; the partition may not)
    g = random_geometric_graph(300, seed=5, radius=0.05)
    labels = _component_labels(g)
    seen = np.full(g.n, -1, np.int64)
    comp = 0
    for s in range(g.n):
        if seen[s] >= 0:
            continue
        stack = [s]
        seen[s] = comp
        while stack:
            u = stack.pop()
            for v in g.nbr_flat[g.nbr_start[u]:g.nbr_start[u + 1]]:
                if seen[v] < 0:
                    seen[v] = comp
                    stack.append(int(v))
        comp += 1
    assert labels.max() + 1 == comp
    # same partition: equal labels iff equal BFS labels
    pairs = set(zip(labels.tolist(), seen.tolist()))
    assert len(pairs) == comp


def test_from_padded_round_trip():
    g = random_geometric_graph(200, seed=2)
    g2 = Graph.from_padded(g.coords, g.neighbors, g.degrees, g.radius)
    _assert_same_graph(g, g2)


def test_grid_graph_layout_unchanged():
    # the grid topology keeps the historical pair-order CSR layout
    gg = grid_graph(4)
    assert gg.n == 16 and gg.num_edges == 24
    np.testing.assert_array_equal(
        gg.nbr_flat[gg.nbr_start[0]:gg.nbr_start[1]], [1, 4]
    )
    np.testing.assert_array_equal(
        gg.nbr_flat[gg.nbr_start[5]:gg.nbr_start[6]], [6, 9, 4, 1]
    )
