"""Coverage for core/failures.handshake_cost and core/metrics."""
import numpy as np
import pytest

from repro.core.failures import handshake_cost
from repro.core.metrics import relative_error, theorem2_bound

# ----------------------------- handshake ------------------------------


def test_handshake_p1_is_passthrough():
    for t in (0, 1, 7, 12345):
        assert handshake_cost(t, 1.0) == t


def test_handshake_zero_transmissions_free():
    assert handshake_cost(0, 0.3) == 0


def test_handshake_geometric_cost_identity():
    """Each delivery takes Geometric(p) attempts, so the physical cost of
    T logical transmissions concentrates around T/p (mean of a sum of T
    iid geometrics).  With T = 20000 the relative sampling error of the
    mean is ~1/sqrt(T*(1-p))/... well under 5%."""
    rng = np.random.default_rng(11)
    T = 20_000
    for p in (0.25, 0.5, 0.9):
        cost = handshake_cost(T, p, rng)
        assert cost >= T  # retransmission never reduces cost
        np.testing.assert_allclose(cost, T / p, rtol=0.05)


def test_handshake_is_reproducible_with_seeded_rng():
    a = handshake_cost(500, 0.4, np.random.default_rng(3))
    b = handshake_cost(500, 0.4, np.random.default_rng(3))
    assert a == b


@pytest.mark.parametrize("p", [0.0, -0.1, 1.5])
def test_handshake_rejects_bad_probability(p):
    with pytest.raises(ValueError, match="success probability"):
        handshake_cost(10, p)


# ------------------------------ metrics -------------------------------


def test_relative_error_zero_at_consensus():
    x0 = np.array([1.0, 2.0, 3.0, 6.0])
    x = np.full(4, x0.mean())
    assert relative_error(x, x0) == 0.0


def test_relative_error_known_value():
    x0 = np.array([1.0, -1.0])  # mean 0, ||x0|| = sqrt(2)
    x = np.array([1.0, -1.0])
    np.testing.assert_allclose(relative_error(x, x0), 1.0)
    # scaling the estimate scales the error linearly
    np.testing.assert_allclose(relative_error(0.5 * x, x0), 0.5)


def test_relative_error_matches_definition():
    rng = np.random.default_rng(0)
    x0 = rng.normal(size=50)
    x = rng.normal(size=50)
    want = np.linalg.norm(x - x0.mean()) / np.linalg.norm(x0)
    np.testing.assert_allclose(relative_error(x, x0), want, rtol=1e-12)


def test_theorem2_bound_formula_and_monotonicity():
    np.testing.assert_allclose(theorem2_bound(100, 1e-3), np.sqrt(6.0) * 0.1)
    assert theorem2_bound(200, 1e-3) > theorem2_bound(100, 1e-3)
    assert theorem2_bound(100, 1e-4) < theorem2_bound(100, 1e-3)
    assert theorem2_bound(0, 1e-3) == 0.0
