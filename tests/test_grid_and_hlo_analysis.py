"""(1) Paper §VIII: "the results translate directly to grids" — run
multiscale gossip on a 2-D lattice.  (2) Unit tests for the HLO
collective parser the roofline analysis depends on."""
import numpy as np
import pytest

from repro.core import grid_graph, multiscale_gossip, path_averaging
from repro.launch.hlo_analysis import (
    CollectiveStats,
    collective_bytes,
    device_pod_map,
)


def test_multiscale_on_grid_topology():
    g = grid_graph(24)  # 576-node lattice in the unit square
    x0 = np.random.default_rng(0).normal(0, 1, g.n)
    res = multiscale_gossip(g, x0, eps=1e-4, seed=0, weighted=True)
    assert res.error(x0) <= 2e-3
    pa = path_averaging(g, x0, eps=1e-4, seed=0)
    assert res.messages < pa.messages  # the paper's claim holds on grids


def test_multiscale_on_jittered_grid():
    g = grid_graph(20, jitter=0.2, seed=3)
    x0 = np.random.default_rng(1).normal(0, 1, g.n)
    res = multiscale_gossip(g, x0, eps=1e-4, seed=1, weighted=True)
    assert res.error(x0) <= 2e-3


# --------------------------- HLO parsing -------------------------------

HLO_SAMPLE = """
ENTRY %main {
  %ar = f32[1024,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={0}
  %cp = f32[32]{0} collective-permute(%z), source_target_pairs={{0,1},{1,2},{7,0}}
  %rs = f32[512]{0} reduce-scatter(%w), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %dot = f32[128,128]{1,0} dot(%a, %b)
}
"""


def test_collective_bytes_counts_and_kinds():
    stats = collective_bytes(HLO_SAMPLE, pod_size=4)
    assert stats.count == 4  # dot is not a collective
    assert stats.by_kind["all-reduce"] == 1024 * 256 * 4
    assert stats.by_kind["all-gather"] == 64 * 128 * 2
    assert stats.by_kind["collective-permute"] == 32 * 4
    assert stats.by_kind["reduce-scatter"] == 512 * 4


def test_cross_pod_classification():
    stats = collective_bytes(HLO_SAMPLE, pod_size=4)
    # all-reduce groups {0..3},{4..7} stay inside pods of 4; the permute
    # pair {7,0} and the global reduce-scatter cross pods
    expected_cross = 32 * 4 + 512 * 4
    assert stats.cross_pod_bytes == expected_cross


def test_stats_arithmetic():
    a = collective_bytes(HLO_SAMPLE, pod_size=4)
    two = a + a
    assert two.total_bytes == 2 * a.total_bytes
    diff = two - a
    assert diff.total_bytes == a.total_bytes
    scaled = a.scaled(3)
    assert scaled.cross_pod_bytes == 3 * a.cross_pod_bytes


def test_start_done_counted_once():
    hlo = """
  %s = f32[256]{0} all-gather-start(%x), replica_groups={{0,1}}
  %d = f32[256]{0} all-gather-done(%s)
"""
    stats = collective_bytes(hlo, pod_size=2)
    assert stats.count == 1
    assert stats.total_bytes == 256 * 4


def test_iota_transpose_crosses_pods():
    # [2,2]<=[2,2]T(1,0): iota [[0,1],[2,3]] transposed -> [0,2,1,3],
    # groups {0,2},{1,3} — every group crosses the 2-device pods.  The
    # old parser dropped the transpose and read consecutive {0,1},{2,3}
    # (intra-pod), the exact bug that zeroed cross-pod bytes on the
    # 32-replica bench.
    hlo = "%ar = f32[64]{0} all-reduce(%x), replica_groups=[2,2]<=[2,2]T(1,0), to_apply=%add"
    stats = collective_bytes(hlo, pod_size=2)
    assert stats.cross_pod_bytes == 64 * 4
    # without the transpose the same shape really is intra-pod
    hlo_plain = "%ar = f32[64]{0} all-reduce(%x), replica_groups=[2,2]<=[2,2], to_apply=%add"
    assert collective_bytes(hlo_plain, pod_size=2).cross_pod_bytes == 0


def test_device_pod_map_overrides_id_heuristic():
    class Dev:
        def __init__(self, id):
            self.id = id

    # assignment order permutes device ids: partition 1 is device 2
    devices = [Dev(0), Dev(2), Dev(1), Dev(3)]
    pod_of = device_pod_map(devices, pod_size=2)
    assert pod_of == [0, 1, 0, 1]
    hlo = "%ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%add"
    # heuristic: groups {0,1},{2,3} look intra-pod; the assignment says
    # partition 1 lives in pod 1 => both groups cross
    assert collective_bytes(hlo, pod_size=2).cross_pod_bytes == 0
    assert collective_bytes(hlo, pod_size=2, pod_of=pod_of).cross_pod_bytes == 8 * 4


def test_device_pod_map_prefers_slice_index():
    class Dev:
        def __init__(self, id, slice_index):
            self.id = id
            self.slice_index = slice_index

    devices = [Dev(0, 1), Dev(1, 0)]
    assert device_pod_map(devices, pod_size=64) == [1, 0]
