"""End-to-end multiscale gossip behavior (paper Thm 1, Thm 2, §VI)."""
import numpy as np
import pytest

from repro.core import (
    ExecOptions,
    FailureModel,
    multiscale_gossip,
    path_averaging,
    random_geometric_graph,
    relative_error,
    theorem2_bound,
)


@pytest.fixture(scope="module")
def ms_result(rgg500, x0_500):
    return multiscale_gossip(rgg500, x0_500, eps=1e-4, seed=0)


def test_error_within_theorem2_bound(ms_result, x0_500):
    # Thm 2: error <= sqrt(6) n eps w.h.p. (loose; typical runs are far
    # below — the point of the test is the guarantee, cf. eq. (2))
    assert ms_result.error(x0_500) <= theorem2_bound(500, 1e-4)


def test_all_levels_converged(ms_result):
    for lr in ms_result.levels:
        assert lr.converged_frac == 1.0


def test_message_and_send_accounting_agree(ms_result):
    # every single-hop transmission is attributed to exactly one sender
    assert ms_result.node_sends.sum() == ms_result.messages


def test_longest_route_scaling(ms_result):
    # paper: messages at the coarsest scale travel O(n^(1/3)) hops
    n = 500
    top = [lr for lr in ms_result.levels if lr.level == 1]
    assert top and top[0].max_hops <= 4 * n ** (1.0 / 3.0)


def test_rep_counts_bounded_by_levels(ms_result):
    assert ms_result.rep_counts.max() <= ms_result.partition.k
    assert ms_result.rep_counts.sum() > 0


def test_weighted_variant_is_exact(rgg500, x0_500):
    res = multiscale_gossip(rgg500, x0_500, eps=1e-4, seed=0, weighted=True)
    # exact-mass fusion: final error limited by per-level gossip eps, not
    # by cell-occupancy imbalance
    assert res.error(x0_500) <= 20 * 1e-4
    assert res.error(x0_500) <= theorem2_bound(500, 1e-4) / 10


def test_two_level_variant(rgg500, x0_500):
    res = multiscale_gossip(
        rgg500, x0_500, eps=1e-4, seed=0, weighted=True, k=2, a=0.5
    )
    assert res.partition.k == 2
    assert res.error(x0_500) <= 20 * 1e-4
    # paper §VI-B: with a=1/2 the longest route is O(n^(1/4)) hops
    top = [lr for lr in res.levels if lr.level == 1]
    assert top[0].max_hops <= 6 * 500 ** (1.0 / 4.0)


def test_fixed_iterations_variant(rgg500, x0_500):
    ideal = multiscale_gossip(rgg500, x0_500, eps=1e-4, seed=0, weighted=True)
    fi = multiscale_gossip(
        rgg500, x0_500, eps=1e-4, seed=0, weighted=True, fixed_ticks_scale=1.0
    )
    # FI spends more messages (paper §VI: redundant transmissions) but
    # still reaches the accuracy target
    assert fi.messages >= ideal.messages
    assert fi.error(x0_500) <= 20 * 1e-4


def test_beats_path_averaging(rgg500, x0_500):
    # paper Fig. 3: multiscale gossip uses noticeably fewer transmissions
    ms = multiscale_gossip(rgg500, x0_500, eps=1e-4, seed=0, weighted=True)
    pa = path_averaging(rgg500, x0_500, eps=1e-4, seed=0)
    assert pa.converged
    assert ms.messages < pa.messages


def test_message_loss_degrades_accuracy(rgg500, x0_500):
    lossy = multiscale_gossip(
        rgg500, x0_500, eps=1e-4, seed=0, weighted=True,
        failures=FailureModel(loss_p=0.9),
        options=ExecOptions(max_ticks_per_level=20_000),
    )
    reliable = multiscale_gossip(rgg500, x0_500, eps=1e-4, seed=0, weighted=True)
    # §VI-C-2: under message loss the accuracy target is unreachable
    assert lossy.error(x0_500) > reliable.error(x0_500)


def test_scaling_near_linear():
    # Thm 1: messages grow near-linearly; check the empirical exponent on
    # a small n-range stays well below the n^2/log n of standard gossip
    ns, msgs = [], []
    for n in (250, 500, 1000):
        g = random_geometric_graph(n, seed=n)
        x0 = np.random.default_rng(n).normal(0, 1, n)
        r = multiscale_gossip(g, x0, eps=1e-4, seed=0, weighted=True)
        ns.append(n)
        msgs.append(r.messages)
    slope = np.polyfit(np.log(ns), np.log(msgs), 1)[0]
    assert slope < 1.6, f"message scaling exponent {slope:.2f} too steep"
