"""Partition invariants (paper §III, §V)."""
import numpy as np
import pytest
from hypothesis_compat import given, strategies as st

from repro.core import auto_levels, build_partition, random_geometric_graph


def test_auto_levels_matches_paper_regime():
    # paper §VI-A: ~4-5 levels suffice for n = 5000
    assert auto_levels(5000) in (4, 5, 6)
    # slow growth: Theta(log log n)
    assert auto_levels(100) <= auto_levels(10_000) <= auto_levels(10_000_000)
    assert auto_levels(10_000_000) - auto_levels(100) <= 4
    assert auto_levels(5) == 1  # tiny network: single level


def test_sides_multiplicative_and_refining():
    p = build_partition(5000)
    assert p.sides[0] == 1
    for a, b in zip(p.sides, p.sides[1:]):
        assert b % a == 0 and b // a >= 2  # strict refinement


def test_cell_of_tiles_unit_square():
    p = build_partition(2000)
    coords = np.random.default_rng(0).uniform(0, 1, (2000, 2))
    for level in range(1, p.k + 1):
        c = p.cell_of(coords, level)
        assert c.min() >= 0 and c.max() < p.num_cells(level)
    # boundary coordinates clamp into range
    edge = np.array([[0.0, 0.0], [1.0, 1.0], [0.5, 1.0]])
    c = p.cell_of(edge, p.k)
    assert (c >= 0).all() and (c < p.num_cells(p.k)).all()


def test_parent_consistency():
    p = build_partition(3000)
    coords = np.random.default_rng(1).uniform(0, 1, (1000, 2))
    for level in range(2, p.k + 1):
        child = p.cell_of(coords, level)
        parent = p.cell_of(coords, level - 1)
        assert (p.parent_cell(level, child) == parent).all()


def test_child_grid_edges_adjacent_same_parent():
    p = build_partition(3000)
    for j in range(1, p.k):
        s = p.sides[j]  # child side
        edges = p.child_grid_edges(j)
        assert len(edges)
        r_u, c_u = edges[:, 0] // s, edges[:, 0] % s
        r_v, c_v = edges[:, 1] // s, edges[:, 1] % s
        manhattan = np.abs(r_u - r_v) + np.abs(c_u - c_v)
        assert (manhattan == 1).all()  # N/S/E/W adjacency
        assert (
            p.parent_cell(j + 1, edges[:, 0]) == p.parent_cell(j + 1, edges[:, 1])
        ).all()


def test_cell_centers_inside_cells():
    p = build_partition(1500)
    cells = np.arange(p.num_cells(p.k))
    centers = p.cell_center(p.k, cells)
    assert (p.cell_of(centers, p.k) == cells).all()


@given(
    n=st.integers(min_value=10, max_value=500_000),
    a=st.floats(min_value=0.55, max_value=0.8),
)
def test_partition_properties(n, a):
    p = build_partition(n, a=a)
    assert p.k >= 1 and p.sides[0] == 1
    # finest cells stay small (bounded occupancy, paper Thm 1 part 2);
    # rounding of split factors makes this approximate
    assert p.expected_cell_size(p.k) <= 4 * 8.0
    # and never degenerate below a fraction of a node on average
    assert p.expected_cell_size(p.k) > 0.1


def test_paper_scaling_of_finest_cells():
    # subnetworks at scale j hold O(n^((2/3)^j)) nodes: check the finest
    # level against the closed form within rounding slack
    for n in (1000, 5000, 20000):
        p = build_partition(n)
        expected = n ** ((2.0 / 3.0) ** (p.k - 1))
        assert p.expected_cell_size(p.k) <= 6 * expected
