"""Wireless-medium cost model + failure-scenario matrix + the unified
ExecOptions/FailureModel/CostModel API (core.medium / core.options /
core.scenarios).

The load-bearing invariants:

* cost pricing is a pure reduction over the presampled schedule — the
  exchange trajectory (x / usage / messages) is bitwise-identical with
  the CostModel on or off;
* sampled Geometric retransmissions agree with the closed form
  ``T * (1-p)/p`` in expectation, and the closed-form mode returns it
  exactly;
* hop-distance pricing matches the independent route-incidence total
  ``sum(usage * 2 * hops)`` computed from the plan CSR;
* the deprecated flat kwargs warn and produce bitwise-identical
  EngineResults to the options=/failures= call form;
* scenarios perturb the replayed schedule in the physically sensible
  direction (churn reduces messages, Byzantine nodes keep their values).
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    CostModel,
    ExecOptions,
    FailureModel,
    build_plan,
    execute_plan,
    expected_retransmissions,
    multiscale_gossip,
    price_messages,
    level_edge_messages,
    price_edge_messages,
    random_geometric_graph,
    route_edge_transmissions,
    run_scenario_matrix,
    scenario_matrix,
)
from repro.core.medium import failure_sets

N = 160
SEEDS = (0, 1)


@pytest.fixture(scope="module")
def setup():
    g = random_geometric_graph(N, seed=5)
    plan = build_plan(g, k=2, seed=0)
    x0 = np.random.default_rng(2).normal(0, 1, N).astype(np.float32)
    return g, plan, x0


def _run(plan, x0, **kw):
    kw.setdefault("eps", 1e-3)
    kw.setdefault("seeds", SEEDS)
    kw.setdefault("fixed_ticks_scale", 0.25)
    return execute_plan(plan, x0, **kw)


def test_cost_pricing_is_bitwise_neutral(setup):
    g, plan, x0 = setup
    base = _run(plan, x0, options=ExecOptions(collect_usage=True))
    priced = _run(
        plan, x0, options=ExecOptions(collect_usage=True),
        cost=CostModel(retransmit_p=0.7, congestion_alpha=0.2),
    )
    assert np.array_equal(base.x_final, priced.x_final)
    assert np.array_equal(base.messages, priced.messages)
    assert np.array_equal(base.node_sends, priced.node_sends)
    for u0, u1 in zip(base.edge_usage, priced.edge_usage):
        assert np.array_equal(u0, u1)
    assert base.cost is None
    assert priced.cost is not None
    assert np.array_equal(
        priced.cost.transmissions, base.messages.astype(np.float64))


def test_sampled_retransmissions_match_geometric_mean(setup):
    g, plan, x0 = setup
    p = 0.6
    # many trials, one schedule each: the per-trial sampled extras
    # should concentrate on T*(1-p)/p within a few percent
    seeds = tuple(range(24))
    res = _run(plan, x0, seeds=seeds, cost=CostModel(retransmit_p=p))
    want = expected_retransmissions(res.messages, p)
    got = res.cost.retransmissions
    assert np.all(got >= 0)
    rel = abs(got.mean() - want.mean()) / want.mean()
    assert rel < 0.05, (got.mean(), want.mean())


def test_closed_form_mode_is_exact(setup):
    g, plan, x0 = setup
    p = 0.8
    res = _run(plan, x0, cost=CostModel(retransmit_p=p, sample=False))
    np.testing.assert_allclose(
        res.cost.retransmissions,
        expected_retransmissions(res.messages, p),
    )
    # energy identity: hop_energy * (logical + retx) with no congestion
    np.testing.assert_allclose(
        res.cost.energy,
        res.cost.transmissions + res.cost.retransmissions,
    )


def test_hop_pricing_matches_route_incidence_totals(setup):
    """The engine's logical message count IS the route-priced total:
    sum over directed-edge slots of usage * 2 * hops (forward + reply
    legs), independently recomputed from the plan CSR."""
    g, plan, x0 = setup
    res = _run(plan, x0, options=ExecOptions(collect_usage=True))
    for li, (lp, usage) in enumerate(zip(plan.levels, res.edge_usage)):
        hops = np.asarray(lp.hop_flat, np.int64)
        for t in range(len(SEEDS)):
            priced = int((usage[t].astype(np.int64) * 2 * hops).sum())
            assert priced == int(res.level_messages[t, li]), (li, t)


def test_congestion_counts_concurrent_pairs(setup):
    """congestion_alpha scales a pure tally: doubling alpha doubles the
    congestion term and nothing else."""
    g, plan, x0 = setup
    a = _run(plan, x0, cost=CostModel(congestion_alpha=0.1, sample=False))
    b = _run(plan, x0, cost=CostModel(congestion_alpha=0.2, sample=False))
    np.testing.assert_allclose(2 * a.cost.congestion, b.cost.congestion)
    np.testing.assert_allclose(
        b.cost.energy - a.cost.energy, a.cost.congestion)


def test_price_messages_supersedes_handshake_cost():
    from repro.core import handshake_cost

    msgs = 10_000
    p = 0.5
    rng_a = np.random.default_rng(7)
    rng_b = np.random.default_rng(7)
    legacy = handshake_cost(msgs, p, rng_a)
    c = price_messages(msgs, CostModel(retransmit_p=p), rng_b)
    assert int(c.physical_transmissions[0]) == legacy
    exact = price_messages(msgs, CostModel(retransmit_p=p, sample=False))
    assert float(exact.retransmissions[0]) == msgs * (1 - p) / p


def test_legacy_flat_kwargs_removed(setup):
    """PR 9's one-release deprecation window has expired: the flat
    execute kwargs are gone, and a stale call fails loudly as a
    TypeError instead of silently warning."""
    g, plan, x0 = setup
    with pytest.raises(TypeError):
        _run(plan, x0, backend="lax", check_every=32)
    with pytest.raises(TypeError):
        _run(plan, x0, loss_p=0.9)
    with pytest.raises(TypeError):
        multiscale_gossip(
            g, x0, eps=1e-3, seed=0, trials=2, plan=plan, backend="lax",
        )


def test_multiscale_gossip_threads_options(setup):
    """options= reaches the engine: the explicit default matches the
    no-options call bitwise."""
    g, plan, x0 = setup
    new = multiscale_gossip(
        g, x0, eps=1e-3, seed=0, trials=2, plan=plan,
        options=ExecOptions(backend="lax"),
    )
    default = multiscale_gossip(g, x0, eps=1e-3, seed=0, trials=2, plan=plan)
    assert np.array_equal(new.x_final, default.x_final)
    assert np.array_equal(new.messages, default.messages)


def test_scenario_and_cost_require_presampled(setup):
    g, plan, x0 = setup
    with pytest.raises(ValueError, match="presampled"):
        _run(plan, x0, options=ExecOptions(schedule="per_tick"),
             cost=CostModel())
    with pytest.raises(ValueError, match="presampled"):
        _run(plan, x0, options=ExecOptions(schedule="per_tick"),
             failures=FailureModel(churn_fraction=0.1))


def test_churn_reduces_messages_and_degrades_error(setup):
    g, plan, x0 = setup
    base = _run(plan, x0)
    churned = _run(
        plan, x0,
        failures=FailureModel(churn_fraction=0.25, churn_time=0.25),
    )
    assert np.all(churned.messages < base.messages)
    assert churned.error(x0).mean() > base.error(x0).mean()


def test_byzantine_nodes_keep_initial_values(setup):
    """drop_fraction nodes never apply updates: their final estimate is
    exactly their initial value (V=1: the raw x0 entry)."""
    g, plan, x0 = setup
    fm = FailureModel(drop_fraction=0.2, seed=3)
    res = _run(plan, x0, failures=fm)
    byz = failure_sets(fm, N)["byz"]
    assert byz.sum() > 0
    # unweighted runs promote raw values, so a frozen node stays at x0
    np.testing.assert_array_equal(
        res.x_final[:, byz], np.broadcast_to(x0[byz], (len(SEEDS),
                                                       int(byz.sum()))))


def test_failure_sets_draw_order_is_stable():
    """Adding one scenario field must not reshuffle another's node set."""
    a = failure_sets(FailureModel(churn_fraction=0.2), 200)
    b = failure_sets(
        FailureModel(churn_fraction=0.2, drop_fraction=0.1), 200)
    np.testing.assert_array_equal(a["churned"], b["churned"])


def test_scenario_matrix_smoke(setup):
    g, plan, x0 = setup
    res = run_scenario_matrix(
        g, x0, scenario_matrix(), eps=1e-3, trials=2, seed=0,
        fixed_ticks_scale=0.25, plan=plan,
        cost=CostModel(retransmit_p=0.9),
    )
    names = [r.scenario.name for r in res]
    assert names == ["baseline", "churn", "stragglers", "regional",
                     "byzantine"]
    by = {r.scenario.name: r for r in res}
    for r in res:
        assert r.errors.shape == (2,)
        assert r.cost is not None and np.all(r.cost.energy > 0)
    # events hurt: every scenario is at least as bad as the baseline
    assert by["churn"].err_mean > by["baseline"].err_mean
    assert by["byzantine"].err_mean > by["baseline"].err_mean
    # eps-oracle mode rejects (event times are budget fractions)
    with pytest.raises(ValueError, match="fixed_ticks_scale"):
        run_scenario_matrix(g, x0, fixed_ticks_scale=0.0, plan=plan)


def test_scenario_executor_cache_keys_on_tick_budget(setup):
    """Scenario event ticks are baked into the trace as constants
    derived from maxt_levels, so a plan whose executor cache was primed
    at one fixed_ticks_scale must retrace — not silently reuse stale
    event times — when replayed at another budget (regression: the
    cache key used to omit maxt_levels)."""
    g, plan, x0 = setup
    fm = FailureModel(churn_fraction=0.25, churn_time=0.25)
    # fresh plan: the ground truth for the full-budget scenario run
    fresh = build_plan(g, k=2, seed=0)
    want = _run(fresh, x0, fixed_ticks_scale=1.0, failures=fm)
    # primed plan: a quarter-budget run populates the executor cache
    # with event ticks scaled to ITS maxt_levels first
    _run(plan, x0, fixed_ticks_scale=0.25, failures=fm)
    got = _run(plan, x0, fixed_ticks_scale=1.0, failures=fm)
    assert np.array_equal(want.x_final, got.x_final)
    assert np.array_equal(want.messages, got.messages)


def test_scenarios_reject_eps_oracle_mode(setup):
    """execute_plan itself (not just run_scenario_matrix) rejects
    scenario FailureModels in eps-oracle mode, where event times become
    fractions of the unbounded max_ticks_per_level cap and the scenario
    silently degenerates to the reliable run."""
    g, plan, x0 = setup
    with pytest.raises(ValueError, match="fixed_ticks_scale"):
        _run(plan, x0, fixed_ticks_scale=0.0,
             failures=FailureModel(churn_fraction=0.1))
    # loss_p alone is the legacy trajectory-level model, not a scenario:
    # it stays valid in eps-oracle mode
    _run(plan, x0, fixed_ticks_scale=0.0, eps=1e-2,
         failures=FailureModel(loss_p=0.9))


def test_price_messages_requires_rng_when_sampling():
    with pytest.raises(ValueError, match="rng"):
        price_messages(100, CostModel(retransmit_p=0.5))
    # no draws happen at p=1 or with sample=False: rng stays optional
    assert price_messages(
        100, CostModel(retransmit_p=1.0)).retransmissions[0] == 0.0
    price_messages(100, CostModel(retransmit_p=0.5, sample=False))


def test_regional_window_coerced_and_validated():
    # lists (natural from JSON configs) coerce to a hashable tuple
    fm = FailureModel(regional_radius=0.2, regional_window=[0.25, 0.75])
    assert fm.regional_window == (0.25, 0.75)
    hash(fm)
    with pytest.raises(ValueError, match="regional_window"):
        FailureModel(regional_window=(0.75, 0.25))
    with pytest.raises(ValueError, match="regional_window"):
        FailureModel(regional_window=(-0.1, 0.5))
    with pytest.raises(ValueError, match="regional_window"):
        FailureModel(regional_window=(0.25,))


# ----------------- heterogeneous per-link loss/energy ------------------


def _overlay_edge_messages(plan, res, trial=0):
    """(lp, per-edge logical transmissions) for every overlay level."""
    out = []
    for li, lp in enumerate(plan.levels):
        if lp.kind != "overlay":
            continue
        out.append((lp, level_edge_messages(lp, res.edge_usage[li][trial])))
    assert out
    return out


def test_route_edge_transmissions_is_two_hops(setup):
    """The incidence scatter independently reproduces 2 * route hops
    per exchange (endpoints once, relays twice)."""
    g, plan, x0 = setup
    for lp in plan.levels:
        if lp.kind != "overlay":
            continue
        tx = route_edge_transmissions(lp)
        hops = np.asarray(lp.hop_flat, np.int64)[lp.edge_pos_i]
        np.testing.assert_array_equal(tx, 2 * hops)


def test_per_edge_messages_sum_to_level_total(setup):
    """Summing the per-edge breakdown recovers the engine's per-level
    logical message count exactly."""
    g, plan, x0 = setup
    res = _run(plan, x0, options=ExecOptions(collect_usage=True))
    for li, lp in enumerate(plan.levels):
        if lp.kind != "overlay":
            continue
        for t in range(len(SEEDS)):
            em = level_edge_messages(lp, res.edge_usage[li][t])
            assert int(em.sum()) == int(res.level_messages[t, li]), (li, t)


def test_per_edge_pricing_constant_tuple_matches_scalar(setup):
    """Parity: a constant per-edge tuple prices identically to the
    scalar model, and both match the homogeneous `price_messages` path
    on the summed count (with loss folded into the delivery p)."""
    g, plan, x0 = setup
    res = _run(plan, x0, options=ExecOptions(collect_usage=True))
    lp, em = _overlay_edge_messages(plan, res)[0]
    E = len(em)
    hop, retx_p, loss = 1.5, 0.8, 0.9
    scalar = price_edge_messages(
        em, CostModel(hop_energy=hop, retransmit_p=retx_p, sample=False),
        FailureModel(loss_p=loss),
    )
    tupled = price_edge_messages(
        em,
        CostModel(hop_energy=(hop,) * E, retransmit_p=retx_p, sample=False),
        FailureModel(loss_p=(loss,) * E),
    )
    np.testing.assert_allclose(tupled.energy, scalar.energy)
    np.testing.assert_allclose(tupled.retransmissions, scalar.retransmissions)
    np.testing.assert_array_equal(tupled.transmissions, scalar.transmissions)
    homo = price_messages(
        int(em.sum()),
        CostModel(hop_energy=hop, retransmit_p=retx_p * loss, sample=False),
    )
    np.testing.assert_allclose(scalar.energy, homo.energy, rtol=1e-12)


def test_per_edge_heterogeneity_is_local(setup):
    """Doubling ONE edge's hop_energy adds exactly that edge's base
    energy — per-edge pricing is a local, decomposable sum."""
    g, plan, x0 = setup
    res = _run(plan, x0, options=ExecOptions(collect_usage=True))
    lp, em = _overlay_edge_messages(plan, res)[0]
    e = int(np.argmax(em))
    assert em[e] > 0
    base = price_edge_messages(
        em, CostModel(hop_energy=(1.0,) * len(em), sample=False))
    he = [1.0] * len(em)
    he[e] = 2.0
    bumped = price_edge_messages(
        em, CostModel(hop_energy=tuple(he), sample=False))
    np.testing.assert_allclose(
        bumped.energy - base.energy, base.level_energy[:, e])


def test_heterogeneous_models_are_closed_form_only(setup):
    """Per-edge tuples coerce/hash like regional_window, but every
    schedule-level consumer rejects them with a pointer at the
    closed-form path."""
    g, plan, x0 = setup
    fm = FailureModel(loss_p=[0.9, 0.8])        # list coerces to tuple
    cm = CostModel(hop_energy=[1.0, 2.0], sample=False)
    assert fm.loss_p == (0.9, 0.8) and fm.heterogeneous
    assert cm.hop_energy == (1.0, 2.0) and cm.heterogeneous
    hash((fm, cm))
    with pytest.raises(ValueError, match="price_edge_messages"):
        _run(plan, x0, failures=fm)
    with pytest.raises(ValueError, match="price_edge_messages"):
        _run(plan, x0, cost=cm)
    with pytest.raises(ValueError, match="price_edge_messages"):
        price_messages(100, cm)
    # per-edge sampling has no schedule: sample=True models are rejected
    with pytest.raises(ValueError, match="sample"):
        price_edge_messages(
            np.ones(2, np.int64), CostModel(hop_energy=(1.0, 2.0),
                                            retransmit_p=0.5))
    # entry validation mirrors the scalar paths
    with pytest.raises(ValueError, match="loss_p"):
        FailureModel(loss_p=(0.9, 0.0))
    with pytest.raises(ValueError):
        CostModel(hop_energy=(1.0, -2.0))
    with pytest.raises(ValueError, match="edges"):
        price_edge_messages(
            np.ones(3, np.int64),
            CostModel(hop_energy=(1.0, 2.0), sample=False))


def test_dataclass_validation():
    with pytest.raises(ValueError):
        CostModel(retransmit_p=0.0)
    with pytest.raises(ValueError):
        CostModel(hop_energy=-1.0)
    with pytest.raises(ValueError):
        FailureModel(churn_fraction=1.5)
    with pytest.raises(ValueError):
        FailureModel(loss_p=0.0)
    with pytest.raises(ValueError):
        ExecOptions(backend="cuda")
    with pytest.raises(ValueError):
        ExecOptions(schedule="sometimes")
    # all three are hashable (compiled-executor cache keys)
    hash((ExecOptions(), FailureModel(), CostModel()))
