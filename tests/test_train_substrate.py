"""Optimizers, data determinism, checkpoint/restart, gossip grad-sync."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config, reduce_config
from repro.data import SyntheticLM
from repro.dist import (
    CompressionConfig, SyncConfig, build_sync_plan, execute_sync,
    init_residual, suggest_levels, sync_gradients,
)
from repro.models import Transformer
from repro.optim import (
    adafactor, adamw, apply_updates, clip_by_global_norm, cosine_schedule,
    global_norm, sgdm,
)
from repro.train import (
    Trainer, consensus_distance, init_decentralized_state, init_train_state,
    make_decentralized_step, make_train_step, restore_checkpoint,
    save_checkpoint, latest_step,
)

# ----------------------------- optimizers -----------------------------


def _quadratic_params():
    return {"w": jnp.array([3.0, -2.0, 1.5]), "b": jnp.zeros((2, 4))}


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_descend_quadratic(opt_name):
    opt = {"adamw": adamw(), "adafactor": adafactor(), "sgdm": sgdm()}[opt_name]
    params = _quadratic_params()
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)
    l0 = loss(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, 0.05)
        params = apply_updates(params, upd)
    assert loss(params) < 0.05 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"w": jnp.zeros((64, 32)), "v1": jnp.zeros((16,))}
    st_ = opt.init(p)
    assert st_["v"]["w"]["vr"].shape == (64,)
    assert st_["v"]["w"]["vc"].shape == (32,)
    assert st_["v"]["v1"]["v"].shape == (16,)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    assert float(norm) > 100.0


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(10)), 1e-3, rtol=1e-5)
    assert float(lr(100)) < 1e-5


# ------------------------------- data ---------------------------------


def test_data_deterministic_per_step():
    d = SyntheticLM(vocab_size=100, seq_len=32, global_batch=4, seed=1)
    a, b = d.batch_at(7), d.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = d.batch_at(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


# ----------------------------- checkpoint ------------------------------


def test_checkpoint_roundtrip_and_retention(tmp_path):
    state = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3)},
        "opt": {"m": jnp.ones((2, 3)), "count": jnp.array(5, jnp.int32)},
        "step": jnp.array(5, jnp.int32),
    }
    d = str(tmp_path / "ck")
    for s in (1, 2, 3, 4):
        save_checkpoint(d, state, s, keep_n=2)
    assert latest_step(d) == 4
    from repro.train.checkpoint import list_steps
    assert list_steps(d) == [3, 4]
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = restore_checkpoint(d, like)
    assert step == 4
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_trainer_failure_recovery(tmp_path):
    """Kill training mid-run, restart, verify bitwise-identical final
    state vs an uninterrupted run (checkpoint/restart requirement)."""
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    opt = adamw()
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=2, seed=3)
    lr = lambda s: 1e-3

    def fresh_state():
        return init_train_state(model.init(jax.random.PRNGKey(0)), opt)

    step_fn = make_train_step(cfg, opt, lr, dp=None)

    # uninterrupted reference
    t_ref = Trainer(step_fn, fresh_state(), data)
    ref = t_ref.run(8)

    d = str(tmp_path / "ck")
    t1 = Trainer(step_fn, fresh_state(), data, ckpt_dir=d, save_every=2,
                 fail_at_step=5)
    with pytest.raises(RuntimeError, match="injected failure"):
        t1.run(8)
    assert latest_step(d) == 4
    # restart: auto-resumes from step 4
    t2 = Trainer(step_fn, fresh_state(), data, ckpt_dir=d, save_every=2)
    t2.run(8)
    final_ref = np.asarray(t_ref.state["params"]["embed"], np.float32)
    final_rec = np.asarray(t2.state["params"]["embed"], np.float32)
    np.testing.assert_array_equal(final_ref, final_rec)
    assert abs(ref[-1]["loss"] - t2.metrics_history[-1]["loss"]) < 1e-5


def test_elastic_restore_dtype_cast(tmp_path):
    state = {"w": jnp.ones((4, 4), jnp.float32)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, state, 1)
    like = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    restored, _ = restore_checkpoint(d, like)
    assert restored["w"].dtype == jnp.bfloat16


# ------------------------- gossip grad sync ---------------------------


def _fake_grads(R, key=0):
    rng = np.random.default_rng(key)
    return {
        "a": jnp.asarray(rng.normal(size=(R, 8, 16)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(R, 32)), jnp.float32),
    }


def test_suggest_levels_products():
    for R in (2, 4, 8, 16, 32, 64, 512):
        lv = suggest_levels(R)
        assert int(np.prod(lv)) == R, (R, lv)
    assert len(suggest_levels(512)) >= 3  # multiscale, not flat


@pytest.mark.parametrize("strategy", ["allreduce", "hierarchical"])
def test_exact_strategies_give_global_mean(strategy):
    R = 16
    g = _fake_grads(R)
    out = sync_gradients(g, SyncConfig(strategy=strategy), R)
    for k in g:
        want = np.broadcast_to(np.asarray(g[k]).mean(0, keepdims=True), g[k].shape)
        np.testing.assert_allclose(np.asarray(out[k]), want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("strategy,kw", [
    ("ring", dict(rounds=(64,))),
    ("multiscale", dict()),
    ("multiscale", dict(exact_fusion=True)),
])
def test_gossip_strategies_preserve_mean_and_mix(strategy, kw):
    R = 16
    g = _fake_grads(R)
    cfg = SyncConfig(strategy=strategy, **kw)
    out = sync_gradients(g, cfg, R)
    for k in g:
        a, b = np.asarray(g[k], np.float64), np.asarray(out[k], np.float64)
        if strategy != "multiscale" or kw.get("exact_fusion"):
            # doubly-stochastic mixing preserves the replica-mean exactly
            np.testing.assert_allclose(b.mean(0), a.mean(0), rtol=1e-4, atol=1e-5)
        # disagreement shrinks substantially
        before = np.linalg.norm(a - a.mean(0, keepdims=True))
        after = np.linalg.norm(b - b.mean(0, keepdims=True))
        assert after < 0.35 * before, (strategy, after / before)


@given(r_log=st.integers(1, 5), seed=st.integers(0, 100))
@settings(max_examples=10)
def test_property_multiscale_consensus_error_bounded(r_log, seed):
    """Multiscale gossip with rep-promotion: result stays in the convex
    hull of inputs and approaches the mean (paper Thm 2 analogue)."""
    R = 2 ** r_log
    rng = np.random.default_rng(seed)
    g = {"x": jnp.asarray(rng.normal(size=(R, 6)), jnp.float32)}
    out = sync_gradients(g, SyncConfig(strategy="multiscale"), R)["x"]
    x = np.asarray(g["x"])
    assert np.asarray(out).min() >= x.min() - 1e-5
    assert np.asarray(out).max() <= x.max() + 1e-5


# ------------------- compressed / rotated execute_sync ------------------


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compressed_multiscale_reaches_uncompressed_fixed_point(scheme):
    """Iterated compressed sync (error feedback threaded through) drives
    consensus distance to the same fixed point as uncompressed — replicas
    agree — while the replica mean of (value + residual) is conserved
    (no gradient mass lost to compression)."""
    R = 8
    rng = np.random.default_rng(2)
    x0 = {"x": jnp.asarray(rng.normal(size=(R, 40)), jnp.float32)}
    mass0 = np.asarray(x0["x"]).mean(0)

    def run(comp):
        plan = build_sync_plan(
            SyncConfig("multiscale", exact_fusion=True, compression=comp), R
        )
        x, r = x0, init_residual(x0)
        for t in range(40):
            x, r = execute_sync(plan, x, r, t)
        return np.asarray(x["x"]), np.asarray(r["x"])

    x_ref, _ = run(CompressionConfig("none"))
    x_c, r_c = run(CompressionConfig(scheme, topk_fraction=0.25))
    for x in (x_ref, x_c):
        spread = np.abs(x - x.mean(0, keepdims=True)).max()
        assert spread < 1e-5, spread  # both at the consensus fixed point
    # EF conservation through the whole trajectory: value + residual mass
    np.testing.assert_allclose(
        (x_c + r_c).mean(0), mass0, rtol=1e-4, atol=1e-5
    )
    if scheme == "int8":  # tight quantization => near the exact mean too
        np.testing.assert_allclose(x_c.mean(0), mass0, atol=5e-2)


def test_rotated_multiscale_preserves_mean_every_step():
    """Randomized cells (rotation schedule): conjugating the exact-fusion
    mix by a permutation preserves the exact replica mean at EVERY step."""
    R = 16
    rng = np.random.default_rng(4)
    g = {"x": jnp.asarray(rng.normal(size=(R, 24)), jnp.float32)}
    want = np.asarray(g["x"]).mean(0)
    plan = build_sync_plan(
        SyncConfig("multiscale", exact_fusion=True, rotation_period=5,
                   rotation_seed=3), R,
    )
    assert plan.rotated
    for step in range(8):
        out, _ = execute_sync(plan, g, None, step)
        got = np.asarray(out["x"])
        np.testing.assert_allclose(got.mean(0), want, rtol=1e-5, atol=1e-6)
        # exact fusion: every replica holds the (grouped-ladder) mean —
        # identical across replicas bitwise, equal to the direct mean up
        # to f32 summation-order rounding
        np.testing.assert_array_equal(got, np.broadcast_to(got[0], got.shape))
        np.testing.assert_allclose(
            got, np.broadcast_to(want, got.shape), rtol=1e-5, atol=1e-6
        )


def test_rotation_deterministic_in_seed_and_step():
    R = 8
    rng = np.random.default_rng(5)
    g = {"x": jnp.asarray(rng.normal(size=(R, 12)), jnp.float32)}
    cfg = SyncConfig("multiscale", rotation_period=4, rotation_seed=9)
    a, _ = execute_sync(build_sync_plan(cfg, R), g, None, 2)
    b, _ = execute_sync(build_sync_plan(cfg, R), g, None, 2)
    np.testing.assert_array_equal(np.asarray(a["x"]), np.asarray(b["x"]))
    # a different step picks a different cell assignment (plain multiscale
    # promotion makes the assignment visible in the output)
    c, _ = execute_sync(build_sync_plan(cfg, R), g, None, 3)
    assert not np.array_equal(np.asarray(a["x"]), np.asarray(c["x"]))
    # ... and the schedule wraps: step t and t + period coincide
    d, _ = execute_sync(build_sync_plan(cfg, R), g, None, 7)
    np.testing.assert_array_equal(np.asarray(c["x"]), np.asarray(d["x"]))


def test_exact_strategies_bitwise_stable_through_plan_execute():
    """allreduce/hierarchical with scheme='none' must produce exactly what
    the direct mean/grouped-mean ladder produces (the pre-plan output)."""
    R = 16
    g = _fake_grads(R)
    lv = suggest_levels(R)

    def pre_refactor(a, strat):  # the seed implementation, verbatim jnp ops
        if strat == "allreduce":
            return jnp.broadcast_to(jnp.mean(a, axis=0, keepdims=True), a.shape)
        x = a.reshape(lv + a.shape[1:])
        for ax in range(len(lv) - 1, -1, -1):
            x = jnp.mean(x, axis=ax, keepdims=True)
        return jnp.broadcast_to(x, lv + a.shape[1:]).reshape(a.shape)

    for strat in ("allreduce", "hierarchical"):
        out = sync_gradients(g, SyncConfig(strategy=strat), R)
        for k in g:
            want = pre_refactor(g[k], strat)
            np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(want))


# ----------------------- decentralized training -----------------------


def test_decentralized_training_runs_and_converges_consensus():
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    R = 4
    opt = sgdm()
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), base
    )
    state = init_decentralized_state(params_r, opt)
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=R * 2, seed=5)
    sync = SyncConfig(strategy="multiscale")
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 1e-2, sync, R))
    losses = []
    for s in range(6):
        b = data.batch_at(s)
        batch = {
            k: jnp.asarray(v.reshape(R, 2, *v.shape[1:])) for k, v in b.items()
        }
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    # replicas stay near consensus (gossip holds them together)
    assert float(m["consensus_distance"]) < 1e-2
    assert losses[-1] < losses[0] + 0.5  # training is stable
    # the step reports the plan's modeled per-sync traffic
    assert float(m["wire_bytes"]) > 0


def test_decentralized_training_compressed_rotated():
    """End-to-end: topk-compressed multiscale sync with randomized-cell
    rotation — residual state threads through the train step, consensus
    holds, and the wire-byte metric reflects the compression ratio."""
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    R = 4
    opt = sgdm()
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (R,) + p.shape), base
    )
    sync = SyncConfig(
        strategy="multiscale", exact_fusion=True,
        compression=CompressionConfig("topk", topk_fraction=0.25),
        rotation_period=3,
    )
    dense = SyncConfig(strategy="multiscale", exact_fusion=True)
    state = init_decentralized_state(params_r, opt, sync=sync)
    assert "residuals" in state
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=R * 2, seed=5)
    step = jax.jit(make_decentralized_step(cfg, opt, lambda s: 1e-2, sync, R))
    for s in range(4):
        b = data.batch_at(s)
        batch = {
            k: jnp.asarray(v.reshape(R, 2, *v.shape[1:])) for k, v in b.items()
        }
        state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
    # error feedback actually accumulated (unsent mass lives in residuals)
    res_norm = float(global_norm(state["residuals"]))
    assert res_norm > 0
    assert float(m["consensus_distance"]) < 5e-2
    # wire metric: topk(0.25) ships (value, index) pairs => 0.5x dense
    from repro.dist import plan_wire_bytes
    ratio = plan_wire_bytes(build_sync_plan(sync, R), params_r) / plan_wire_bytes(
        build_sync_plan(dense, R), params_r
    )
    assert ratio == pytest.approx(0.5)


def test_compressed_step_without_residual_state_raises():
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    R = 4
    opt = sgdm()
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (R,) + p.shape),
        model.init(jax.random.PRNGKey(0)),
    )
    sync = SyncConfig(strategy="multiscale", compression="int8")
    state = init_decentralized_state(params_r, opt)  # no sync= passed
    step = make_decentralized_step(cfg, opt, lambda s: 1e-2, sync, R)
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=R * 2, seed=5)
    b = data.batch_at(0)
    batch = {k: jnp.asarray(v.reshape(R, 2, *v.shape[1:])) for k, v in b.items()}
    with pytest.raises(ValueError, match="init_decentralized_state"):
        step(state, batch)
