"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on
CPU; the BlockSpecs target TPU v5e VMEM)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cell_mixing import (
    cell_mixing, cell_mixing_pallas, cell_mixing_ref, mixing_matrix, pad_mixing,
)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.rwkv6 import rwkv6_ref, rwkv6_wkv

# ----------------------------- cell mixing -----------------------------


def _random_mixing(rng, B, m):
    """Random symmetric doubly-stochastic matrices (Metropolis on a
    random graph)."""
    w = np.zeros((B, m, m), np.float32)
    for b in range(B):
        adj = rng.uniform(size=(m, m)) < 0.4
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        deg = adj.sum(1)
        for i in range(m):
            for j in range(m):
                if adj[i, j]:
                    w[b, i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        np.fill_diagonal(w[b], 1.0 - w[b].sum(1))
    return w


@pytest.mark.parametrize("B,m,d", [(1, 8, 128), (3, 16, 256), (2, 40, 384)])
@pytest.mark.parametrize("rounds", [1, 4])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cell_mixing_matches_ref(B, m, d, rounds, dtype):
    rng = np.random.default_rng(B * 100 + m + rounds)
    w = jnp.asarray(_random_mixing(rng, B, m))
    x = jnp.asarray(rng.normal(size=(B, m, d)), dtype)
    got = cell_mixing(w, x, rounds=rounds, use_pallas=True, interpret=True)
    want = cell_mixing(w, x, rounds=rounds, use_pallas=False)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_cell_mixing_preserves_mass_and_consensus():
    rng = np.random.default_rng(0)
    B, m, d = 2, 16, 128
    w = jnp.asarray(_random_mixing(rng, B, m))
    x = jnp.asarray(rng.normal(size=(B, m, d)), jnp.float32)
    y = cell_mixing(w, x, rounds=64, use_pallas=True, interpret=True)
    # doubly stochastic: per-cell column sums (mass) preserved
    np.testing.assert_allclose(
        np.asarray(y.sum(1)), np.asarray(x.sum(1)), rtol=1e-4, atol=1e-4
    )
    # many rounds => consensus at the cell average
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x.mean(1, keepdims=True) * jnp.ones_like(x)),
        rtol=1e-2, atol=1e-2,
    )


def test_mixing_matrix_from_graph_is_doubly_stochastic():
    from repro.core import batched_graphs, random_geometric_graph

    g = random_geometric_graph(40, seed=5)
    neighbors, degrees, n_nodes, _ = batched_graphs([g])
    w = mixing_matrix(neighbors, degrees, n_nodes)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-6)
    np.testing.assert_allclose(w.sum(2), 1.0, atol=1e-6)
    np.testing.assert_allclose(w[0], w[0].T, atol=1e-7)


def test_pad_mixing_identity_extension():
    rng = np.random.default_rng(1)
    w = jnp.asarray(_random_mixing(rng, 1, 5))
    x = jnp.asarray(rng.normal(size=(1, 5, 7)), jnp.float32)
    wp, xp, (m, d) = pad_mixing(w, x)
    assert wp.shape[1] % 8 == 0 and xp.shape[2] % 128 == 0
    np.testing.assert_allclose(np.asarray(wp.sum(1)), 1.0, atol=1e-6)


# --------------------------- flash attention ---------------------------


@pytest.mark.parametrize(
    "B,Hq,Hkv,S,D",
    [(1, 2, 2, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 128, 128)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, Hq, Hkv, S, D, dtype):
    rng = np.random.default_rng(S + Hq)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    got = flash_attention(
        q, k, v, causal=True, block_q=128, block_k=128,
        use_pallas=True, interpret=True,
    )
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(window)
    B, H, S, D = 1, 2, 384, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) for _ in range(3))
    got = flash_attention(
        q, k, v, causal=True, window=window, block_q=128, block_k=128,
        use_pallas=True, interpret=True,
    )
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    rng = np.random.default_rng(9)
    B, H, S, D = 1, 2, 256, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) for _ in range(3))
    got = flash_attention(
        q, k, v, causal=True, softcap=30.0, block_q=128, block_k=128,
        use_pallas=True, interpret=True,
    )
    want = attention_ref(q, k, v, causal=True, softcap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_unaligned_seq_padding():
    rng = np.random.default_rng(11)
    B, H, S, D = 1, 2, 200, 64  # not a block multiple
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) for _ in range(3))
    got = flash_attention(
        q, k, v, causal=True, block_q=128, block_k=128,
        use_pallas=True, interpret=True,
    )
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(13)
    B, H, S, D = 1, 2, 256, 64
    q, k, v = (jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32) for _ in range(3))
    got = flash_attention(
        q, k, v, causal=False, block_q=128, block_k=128,
        use_pallas=True, interpret=True,
    )
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ------------------------------- rwkv6 --------------------------------


@pytest.mark.parametrize("BH,T,N", [(2, 64, 32), (1, 130, 64), (3, 96, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_matches_ref(BH, T, N, dtype):
    rng = np.random.default_rng(BH * T)
    r = jnp.asarray(rng.normal(size=(BH, T, N)), dtype)
    k = jnp.asarray(rng.normal(size=(BH, T, N)) * 0.3, dtype)
    v = jnp.asarray(rng.normal(size=(BH, T, N)), dtype)
    w = jnp.asarray(rng.uniform(0.85, 0.999, size=(BH, T, N)), dtype)
    u = jnp.asarray(rng.normal(size=(BH, N)) * 0.2, dtype)
    got = rwkv6_wkv(r, k, v, w, u, block_t=64, use_pallas=True, interpret=True)
    want = rwkv6_ref(r, k, v, w, u)
    tol = 3e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_rwkv6_state_streaming_across_blocks():
    """Splitting T across grid blocks must not reset the state."""
    rng = np.random.default_rng(21)
    BH, T, N = 1, 128, 32
    args = [
        jnp.asarray(rng.normal(size=(BH, T, N)), jnp.float32) for _ in range(3)
    ]
    w = jnp.asarray(rng.uniform(0.9, 0.999, size=(BH, T, N)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(BH, N)), jnp.float32)
    one_block = rwkv6_wkv(*args[:3], w, u, block_t=128, use_pallas=True, interpret=True)
    four_blocks = rwkv6_wkv(*args[:3], w, u, block_t=32, use_pallas=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(one_block), np.asarray(four_blocks), rtol=1e-5, atol=1e-5
    )
