"""Geographic routing + baseline algorithms (paper §II, §VI)."""
import numpy as np
import pytest

from repro.core import (
    geographic_gossip,
    greedy_route,
    handshake_cost,
    path_averaging,
    route_to_node,
    standard_gossip,
)


def test_greedy_route_valid_and_terminates(rgg500):
    rng = np.random.default_rng(0)
    for _ in range(20):
        src = int(rng.integers(500))
        target = rng.uniform(0, 1, 2)
        r = greedy_route(rgg500, src, target)
        assert r.nodes[0] == src
        # consecutive path nodes are graph neighbors
        for u, v in zip(r.nodes, r.nodes[1:]):
            assert v in rgg500.neighbors[u, : rgg500.degrees[u]]
        # recipient is locally closest to the target
        dst = int(r.nodes[-1])
        d_dst = np.sum((rgg500.coords[dst] - target) ** 2)
        nbrs = rgg500.neighbors[dst, : rgg500.degrees[dst]]
        assert (np.sum((rgg500.coords[nbrs] - target) ** 2, 1) >= d_dst).all()


def test_route_to_node_reaches_destination(rgg500):
    rng = np.random.default_rng(1)
    greedy_success = 0
    for _ in range(30):
        u, v = rng.integers(500, size=2)
        r = route_to_node(rgg500, int(u), int(v))
        assert r.nodes[0] == u and r.nodes[-1] == v
        greedy_success += r.greedy_ok
    # paper [11]: greedy geographic routing succeeds w.h.p. on RGGs
    assert greedy_success >= 25


def test_send_counts_sum_to_two_hops(rgg500):
    r = route_to_node(rgg500, 0, 499)
    sends = r.send_counts(500)
    assert sends.sum() == 2 * r.hops


def test_path_averaging_mass_conserved(rgg500, x0_500):
    res = path_averaging(rgg500, x0_500, eps=1e-4, seed=0)
    assert res.converged
    np.testing.assert_allclose(res.x.sum(), x0_500.sum(), rtol=1e-9)
    assert res.node_sends.sum() == res.messages
    assert res.error(x0_500) <= 1.2e-4


def test_geographic_gossip_converges(rgg500, x0_500):
    res = geographic_gossip(rgg500, x0_500, eps=1e-3, seed=0)
    assert res.converged
    assert res.error(x0_500) <= 1.2e-3
    assert res.node_sends.sum() == res.messages


def test_standard_gossip_is_least_efficient(rgg500, x0_500):
    sg = standard_gossip(rgg500, x0_500, eps=1e-3, seed=0)
    gg = geographic_gossip(rgg500, x0_500, eps=1e-3, seed=0)
    assert sg.converged
    # Boyd et al.: Theta(n^2/log n) for neighbor-only gossip vs
    # Theta(n^1.5/sqrt(log n)) for geographic gossip
    assert sg.messages > gg.messages


def test_path_averaging_loss_distorts(rgg500, x0_500):
    res = path_averaging(
        rgg500, x0_500, eps=1e-4, seed=0, loss_p=0.8, max_iters=30_000
    )
    assert not res.converged or res.error(x0_500) > 1e-4


def test_handshake_cost_statistics():
    rng = np.random.default_rng(0)
    T = 100_000
    for p in (0.5, 0.8, 1.0):
        c = handshake_cost(T, p, rng)
        assert c >= T
        np.testing.assert_allclose(c, T / p, rtol=0.02)
    with pytest.raises(ValueError):
        handshake_cost(10, 0.0)
