"""Banded local attention vs reference (sliding-window correctness)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref
from repro.models.attention import banded_local_attention, chunked_attention


@pytest.mark.parametrize("S,window,block", [
    (256, 64, 64), (256, 96, 64), (384, 128, 128), (200, 64, 64),
])
def test_banded_matches_reference(S, window, block):
    rng = np.random.default_rng(S + window)
    B, H, Hkv, D = 2, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    got = banded_local_attention(
        q, k, v, pos, pos, window=window, softcap=None,
        scale=D ** -0.5, block=block,
    )
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
    )


def test_banded_matches_chunked_with_softcap():
    rng = np.random.default_rng(0)
    B, H, S, D, w = 1, 2, 256, 32, 64
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    banded = banded_local_attention(
        q, k, v, pos, pos, window=w, softcap=30.0, scale=D ** -0.5, block=64
    )
    chunked = chunked_attention(
        q, k, v, pos, pos, causal=True, window=w, softcap=30.0,
        scale=D ** -0.5, chunk=64,
    )
    np.testing.assert_allclose(
        np.asarray(banded), np.asarray(chunked), rtol=2e-5, atol=2e-5
    )
