"""Content-addressed plan cache (core.plan_cache) + parallel build
parity: a cache hit is bitwise-equal to a fresh build, stale keys miss,
and `build_plan(..., workers>1)` reproduces the serial plan exactly."""
import numpy as np
import pytest

from repro.core import build_plan, random_geometric_graph
from repro.core.plan_cache import (
    PLAN_CACHE_VERSION,
    graph_digest_spec,
    graph_spec,
    load_plan,
    plan_key,
    setup_plan,
    store_plan,
)

# every array field a LevelPlan carries (mirrors tests/test_plan_methods)
_LP_ARRAY_FIELDS = (
    "degrees", "n_nodes", "node_mask", "slot_node",
    "nbr_start", "nbr_flat", "hop_flat", "row_node", "partner_flat",
    "edge_b", "edge_i", "edge_si", "edge_j", "edge_sj",
    "edge_pos_i", "edge_pos_j",
    "inc_node", "inc_edge", "inc_count",
    "rep_slot", "rep_node", "line16", "next_graph", "next_slot",
)


def _assert_plans_bitwise_equal(p1, p2):
    assert len(p1.levels) == len(p2.levels)
    for lp1, lp2 in zip(p1.levels, p2.levels):
        assert lp1.level == lp2.level and lp1.kind == lp2.kind
        assert lp1.max_hops == lp2.max_hops
        assert lp1.max_deg == lp2.max_deg
        for f in _LP_ARRAY_FIELDS:
            a, b = getattr(lp1, f), getattr(lp2, f)
            if a is None or b is None:
                assert a is None and b is None, f
            else:
                np.testing.assert_array_equal(a, b, err_msg=f)
        ra, rb = lp1.routes, lp2.routes
        if ra is None or rb is None:
            assert ra is None and rb is None
        else:
            np.testing.assert_array_equal(ra.nodes, rb.nodes)
            np.testing.assert_array_equal(ra.hops, rb.hops)
            np.testing.assert_array_equal(ra.greedy_ok, rb.greedy_ok)
    np.testing.assert_array_equal(p1.rep_counts, p2.rep_counts)
    np.testing.assert_array_equal(p1.final_graph, p2.final_graph)
    np.testing.assert_array_equal(p1.final_slot, p2.final_slot)
    assert p1.disconnected_cells == p2.disconnected_cells
    assert p1.disseminate == p2.disseminate
    np.testing.assert_array_equal(p1.graph.nbr_start, p2.graph.nbr_start)
    np.testing.assert_array_equal(p1.graph.nbr_flat, p2.graph.nbr_flat)
    np.testing.assert_array_equal(p1.graph.coords, p2.graph.coords)


def test_cache_hit_bitwise_equal_to_fresh_build(tmp_path):
    d = str(tmp_path)
    p1, i1 = setup_plan(600, graph_seed=11, seed=5, cache_dir=d)
    assert i1["cache"] == "miss" and i1["graph_gen_s"] > 0
    p2, i2 = setup_plan(600, graph_seed=11, seed=5, cache_dir=d)
    assert i2["cache"] == "hit" and i2["graph_gen_s"] == 0.0
    _assert_plans_bitwise_equal(p1, p2)
    # fresh (uncached) build of the same spec for good measure
    g = random_geometric_graph(600, seed=11)
    _assert_plans_bitwise_equal(p2, build_plan(g, seed=5))


def test_cache_hit_skips_and_refresh_rebuilds(tmp_path):
    d = str(tmp_path)
    _, i1 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    assert i1["cache"] == "miss"
    _, i2 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    assert i2["cache"] == "hit" and "plan_build_s" in i2
    _, i3 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d, refresh=True)
    assert i3["cache"] == "miss" and i3["graph_gen_s"] > 0
    _, i4 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d, use_cache=False)
    assert i4["cache"] == "off"


def test_stale_keys_miss(tmp_path):
    d = str(tmp_path)
    p1, _ = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    stored = plan_key(graph_spec(400, seed=3), seed=0)
    assert load_plan(stored, cache_dir=d) is not None
    # any change to the spec produces a different key -> miss
    for other in (
        plan_key(graph_spec(400, seed=4), seed=0),       # graph seed
        plan_key(graph_spec(401, seed=3), seed=0),       # n
        plan_key(graph_spec(400, seed=3, c=2.5), seed=0),  # density
        plan_key(graph_spec(400, seed=3), seed=1),       # plan seed
        plan_key(graph_spec(400, seed=3), seed=0, k=2),  # partition
        plan_key(graph_spec(400, seed=3), seed=0, rep_mode="first"),
    ):
        assert other != stored
        assert load_plan(other, cache_dir=d) is None


def test_version_bump_invalidates(tmp_path, monkeypatch):
    d = str(tmp_path)
    _, i1 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    monkeypatch.setattr(
        "repro.core.plan_cache.PLAN_CACHE_VERSION", PLAN_CACHE_VERSION + 1
    )
    _, i2 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    assert i2["cache"] == "miss"


def test_digest_spec_for_external_graph(tmp_path):
    d = str(tmp_path)
    g = random_geometric_graph(500, seed=7)
    p1, i1 = setup_plan(g=g, seed=2, cache_dir=d)
    assert i1["cache"] == "miss"
    p2, i2 = setup_plan(g=g, seed=2, cache_dir=d)
    assert i2["cache"] == "hit"
    _assert_plans_bitwise_equal(p1, p2)
    # different content -> different key
    g2 = random_geometric_graph(500, seed=8)
    assert graph_digest_spec(g) != graph_digest_spec(g2)
    with pytest.raises(ValueError):
        setup_plan(500, g=g)
    with pytest.raises(ValueError):
        setup_plan()


def test_corrupt_entry_misses(tmp_path):
    d = str(tmp_path)
    _, i1 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    path = next(tmp_path.glob("*.plan.pkl"))
    path.write_bytes(b"not a pickle")
    p, i2 = setup_plan(400, graph_seed=3, seed=0, cache_dir=d)
    assert i2["cache"] == "miss"
    assert load_plan(i2["key"], cache_dir=d) is not None


def test_store_load_round_trip_drops_exec_cache(tmp_path):
    g = random_geometric_graph(400, seed=3)
    plan = build_plan(g, seed=0)
    plan.exec_cache["sentinel"] = object()
    key = plan_key(graph_digest_spec(g), seed=0)
    store_plan(key, plan, cache_dir=str(tmp_path))
    loaded = load_plan(key, cache_dir=str(tmp_path))
    assert loaded.exec_cache == {}
    _assert_plans_bitwise_equal(plan, loaded)


@pytest.mark.parametrize("workers", [2, 3])
def test_parallel_build_bitwise_equals_serial(workers):
    g = random_geometric_graph(800, seed=13)
    serial = build_plan(g, seed=4)
    parallel = build_plan(g, seed=4, workers=workers)
    _assert_plans_bitwise_equal(serial, parallel)
    assert parallel.build_seconds["workers"] == workers


def test_parallel_routes_bitwise_equal():
    from repro.core.routing import batched_routes_to_nodes

    g = random_geometric_graph(500, seed=7)
    rng = np.random.default_rng(0)
    pairs = rng.integers(0, g.n, size=(64, 2))
    serial = batched_routes_to_nodes(g, pairs)
    chunked = batched_routes_to_nodes(g, pairs, workers=3)
    np.testing.assert_array_equal(serial.nodes, chunked.nodes)
    np.testing.assert_array_equal(serial.hops, chunked.hops)
    np.testing.assert_array_equal(serial.greedy_ok, chunked.greedy_ok)
