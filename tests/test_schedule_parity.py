"""Presampled-schedule parity vs the legacy per-tick scan.

The schedule/value split must be invisible to the simulation: the lax
and pallas backends are BITWISE-identical to the legacy sequential scan
(x, edge_usage, messages, ticks — including the `loss_p` failure path),
and the matmul backend keeps the integer accounting bitwise while its
values agree up to f32 rounding (matrix composition reassociates the
pair-average sums; same caveat the historical pallas branch carried).
"""
import numpy as np
import pytest

from repro.core import (
    ExecOptions,
    batched_graphs,
    build_plan,
    compose_schedule,
    execute_plan,
    gossip_until,
    multiscale_gossip,
    random_geometric_graph,
    sample_schedule,
    sample_tick,
)
from repro.kernels.pair_apply import pair_apply, pair_apply_ref


def _ring(n):
    class G:
        pass

    g = G()
    g.n = n
    g.max_deg = 2
    g.neighbors = np.stack(
        [(np.arange(n) - 1) % n, (np.arange(n) + 1) % n], axis=1
    ).astype(np.int32)
    g.degrees = np.full(n, 2, np.int32)
    return g


def _gossip_args(n=48, seed=0):
    g = _ring(n)
    x0 = np.random.default_rng(seed).normal(0, 1, n).astype(np.float32)[None]
    return (x0, g.neighbors[None], g.degrees[None], np.array([n], np.int32))


def _assert_int_parity(a, b):
    np.testing.assert_array_equal(a.edge_usage, b.edge_usage)
    np.testing.assert_array_equal(a.messages, b.messages)
    np.testing.assert_array_equal(a.ticks, b.ticks)
    np.testing.assert_array_equal(a.converged, b.converged)


# ------------------------ gossip-loop parity ---------------------------


@pytest.mark.parametrize("backend", ["lax", "pallas"])
def test_presampled_bitwise_eps_oracle(backend):
    args = _gossip_args(seed=1)
    legacy = gossip_until(*args, eps=1e-4, seed=3, schedule="per_tick")
    new = gossip_until(
        *args, eps=1e-4, seed=3, schedule="presampled", backend=backend,
        interpret=True,
    )
    np.testing.assert_array_equal(legacy.x, new.x)
    _assert_int_parity(legacy, new)


@pytest.mark.parametrize("backend", ["lax", "pallas", "matmul"])
def test_presampled_parity_fixed_ticks_loss(backend):
    """The paper's failure path: fixed budget, per-hop loss.  All
    accounting is schedule-only, so it is bitwise for every backend;
    values are bitwise for lax/pallas and allclose for matmul."""
    args = _gossip_args(seed=2)
    kw = dict(eps=-1.0, seed=7, fixed_ticks=384, loss_p=0.8)
    legacy = gossip_until(*args, schedule="per_tick", **kw)
    new = gossip_until(
        *args, schedule="presampled", backend=backend, interpret=True, **kw
    )
    _assert_int_parity(legacy, new)
    if backend == "matmul":
        np.testing.assert_allclose(legacy.x, new.x, rtol=2e-5, atol=2e-6)
    else:
        np.testing.assert_array_equal(legacy.x, new.x)


def test_presampled_parity_batched_weighted():
    gs = [_ring(8), _ring(24), _ring(40)]
    neighbors, degrees, n_nodes, mask = batched_graphs(gs)
    rng = np.random.default_rng(5)
    x = np.where(mask, rng.normal(0, 1, mask.shape), 0.0)
    w = np.where(mask, rng.uniform(0.5, 2.0, mask.shape), 0.0)
    x0 = np.stack([x * w, w], axis=-1).astype(np.float32)
    legacy = gossip_until(
        x0, neighbors, degrees, n_nodes, eps=1e-4, seed=9,
        schedule="per_tick",
    )
    new = gossip_until(x0, neighbors, degrees, n_nodes, eps=1e-4, seed=9)
    np.testing.assert_array_equal(legacy.x, new.x)
    _assert_int_parity(legacy, new)


def test_per_tick_pallas_matches_lax_accounting():
    """The kept legacy pallas branch (eye hoisted out of the chunk
    body) must still produce the identical exchange sequence."""
    args = _gossip_args(seed=3)
    a = gossip_until(*args, eps=1e-3, seed=11, schedule="per_tick")
    b = gossip_until(
        *args, eps=1e-3, seed=11, schedule="per_tick", backend="pallas",
        interpret=True,
    )
    _assert_int_parity(a, b)
    np.testing.assert_allclose(a.x, b.x, rtol=1e-4, atol=1e-5)


def test_schedule_mode_validation():
    args = _gossip_args()
    with pytest.raises(ValueError):
        gossip_until(*args, eps=1e-3, schedule="clairvoyant")
    with pytest.raises(ValueError):
        gossip_until(*args, eps=1e-3, schedule="per_tick", backend="matmul")


# -------------------------- schedule pass ------------------------------


def test_sample_schedule_matches_sample_tick():
    import jax
    import jax.numpy as jnp

    from repro.core import CsrGraphs, dense_to_csr

    g = _ring(16)
    key = jax.random.PRNGKey(4)
    adj_np = dense_to_csr(
        g.neighbors[None], g.degrees[None], np.array([16], np.int32)
    )
    adj = CsrGraphs(*(jnp.asarray(a) for a in adj_np))
    ts = jnp.arange(10, 42)
    sched = sample_schedule(ts, key, adj, 0.7)
    for idx, t in enumerate(np.asarray(ts)):
        one = sample_tick(jnp.int32(t), key, adj, 0.7)
        for field, batch in zip(one._fields, sched):
            np.testing.assert_array_equal(
                np.asarray(batch[idx]), np.asarray(getattr(one, field)),
                err_msg=f"t={t} field={field}",
            )


def test_compose_schedule_is_stochastic_and_matches_ref():
    import jax.numpy as jnp

    rng = np.random.default_rng(6)
    T, B, C, V = 48, 3, 12, 2
    i = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
    j = jnp.asarray((rng.integers(1, C, (T, B)) + np.asarray(i)) % C,
                    jnp.int32)
    ui = jnp.asarray(rng.uniform(size=(T, B)) < 0.8)
    uj = jnp.asarray(rng.uniform(size=(T, B)) < 0.9)
    m = compose_schedule(C, i, j, ui, uj)
    # each elementary matrix is row-stochastic, so the composition is too
    np.testing.assert_allclose(np.asarray(m).sum(-1), 1.0, atol=1e-5)
    x = jnp.asarray(rng.normal(size=(B, C, V)), jnp.float32)
    want = pair_apply_ref(x, i, j, ui, uj)
    got = jnp.einsum("bij,bjv->biv", m, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


# ------------------------- pair_apply kernel ---------------------------


@pytest.mark.parametrize("B,C,V,T", [(1, 8, 1, 16), (3, 13, 2, 64)])
def test_pair_apply_kernel_bitwise_vs_oracle(B, C, V, T):
    import jax.numpy as jnp

    rng = np.random.default_rng(B * T)
    x = jnp.asarray(rng.normal(size=(B, C, V)), jnp.float32)
    i = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
    j = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
    ui = jnp.asarray(rng.uniform(size=(T, B)) < 0.8)
    uj = jnp.asarray(rng.uniform(size=(T, B)) < 0.9)
    want = pair_apply_ref(x, i, j, ui, uj)
    got = pair_apply(x, i, j, ui, uj, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_b", [1, 2, 3, 4])
@pytest.mark.parametrize("B,C,V,T", [(7, 5, 1, 32), (16, 9, 2, 48)])
def test_pair_apply_tiled_bitwise_any_block(B, C, V, T, block_b):
    """Tiling must be invisible: every block size — including blocks
    smaller than the batch and batches that are NOT a block multiple
    (ops pads with all-masked pass-through schedules) — reproduces the
    oracle bitwise, because cells never interact."""
    import jax.numpy as jnp

    rng = np.random.default_rng(B * T + block_b)
    x = jnp.asarray(rng.normal(size=(B, C, V)), jnp.float32)
    i = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
    j = jnp.asarray(rng.integers(0, C, (T, B)), jnp.int32)
    ui = jnp.asarray(rng.uniform(size=(T, B)) < 0.8)
    uj = jnp.asarray(rng.uniform(size=(T, B)) < 0.9)
    want = pair_apply_ref(x, i, j, ui, uj)
    got = pair_apply(
        x, i, j, ui, uj, use_pallas=True, interpret=True, block_b=block_b
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pair_apply_noop_when_masked():
    import jax.numpy as jnp

    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 9, 1)),
                    jnp.float32)
    i = jnp.zeros((12, 2), jnp.int32)
    off = jnp.zeros((12, 2), bool)
    got = pair_apply_ref(x, i, i, off, off)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


# --------------------------- engine parity -----------------------------


def test_engine_presampled_matches_per_tick():
    g = random_geometric_graph(120, seed=5)
    x0 = np.random.default_rng(2).normal(0, 1, 120)
    plan = build_plan(g, seed=0)
    legacy = execute_plan(
        plan, x0, eps=1e-4, seeds=(0,), weighted=True,
        options=ExecOptions(schedule="per_tick"),
    )
    new = execute_plan(plan, x0, eps=1e-4, seeds=(0,), weighted=True)
    np.testing.assert_array_equal(legacy.x_final, new.x_final)
    np.testing.assert_array_equal(legacy.messages, new.messages)
    np.testing.assert_array_equal(legacy.node_sends, new.node_sends)
    np.testing.assert_array_equal(legacy.level_ticks, new.level_ticks)


def test_engine_matmul_backend():
    g = random_geometric_graph(100, seed=6)
    x0 = np.random.default_rng(3).normal(0, 1, 100)
    plan = build_plan(g, seed=0)
    a = multiscale_gossip(
        g, x0, eps=1e-4, seed=0, weighted=True, plan=plan,
        options=ExecOptions(backend="lax"),
    )
    b = multiscale_gossip(
        g, x0, eps=1e-4, seed=0, weighted=True, plan=plan,
        options=ExecOptions(backend="matmul"),
    )
    assert a.messages == b.messages
    np.testing.assert_array_equal(a.node_sends, b.node_sends)
    np.testing.assert_allclose(a.x_final, b.x_final, atol=2e-4, rtol=1e-4)


def test_engine_single_device_mesh_matches_unsharded():
    import jax
    from jax.sharding import Mesh

    g = random_geometric_graph(90, seed=7)
    x0 = np.random.default_rng(4).normal(0, 1, 90)
    plan = build_plan(g, seed=0)
    mesh = Mesh(np.array(jax.devices()), ("trials",))
    sharded = execute_plan(
        plan, x0, eps=1e-4, seeds=(0, 1, 2), weighted=True,
        options=ExecOptions(mesh=mesh),
    )
    dense = execute_plan(plan, x0, eps=1e-4, seeds=(0, 1, 2), weighted=True)
    np.testing.assert_array_equal(sharded.x_final, dense.x_final)
    np.testing.assert_array_equal(sharded.messages, dense.messages)
    np.testing.assert_array_equal(sharded.node_sends, dense.node_sends)


def test_engine_mesh_rejects_multi_axis():
    import jax
    from jax.sharding import Mesh

    g = random_geometric_graph(30, seed=8)
    plan = build_plan(g, seed=0)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("a", "b"))
    with pytest.raises(ValueError):
        execute_plan(
            plan, np.zeros(30), seeds=(0,), options=ExecOptions(mesh=mesh)
        )
