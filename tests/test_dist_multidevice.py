"""Multi-device distribution tests.

Each test spawns a SUBPROCESS that forces
XLA_FLAGS=--xla_force_host_platform_device_count=8 before importing jax
(the main pytest process must keep seeing 1 device for the smoke
tests).  These execute REAL sharded computations on an 8-device host
mesh — a miniature of the production (pod, data, model) topology.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> str:
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import set_mesh
        assert len(jax.devices()) == 8
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=900, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_train_step_executes_on_multipod_mesh():
    out = _run("""
    import dataclasses
    from repro.configs import get_config, reduce_config
    from repro.launch.specs import build_cell
    from repro.configs.registry import SHAPES

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = dataclasses.replace(
        reduce_config(get_config("llama3.2-3b")),
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    )
    SHAPES["tiny_train"] = (32, 8, "train")
    cell = build_cell(cfg, "tiny_train", mesh, model_axis=2)
    with set_mesh(mesh):
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        # materialize real inputs per the abstract specs
        def materialize(a, sh):
            arr = (np.random.default_rng(0).normal(0, 0.02, a.shape)
                   if jnp.issubdtype(a.dtype, jnp.floating)
                   else np.zeros(a.shape, a.dtype))
            return jax.device_put(jnp.asarray(arr, a.dtype), sh)
        args = jax.tree.map(materialize, cell.args_abs, cell.in_shardings,
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        state, metrics = jitted(*args)
        loss = float(metrics["loss"])
        assert np.isfinite(loss), loss
        print("LOSS", loss)
    """)
    assert "LOSS" in out


def test_sync_strategies_execute_with_collectives():
    out = _run("""
    from repro.dist import SyncConfig, suggest_levels, sync_gradients
    from repro.launch.hlo_analysis import collective_bytes

    R = 8
    mesh = jax.make_mesh((R,), ("replica",))
    sh = NamedSharding(mesh, P("replica", None))
    g = {"w": jax.device_put(
        jnp.asarray(np.random.default_rng(0).normal(size=(R, 256)), jnp.float32),
        sh)}
    want = np.asarray(g["w"]).mean(0)
    for strat in ("allreduce", "hierarchical", "ring", "multiscale"):
        cfg = SyncConfig(strategy=strat, levels=suggest_levels(R),
                         rounds=(64,) if strat == "ring" else ())
        with set_mesh(mesh):
            f = jax.jit(lambda x: sync_gradients(x, cfg, R),
                        in_shardings=(dict(w=sh),), out_shardings=dict(w=sh))
            out = f(g)
            hlo = f.lower(g).compile().as_text()
        stats = collective_bytes(hlo, pod_size=4)
        got = np.asarray(out["w"])
        err = np.abs(got - want[None]).max()
        exact = strat in ("allreduce", "hierarchical")
        assert stats.count > 0, (strat, "no collectives found")
        if exact:
            assert err < 1e-5, (strat, err)
        else:
            spread = np.abs(got - got.mean(0, keepdims=True)).max()
            before = np.abs(np.asarray(g["w"]) - want[None]).max()
            assert spread < 0.5 * before, (strat, spread, before)
        print("STRAT", strat, stats.count, round(float(err), 6))

    # compressed + rotated execute_sync on the same sharded mesh: residual
    # state and the step index thread through a real collective lowering
    from repro.dist import (CompressionConfig, build_sync_plan, execute_sync,
                            init_residual)
    plan = build_sync_plan(
        SyncConfig("multiscale", levels=suggest_levels(R),
                   compression=CompressionConfig("topk", topk_fraction=0.25),
                   rotation_period=3),
        R)
    with set_mesh(mesh):
        f = jax.jit(lambda x, r, s: execute_sync(plan, x, r, s),
                    in_shardings=((dict(w=sh), dict(w=sh), None)),
                    out_shardings=(dict(w=sh), dict(w=sh)))
        mixed, res = f(g, init_residual(g), jnp.int32(0))
    assert np.isfinite(np.asarray(mixed["w"])).all()
    # EF decomposition survives sharding: residual is exactly the unsent mass
    assert np.abs(np.asarray(res["w"])).max() > 0
    print("COMPRESSED OK")
    """)
    assert out.count("STRAT") == 4
    assert "COMPRESSED OK" in out


def test_sharded_executor_matches_dense_and_overlaps():
    out = _run("""
    from repro.dist import (CompressionConfig, SyncConfig, async_execute_sync,
                            build_sync_plan, execute_sync,
                            execute_sync_sharded, init_inflight,
                            init_residual, suggest_levels)
    from repro.launch.hlo_analysis import collective_bytes

    R = 8
    mesh = jax.make_mesh((R,), ("replica",))
    sh = NamedSharding(mesh, P("replica", None))
    g = {"w": jax.device_put(
        jnp.asarray(np.random.default_rng(0).normal(size=(R, 96)), jnp.float32),
        sh)}
    cases = {
        "allreduce": SyncConfig("allreduce"),
        "hierarchical": SyncConfig("hierarchical"),
        "ring": SyncConfig("ring", rounds=(16,)),
        "multiscale": SyncConfig("multiscale"),
        "ms_exact": SyncConfig("multiscale", exact_fusion=True),
        "ms_rotated": SyncConfig("multiscale", rotation_period=3,
                                 rotation_seed=5),
        "ms_topk": SyncConfig("multiscale",
                              compression=CompressionConfig("topk", 0.25)),
    }
    for name, cfg in cases.items():
        plan = build_sync_plan(cfg, R)
        res = (init_residual(g)
               if plan.compression.scheme != "none" else None)
        for step in (0, 2):
            dense, dres = execute_sync(plan, g, res, step)
            f = jax.jit(lambda x, r, s, p=plan: execute_sync_sharded(
                p, x, r, s, mesh=mesh))
            sharded, sres = f(g, res, jnp.int32(step))
            hlo = f.lower(g, res, jnp.int32(step)).compile().as_text()
            stats = collective_bytes(hlo, pod_size=4)
            assert stats.count > 0, (name, "no collectives in shard_map path")
            np.testing.assert_allclose(
                np.asarray(dense["w"]), np.asarray(sharded["w"]),
                rtol=2e-6, atol=2e-6)
            if res is not None:
                np.testing.assert_allclose(
                    np.asarray(dres["w"]), np.asarray(sres["w"]),
                    rtol=2e-6, atol=2e-6)
        print("PARITY", name)

    # async pipeline stage under the mesh: the applied output is the mix
    # of the in-flight buffer (zeros at warmup), not of the fresh grads
    plan = build_sync_plan(
        SyncConfig("multiscale", exact_fusion=True, overlap="one_step"), R)
    f = jax.jit(lambda cur, prev, s, p=plan: async_execute_sync(
        p, cur, prev, None, s, mesh=mesh))
    applied, inflight, _ = f(g, init_inflight(g), jnp.int32(0))
    assert float(np.abs(np.asarray(applied["w"])).max()) == 0.0
    np.testing.assert_array_equal(np.asarray(inflight["w"]),
                                  np.asarray(g["w"]))
    applied, _, _ = f(g, inflight, jnp.int32(1))
    np.testing.assert_allclose(
        np.asarray(applied["w"]).mean(0), np.asarray(g["w"]).mean(0),
        rtol=1e-5, atol=1e-6)
    print("ASYNC OK")
    """)
    assert out.count("PARITY") == 7
    assert "ASYNC OK" in out


def test_sharded_executor_failure_parity_with_dense():
    """Fault injection on the 8-device replica mesh: the per-program
    masks recomputed inside shard_map must match the dense executor's
    global draw for the same (seed, step) — outputs agree across every
    aggregation mode, dropped rows are zero on both paths, and an inert
    SyncFailureModel stays bitwise-identical to a failure-free plan."""
    out = _run("""
    import dataclasses
    from repro.dist import (CompressionConfig, SyncConfig, SyncFailureModel,
                            build_sync_plan, execute_sync,
                            execute_sync_sharded, init_residual,
                            replica_fault_masks)

    R = 8
    mesh = jax.make_mesh((R,), ("replica",))
    sh = NamedSharding(mesh, P("replica", None))
    g = {"w": jax.device_put(
        jnp.asarray(np.random.default_rng(0).normal(size=(R, 96)), jnp.float32),
        sh)}
    fm = SyncFailureModel(churn_fraction=0.25, straggler_fraction=0.125,
                          byzantine_fraction=0.125, seed=11)
    cases = {
        "mean": SyncConfig("multiscale", failures=fm),
        "survivor": SyncConfig("multiscale", aggregation="survivor_weighted",
                               failures=fm),
        "trimmed": SyncConfig("allreduce", aggregation="trimmed_mean",
                              failures=fm),
        "median": SyncConfig("allreduce", aggregation="coordinate_median",
                             failures=fm),
        "topk_churn": SyncConfig("multiscale",
                                 compression=CompressionConfig("topk", 0.25),
                                 failures=fm),
        "rotated_churn": SyncConfig("multiscale", rotation_period=3,
                                    rotation_seed=5, failures=fm),
    }
    for name, cfg in cases.items():
        plan = build_sync_plan(cfg, R)
        res = (init_residual(g)
               if plan.compression.scheme != "none" else None)
        f = jax.jit(lambda x, r, s, p=plan: execute_sync_sharded(
            p, x, r, s, mesh=mesh))
        for step in (0, 3):
            dense, dres = execute_sync(plan, g, res, step)
            sharded, sres = f(g, res, jnp.int32(step))
            np.testing.assert_allclose(
                np.asarray(dense["w"]), np.asarray(sharded["w"]),
                rtol=2e-6, atol=2e-6)
            if res is not None:
                np.testing.assert_allclose(
                    np.asarray(dres["w"]), np.asarray(sres["w"]),
                    rtol=2e-6, atol=2e-6)
            dropped = np.asarray(replica_fault_masks(fm, R, step).dropped)
            assert dropped.sum() == 3
            assert np.all(np.asarray(sharded["w"])[dropped] == 0.0), name
        print("FAULT PARITY", name)

    # inert model: bitwise equality with the failure-free plan, sharded
    clean = build_sync_plan(SyncConfig("multiscale"), R)
    inert = build_sync_plan(
        SyncConfig("multiscale", failures=SyncFailureModel()), R)
    fc = jax.jit(lambda x, s, p=clean: execute_sync_sharded(
        p, x, None, s, mesh=mesh))
    fi = jax.jit(lambda x, s, p=inert: execute_sync_sharded(
        p, x, None, s, mesh=mesh))
    a, _ = fc(g, jnp.int32(1))
    b, _ = fi(g, jnp.int32(1))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    print("INERT BITWISE OK")
    """)
    assert out.count("FAULT PARITY") == 6
    assert "INERT BITWISE OK" in out


def test_elastic_checkpoint_restore_across_meshes():
    out = _run("""
    import tempfile
    from repro.train import restore_checkpoint, save_checkpoint

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    sh_a = NamedSharding(mesh_a, P("data", "model"))
    sh_b = NamedSharding(mesh_b, P("data", "model"))
    state = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8), sh_a)}
    d = tempfile.mkdtemp()
    save_checkpoint(d, state, 3)
    like = {"w": jnp.zeros((8, 8))}
    restored, step = restore_checkpoint(d, like, shardings={"w": sh_b})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
    assert restored["w"].sharding.mesh.shape["data"] == 2
    print("ELASTIC OK")
    """)
    assert "ELASTIC OK" in out


def test_trial_mesh_sharding_matches_unsharded():
    """execute_plan(mesh=) shards the vmapped Monte-Carlo trial axis
    over an 8-device host mesh; per-trial results must be bitwise
    independent of the sharding, including a T not divisible by the
    device count (padding trials are discarded)."""
    out = _run("""
    from jax.sharding import Mesh
    from repro.core import (
        ExecOptions, build_plan, execute_plan, random_geometric_graph,
    )

    g = random_geometric_graph(90, seed=7)
    x0 = np.random.default_rng(4).normal(0, 1, 90)
    plan = build_plan(g, seed=0)
    mesh = Mesh(np.array(jax.devices()), ("trials",))
    seeds = tuple(range(6))  # 6 trials on 8 devices: forces padding
    sharded = execute_plan(
        plan, x0, eps=1e-4, seeds=seeds, weighted=True,
        options=ExecOptions(mesh=mesh))
    dense = execute_plan(plan, x0, eps=1e-4, seeds=seeds, weighted=True)
    assert sharded.x_final.shape == (6, 90)
    np.testing.assert_array_equal(sharded.x_final, dense.x_final)
    np.testing.assert_array_equal(sharded.messages, dense.messages)
    np.testing.assert_array_equal(sharded.node_sends, dense.node_sends)
    np.testing.assert_array_equal(sharded.level_ticks, dense.level_ticks)
    print("TRIAL MESH OK")
    """)
    assert "TRIAL MESH OK" in out


def test_node_mesh_2d_matches_trial_mesh():
    """The (trials, nodes) 2-D mesh blocks every level's graph batch
    over the nodes axis (halo exchange only at promotion boundaries);
    results must be bitwise-equal to both the unsharded run and the
    1-axis trial mesh, in the eps-oracle AND fixed-iterations modes."""
    out = _run("""
    from jax.sharding import Mesh
    from repro.core import (
        ExecOptions, build_plan, execute_plan, random_geometric_graph,
    )

    g = random_geometric_graph(200, seed=11)
    x0 = np.random.default_rng(6).normal(0, 1, 200)
    plan = build_plan(g, seed=0)
    devs = np.array(jax.devices())
    mesh2d = Mesh(devs.reshape(2, 4), ("trials", "nodes"))
    mesh1d = Mesh(devs, ("trials",))
    for kw in (dict(eps=1e-4), dict(eps=1e-3, fixed_ticks_scale=1.0)):
        seeds = (0, 1, 2)  # 3 trials on a 2-way trial axis: forces padding
        node = execute_plan(
            plan, x0, seeds=seeds, weighted=True,
            options=ExecOptions(mesh=mesh2d), **kw)
        trial = execute_plan(
            plan, x0, seeds=seeds, weighted=True,
            options=ExecOptions(mesh=mesh1d), **kw)
        dense = execute_plan(plan, x0, seeds=seeds, weighted=True, **kw)
        for other in (trial, dense):
            np.testing.assert_array_equal(node.x_final, other.x_final)
            np.testing.assert_array_equal(node.messages, other.messages)
            np.testing.assert_array_equal(node.node_sends, other.node_sends)
            np.testing.assert_array_equal(
                node.level_ticks, other.level_ticks)
            np.testing.assert_array_equal(
                node.level_messages, other.level_messages)
        print("NODE MESH OK", kw["eps"])

    # guardrails: the node-sharded path is presampled-only and cannot
    # collect per-edge usage (counters live sharded)
    try:
        execute_plan(plan, x0, seeds=(0,),
                     options=ExecOptions(mesh=mesh2d, schedule="per_tick"))
        raise AssertionError("per_tick + node mesh must be rejected")
    except ValueError:
        pass
    try:
        execute_plan(plan, x0, seeds=(0,),
                     options=ExecOptions(mesh=mesh2d, collect_usage=True))
        raise AssertionError("collect_usage + node mesh must be rejected")
    except ValueError:
        pass
    print("NODE MESH GUARDS OK")
    """)
    assert out.count("NODE MESH OK") == 2
    assert "NODE MESH GUARDS OK" in out
