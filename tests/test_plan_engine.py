"""Plan/execute core: batched-router parity with the scalar router,
CSR attribution parity with the legacy dict-based crawl, trial vmapping
consistency, per-trial mass conservation, and backend agreement."""
import numpy as np
import pytest

from repro.core import (
    ExecOptions,
    Graph,
    batched_greedy_routes,
    batched_routes_to_nodes,
    build_plan,
    execute_plan,
    greedy_route,
    multiscale_gossip,
    random_geometric_graph,
    route_to_node,
)
from repro.core.plan import overlay_node_sends


# --------------------------- routing parity ----------------------------


def test_batched_greedy_matches_scalar(rgg500):
    rng = np.random.default_rng(0)
    E = 50
    srcs = rng.integers(500, size=E)
    targets = rng.uniform(0, 1, (E, 2))
    br = batched_greedy_routes(rgg500, srcs, targets)
    for e in range(E):
        r = greedy_route(rgg500, int(srcs[e]), targets[e])
        assert br.hops[e] == r.hops
        np.testing.assert_array_equal(br.nodes[e, : r.hops + 1], r.nodes)
        assert (br.nodes[e, r.hops + 1 :] == -1).all()


def test_batched_route_to_nodes_matches_scalar(rgg500):
    rng = np.random.default_rng(1)
    pairs = rng.integers(500, size=(60, 2))
    br = batched_routes_to_nodes(rgg500, pairs)
    for e, (u, v) in enumerate(pairs):
        r = route_to_node(rgg500, int(u), int(v))
        assert br.hops[e] == r.hops
        np.testing.assert_array_equal(br.nodes[e, : r.hops + 1], r.nodes)
        assert br.greedy_ok[e] == r.greedy_ok
        assert br.nodes[e, 0] == u and br.nodes[e, br.hops[e]] == v


def _dead_end_graph() -> Graph:
    """A hook shape where greedy routing from node 0 toward node 4 gets
    stuck at a local minimizer, forcing the BFS fallback."""
    coords = np.array([
        [0.10, 0.50],   # 0: source
        [0.10, 0.20],   # 1: detour, farther from 4 than 0 is
        [0.45, 0.10],   # 2
        [0.80, 0.20],   # 3
        [0.80, 0.50],   # 4: destination (no direct link 0-4)
        [0.30, 0.52],   # 5: bait — closer to 4 than 0, but a dead end
    ])
    pairs = np.array([[0, 1], [1, 2], [2, 3], [3, 4], [0, 5]], np.int32)
    return Graph.from_pairs(coords, pairs, radius=0.4)


def test_batched_bfs_fallback_matches_scalar():
    g = _dead_end_graph()
    scalar = route_to_node(g, 0, 4)
    assert not scalar.greedy_ok  # the construction forces the fallback
    br = batched_routes_to_nodes(g, np.array([[0, 4], [4, 0], [1, 3]]))
    assert not br.greedy_ok[0]
    for e, (u, v) in enumerate([(0, 4), (4, 0), (1, 3)]):
        r = route_to_node(g, u, v)
        assert br.hops[e] == r.hops
        np.testing.assert_array_equal(br.nodes[e, : r.hops + 1], r.nodes)


# ------------------------- attribution parity --------------------------


def _legacy_overlay_sends(lp, usage, n):
    """The pre-refactor dict crawl: map (node, slot) -> edge via list
    scans, then add the full route send profile per recorded exchange."""
    E = len(lp.edge_b)
    node_sends = np.zeros(n, np.int64)
    for e in range(E):
        b = int(lp.edge_b[e])
        route = lp.routes.route(e)
        uses = int(usage[b, lp.edge_i[e], lp.edge_si[e]]) + int(
            usage[b, lp.edge_j[e], lp.edge_sj[e]]
        )
        node_sends += uses * route.send_counts(n)
    return node_sends


def test_csr_attribution_matches_legacy_dict(rgg500, x0_500):
    plan = build_plan(rgg500, seed=0)
    res = execute_plan(
        plan, x0_500, eps=1e-4, seeds=(0,), weighted=True,
        options=ExecOptions(collect_usage=True),
    )
    overlay_total = np.zeros(500, np.int64)
    checked = 0
    for li, lp in enumerate(plan.levels):
        if lp.kind != "overlay":
            continue
        usage = res.edge_usage[li][0]          # flat (nnz+1,) counters
        csr = overlay_node_sends(lp, usage, 500)
        legacy = _legacy_overlay_sends(lp, lp.dense_usage(usage), 500)
        np.testing.assert_array_equal(csr, legacy)
        overlay_total += csr
        checked += 1
    assert checked >= 1
    # full-run cross-check: engine node_sends == overlay CSR + base-level
    # (initiator+partner) counts + the dissemination send
    base = plan.levels[0]
    usage0 = base.dense_usage(res.edge_usage[0][0])
    base_sends = np.zeros(500, np.int64)
    for b in range(base.num_graphs):
        ids = base.slot_node[b][base.slot_node[b] >= 0]
        u = usage0[b, : len(ids)]
        base_sends[ids] += u.sum(axis=1)
        nbr = base.neighbors[b, : len(ids)]
        valid = nbr >= 0
        np.add.at(base_sends, ids[nbr[valid]], u[valid])
    expect = base_sends + overlay_total + (1 if plan.disseminate else 0)
    np.testing.assert_array_equal(res.node_sends[0], expect)


# --------------------------- trial vmapping ----------------------------


def test_trials_vmap_matches_sequential(rgg500, x0_500):
    plan = build_plan(rgg500, seed=0)
    batched = multiscale_gossip(
        rgg500, x0_500, eps=1e-4, seed=0, weighted=True, trials=3, plan=plan
    )
    assert batched.trials == 3
    for t in range(3):
        single = multiscale_gossip(
            rgg500, x0_500, eps=1e-4, seed=t, weighted=True, plan=plan
        )
        assert int(batched.messages[t]) == single.messages
        np.testing.assert_array_equal(batched.node_sends[t], single.node_sends)
        np.testing.assert_allclose(
            batched.x_final[t], single.x_final, rtol=1e-5, atol=1e-6
        )
    errs = batched.error(x0_500)
    assert errs.shape == (3,)


def test_trial_conservation_weighted(rgg500, x0_500):
    res = multiscale_gossip(
        rgg500, x0_500, eps=1e-5, seed=0, weighted=True, trials=3
    )
    target = 500 * float(np.mean(x0_500))
    for t in range(3):
        # exact-mass fusion: sum(x_final) ~= n * mean(x0) per trial
        assert abs(float(res.x_final[t].sum()) - target) <= 0.5
        assert res.error(x0_500)[t] <= 20 * 1e-5


def test_trials_accounting_per_trial(rgg500, x0_500):
    res = multiscale_gossip(
        rgg500, x0_500, eps=1e-4, seed=3, weighted=True, trials=2
    )
    for t in range(2):
        assert res.node_sends[t].sum() == res.messages[t]


# ----------------------------- backends --------------------------------


def test_pallas_backend_matches_lax():
    g = random_geometric_graph(120, seed=5)
    x0 = np.random.default_rng(2).normal(0, 1, 120)
    plan = build_plan(g, seed=0)
    a = multiscale_gossip(
        g, x0, eps=1e-4, seed=0, weighted=True, plan=plan,
        options=ExecOptions(backend="lax"),
    )
    b = multiscale_gossip(
        g, x0, eps=1e-4, seed=0, weighted=True, plan=plan,
        options=ExecOptions(backend="pallas"),
    )
    # identical exchange sequence => identical message/send accounting;
    # values agree up to f32 matmul rounding
    assert a.messages == b.messages
    np.testing.assert_array_equal(a.node_sends, b.node_sends)
    np.testing.assert_allclose(a.x_final, b.x_final, atol=2e-4, rtol=1e-4)


def test_unknown_backend_rejected(rgg500, x0_500):
    with pytest.raises(ValueError):
        multiscale_gossip(
            rgg500, x0_500, options=ExecOptions(backend="cuda")
        )


def test_single_level_plan_counts_reps():
    # n <= cell_max => K == 1: no promotion, but the per-cell election
    # still happens and is counted (legacy Alg. 1 behavior)
    g = random_geometric_graph(6, seed=0)
    x0 = np.random.default_rng(0).normal(0, 1, 6)
    res = multiscale_gossip(g, x0, eps=1e-4, seed=0)
    assert res.partition.k == 1
    assert res.rep_counts.sum() > 0
    assert res.rep_counts.max() <= res.partition.k
    assert res.error(x0) <= 1e-3
