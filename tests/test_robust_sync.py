"""Fault-tolerant gradient sync: failure injection, robust aggregation,
EF-residual recovery (dist.failures / dist.robust).

The load-bearing invariants:

* fault masks are deterministic in (seed, step), mutually disjoint, and
  hit their exact static counts — identical across every executor;
* an inert `SyncFailureModel` (all fractions 0, or None) is
  bitwise-invisible on the dense and async executors;
* EF compression conserves mass bitwise under drops: a dropped
  replica's whole accumulator (gradient + residual) lands in its new
  residual, and re-enters the stream when the replica rejoins;
* trimmed-mean consensus bounds the output by the honest gradient
  range even under 10x-scaled Byzantine payloads, where plain mean is
  dragged far outside it;
* survivor-weighted mixing renormalizes the doubly-stochastic mass
  over live replicas (constant stream -> live rows keep the constant);
* the decentralized train step converges end-to-end under
  churn + Byzantine <= 0.25 with trimmed_mean, and reports the
  degradation metrics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data import SyntheticLM
from repro.dist import (
    CompressionConfig, SyncConfig, SyncFailureModel, async_execute_sync,
    build_sync_plan, execute_sync, fault_counts, init_inflight,
    init_residual, replica_fault_masks,
)
from repro.dist.compression import compress, decompress
from repro.models import Transformer
from repro.optim import sgdm
from repro.train import (
    init_decentralized_state, make_decentralized_step, run_train_scenarios,
    train_scenario_matrix,
)

R = 8


def _grads(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(scale * rng.normal(size=(R, 6, 3)), jnp.float32),
        "b": jnp.asarray(scale * rng.normal(size=(R, 10)), jnp.float32),
    }


def _tree_eq(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --------------------------- model validation ----------------------------


def test_failure_model_validation():
    with pytest.raises(ValueError, match="churn_fraction"):
        SyncFailureModel(churn_fraction=1.0)
    with pytest.raises(ValueError, match="byzantine_fraction"):
        SyncFailureModel(byzantine_fraction=-0.1)
    with pytest.raises(ValueError, match="byzantine_scale"):
        SyncFailureModel(byzantine_scale=-1.0)
    assert not SyncFailureModel().active
    assert SyncFailureModel(churn_fraction=0.25).active
    hash(SyncFailureModel(churn_fraction=0.25))


def test_plan_rejects_infeasible_failure_budgets():
    # everyone fails: no live replica left
    with pytest.raises(ValueError, match="live"):
        build_sync_plan(
            SyncConfig("multiscale",
                       failures=SyncFailureModel(churn_fraction=0.5,
                                                 straggler_fraction=0.5)),
            R,
        )
    # trimmed mean needs at least one survivor after trimming
    with pytest.raises(ValueError, match="trimmed"):
        build_sync_plan(
            SyncConfig("allreduce", aggregation="trimmed_mean",
                       failures=SyncFailureModel(churn_fraction=0.375,
                                                 byzantine_fraction=0.375)),
            R,
        )
    with pytest.raises(ValueError, match="aggregation"):
        SyncConfig("multiscale", aggregation="krum")


# ------------------------------ fault masks ------------------------------


def test_fault_masks_deterministic_disjoint_exact_counts():
    fm = SyncFailureModel(churn_fraction=0.25, straggler_fraction=0.125,
                          byzantine_fraction=0.25, seed=3)
    kc, ks, kb = fault_counts(fm, R)
    assert (kc, ks, kb) == (2, 1, 2)
    seen = []
    for step in range(6):
        f = replica_fault_masks(fm, R, step)
        c, s, b = (np.asarray(f.churned), np.asarray(f.straggler),
                   np.asarray(f.byzantine))
        assert (c.sum(), s.sum(), b.sum()) == (kc, ks, kb)
        assert not np.any(c & s) and not np.any(c & b) and not np.any(s & b)
        np.testing.assert_array_equal(np.asarray(f.dropped), c | s)
        np.testing.assert_array_equal(np.asarray(f.live), ~(c | s))
        # determinism in (seed, step)
        g = replica_fault_masks(fm, R, step)
        np.testing.assert_array_equal(np.asarray(g.dropped), c | s)
        seen.append(tuple(np.flatnonzero(c | s | b)))
    assert len(set(seen)) > 1  # masks vary across steps


# ----------------------- inert model is invisible ------------------------


@pytest.mark.parametrize("cfg", [
    SyncConfig("multiscale", rotation_period=3),
    SyncConfig("ring", compression=CompressionConfig("topk", 0.25)),
    SyncConfig("allreduce"),
])
def test_inert_failure_model_bitwise_identical(cfg):
    """failures=SyncFailureModel() (all fractions 0) must be
    bitwise-invisible on the dense AND async executors (the sharded
    executor is pinned in test_dist_multidevice)."""
    base = build_sync_plan(cfg, R)
    inert = build_sync_plan(
        dataclasses.replace(cfg, failures=SyncFailureModel()), R)
    assert not inert.faulty
    G = _grads(1)
    res = init_residual(G) if cfg.compression.scheme != "none" else None
    for step in range(3):
        m0, r0 = execute_sync(base, G, res, step)
        m1, r1 = execute_sync(inert, G, res, step)
        _tree_eq(m0, m1)
        if res is not None:
            _tree_eq(r0, r1)
    fa0 = init_inflight(G)
    fa1 = init_inflight(G)
    ra0, ra1 = res, res
    ocfg = dataclasses.replace(cfg, overlap="one_step")
    op0 = build_sync_plan(ocfg, R)
    op1 = build_sync_plan(
        dataclasses.replace(ocfg, failures=SyncFailureModel()), R)
    for step in range(3):
        a0, fa0, ra0 = async_execute_sync(op0, G, fa0, ra0, step)
        a1, fa1, ra1 = async_execute_sync(op1, G, fa1, ra1, step)
        _tree_eq(a0, a1)
        _tree_eq(fa0, fa1)


# ----------------- EF mass conservation / recovery -----------------------


@pytest.mark.parametrize("scheme,arg", [("topk", 0.25), ("int8", 0.25)])
def test_ef_mass_conservation_under_drops_bitwise(scheme, arg):
    """A dropped replica's new residual is EXACTLY grads + residuals
    (the whole accumulator, bitwise): nothing it would have transmitted
    is lost, and live replicas' residuals are untouched by the drop."""
    fm = SyncFailureModel(churn_fraction=0.25, straggler_fraction=0.125,
                          seed=5)
    comp = CompressionConfig(scheme, arg)
    faulty = build_sync_plan(
        SyncConfig("multiscale", compression=comp, failures=fm), R)
    clean = build_sync_plan(SyncConfig("multiscale", compression=comp), R)
    G = _grads(2)
    res = jax.tree.map(
        lambda g: 0.1 * g, G)  # nonzero residual state to conserve
    step = 1
    mixed, new_res = execute_sync(faulty, G, res, step)
    _, clean_res = execute_sync(clean, G, res, step)
    f = replica_fault_masks(fm, R, step)
    dropped = np.asarray(f.dropped)
    assert dropped.sum() == 3
    for k in G:
        acc = np.asarray(G[k]) + np.asarray(res[k])
        # dropped rows: full accumulator in the residual, zero applied
        np.testing.assert_array_equal(
            np.asarray(new_res[k])[dropped], acc[dropped])
        np.testing.assert_array_equal(
            np.asarray(mixed[k])[dropped], np.zeros_like(acc[dropped]))
        # live rows: residuals bitwise-identical to the reliable run
        np.testing.assert_array_equal(
            np.asarray(new_res[k])[~dropped],
            np.asarray(clean_res[k])[~dropped])


def test_ef_recovery_reinjects_on_rejoin():
    """The EF-recovery story: mass parked in a dropped replica's
    residual re-enters its transmitted accumulator at the next step it
    is live — the two-step payload sum equals what two reliable steps
    would have transmitted."""
    fm = SyncFailureModel(churn_fraction=0.25, seed=5)
    comp = CompressionConfig("topk", 1.0)  # identity payload, EF plumbing
    plan = build_sync_plan(
        SyncConfig("multiscale", compression=comp, failures=fm), R)
    G = _grads(4)
    res = init_residual(G)
    mixed0, res = execute_sync(plan, G, res, 0)
    d0 = np.asarray(replica_fault_masks(fm, R, 0).dropped)
    d1 = np.asarray(replica_fault_masks(fm, R, 1).dropped)
    rejoined = d0 & ~d1
    assert rejoined.sum() > 0
    # at step 1, a rejoined replica's accumulator is 2x its constant
    # gradient (step-0 mass recovered from the residual + fresh grads)
    payload, _ = compress(G, res, plan.compression)
    payload = decompress(payload, plan.compression)
    for k in G:
        np.testing.assert_allclose(
            np.asarray(payload[k])[rejoined],
            2.0 * np.asarray(G[k])[rejoined], rtol=1e-6)


# ------------------------- robust aggregation ----------------------------


def test_trimmed_mean_bounds_byzantine_norm():
    """10x-scaled sign-flipped Byzantine payloads drag the plain mean
    far outside the honest range; trimmed_mean stays inside it."""
    fm = SyncFailureModel(byzantine_fraction=0.25, byzantine_scale=10.0,
                          seed=1)
    trimmed = build_sync_plan(
        SyncConfig("allreduce", aggregation="trimmed_mean", failures=fm), R)
    naive = build_sync_plan(SyncConfig("allreduce", failures=fm), R)
    G = _grads(7)
    step = 2
    byz = np.asarray(replica_fault_masks(fm, R, step).byzantine)
    assert byz.sum() == 2
    m_t, _ = execute_sync(trimmed, G, None, step)
    m_n, _ = execute_sync(naive, G, None, step)
    for k in G:
        honest_max = np.abs(np.asarray(G[k])[~byz]).max()
        assert np.abs(np.asarray(m_t[k])).max() <= honest_max + 1e-6
        assert np.abs(np.asarray(m_n[k])).max() > honest_max
        # consensus: every replica holds the same trimmed row
        np.testing.assert_array_equal(
            np.asarray(m_t[k]),
            np.broadcast_to(np.asarray(m_t[k])[:1], m_t[k].shape))


def test_coordinate_median_ignores_outlier_coordinates():
    fm = SyncFailureModel(byzantine_fraction=0.125, byzantine_scale=100.0,
                          seed=2)
    plan = build_sync_plan(
        SyncConfig("allreduce", aggregation="coordinate_median",
                   failures=fm), R)
    G = {"a": jnp.broadcast_to(jnp.arange(R, dtype=jnp.float32)[:, None],
                               (R, 4))}
    m, _ = execute_sync(plan, G, None, 0)
    vals = np.asarray(m["a"])
    assert np.all(np.abs(vals) <= R)  # the 100x outlier never leaks


def test_survivor_weighted_renormalizes_live_mass():
    """Constant gradient stream + churn: survivor-weighted mixing keeps
    live rows at the constant (mass renormalized over survivors), plain
    mean shrinks them by the dead replicas' missing share."""
    fm = SyncFailureModel(churn_fraction=0.25, seed=9)
    const = {"a": jnp.ones((R, 5), jnp.float32)}
    sw = build_sync_plan(
        SyncConfig("allreduce", aggregation="survivor_weighted",
                   failures=fm), R)
    mean = build_sync_plan(SyncConfig("allreduce", failures=fm), R)
    step = 0
    live = np.asarray(replica_fault_masks(fm, R, step).live)
    m_sw, _ = execute_sync(sw, const, None, step)
    m_mean, _ = execute_sync(mean, const, None, step)
    np.testing.assert_allclose(np.asarray(m_sw["a"])[live], 1.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(m_mean["a"])[live], live.mean(), rtol=1e-6)
    # dropped rows receive nothing either way
    np.testing.assert_array_equal(np.asarray(m_sw["a"])[~live], 0.0)


def test_survivor_weighted_is_noop_without_failures():
    cfg = SyncConfig("multiscale", aggregation="survivor_weighted")
    plan = build_sync_plan(cfg, R)
    base = build_sync_plan(SyncConfig("multiscale"), R)
    G = _grads(3)
    m0, _ = execute_sync(base, G, None, 0)
    m1, _ = execute_sync(plan, G, None, 0)
    _tree_eq(m0, m1)


# --------------------- end-to-end training robustness --------------------


def _tiny_train(sync, steps=8, Rr=R, fixed_batch=False):
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    opt = sgdm()
    base = model.init(jax.random.PRNGKey(0))
    params_r = jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (Rr,) + p.shape), base)
    state = init_decentralized_state(params_r, opt, sync=sync)
    step = jax.jit(
        make_decentralized_step(cfg, opt, lambda s: 1e-2, sync, Rr))
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=Rr * 2,
                       seed=5)
    hist = []
    for s in range(steps):
        b = data.batch_at(0 if fixed_batch else s)
        batch = {k: jnp.asarray(v.reshape(Rr, 2, *v.shape[1:]))
                 for k, v in b.items()}
        state, m = step(state, batch)
        hist.append({k: float(v) for k, v in m.items()})
    return hist


def test_robust_training_converges_under_churn_and_byzantine():
    """The acceptance bar: churn + Byzantine <= 0.25 of replicas with
    trimmed_mean still trains (loss decreases end to end), and the
    degradation metrics report the injected faults.  A fixed batch
    makes descent deterministic (random-token streams are memorized,
    not generalized, at this scale)."""
    fm = SyncFailureModel(churn_fraction=0.125, byzantine_fraction=0.125,
                          byzantine_scale=10.0, seed=4)
    sync = SyncConfig("multiscale", aggregation="trimmed_mean", failures=fm)
    hist = _tiny_train(sync, steps=8, fixed_batch=True)
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert np.isfinite(last) and last < first, (first, last)
    m = hist[-1]
    assert m["effective_replica_fraction"] == pytest.approx(7 / 8)
    assert m["rejected_gradient_count"] == 1.0
    assert np.isfinite(m["survivor_consensus_error"])


def test_degradation_metrics_inert_without_failures():
    hist = _tiny_train(SyncConfig("multiscale"), steps=2)
    for m in hist:
        assert m["effective_replica_fraction"] == 1.0
        assert m["rejected_gradient_count"] == 0.0
        assert m["survivor_consensus_error"] == m["consensus_distance"]


def test_train_scenario_matrix_smoke():
    cfg = reduce_config(get_config("llama3.2-3b"))
    model = Transformer(cfg, model_axis=1)
    base = model.init(jax.random.PRNGKey(0))  # broadcast happens inside
    data = SyntheticLM(cfg.vocab_size, seq_len=16, global_batch=R * 2,
                       seed=5)
    res = run_train_scenarios(
        cfg, sgdm(), lambda s: 1e-2, SyncConfig("multiscale"), R,
        base, data, num_steps=3,
    )
    names = [r.scenario.name for r in res]
    assert names == ["baseline", "churn", "straggler", "byzantine"]
    by = {r.scenario.name: r for r in res}
    for r in res:
        assert len(r.losses) == 3 and np.isfinite(r.final_loss)
    assert by["baseline"].effective_replica_fraction_mean == 1.0
    assert by["churn"].effective_replica_fraction_mean < 1.0
    assert by["byzantine"].rejected_gradients_total > 0
    # matrix cells are plain dataclasses the caller can extend
    assert train_scenario_matrix()[0].aggregation == "mean"
