"""Dry-run memory profiler: compile a (reduced-depth) cell and list the
largest per-device HLO buffers — the working tool behind the §Perf
memory iterations.

  PYTHONPATH=src python tools/membuf_probe.py --arch grok-1-314b \
      --shape train_4k --unit "attn" --layers 1 [--top 15]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import re
from collections import Counter

import jax

from repro.configs import get_config
from repro.launch.hlo_analysis import DTYPE_BYTES
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.specs import build_cell

SHAPE_RE = re.compile(r"^\s*%?\S+ = ([a-z0-9]+)\[([\d,]+)\]")


def probe(arch, shape, unit=None, layers=None, top=15, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    changes = {}
    if unit:
        changes["block_unit"] = tuple(unit.split(","))
    if layers:
        changes["num_layers"] = layers
        if cfg.encoder_layers:
            changes["encoder_layers"] = min(cfg.encoder_layers, layers)
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    cell = build_cell(cfg, shape, mesh)
    with set_mesh(mesh):
        c = (
            jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                    donate_argnums=cell.donate)
            .lower(*cell.args_abs)
            .compile()
        )
    ma = c.memory_analysis()
    print(f"{arch} {shape} layers={cfg.num_layers} unit={cfg.block_unit}: "
          f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB")
    sizes = Counter()
    for line in c.as_text().splitlines():
        m = SHAPE_RE.match(line)
        if m and m.group(1) in DTYPE_BYTES:
            n = 1
            for d in m.group(2).split(","):
                n *= int(d)
            sizes[(m.group(1), m.group(2))] += 1
    items = sorted(
        sizes.items(),
        key=lambda kv: -DTYPE_BYTES[kv[0][0]]
        * eval(kv[0][1].replace(",", "*")),
    )
    shown = 0
    for (dt, dims), cnt in items:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DTYPE_BYTES[dt]
        if b < 2**27:
            break
        print(f"  {dt}[{dims}] x{cnt}  {b/2**30:.2f}GiB each")
        shown += 1
        if shown >= top:
            break


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--unit", default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    probe(a.arch, a.shape, a.unit, a.layers, a.top, a.multi_pod)
