"""Memory probes: gossip plan+execute footprint and the model-cell
HLO-buffer dry run.

Gossip mode (importable; used by the large-n benchmark smoke) reports
the peak host RSS and live device-buffer bytes for building and
executing a `HierarchyPlan` at a given n:

  PYTHONPATH=src python tools/membuf_probe.py --gossip-n 100000

`--graph-only` restricts the probe to graph generation (the streamed
bucket builder's peak RSS, no plan build or execute):

  PYTHONPATH=src python tools/membuf_probe.py --gossip-n 1000000 \
      --graph-only [--chunk 8000] [--graph-method bucket|reference]

Model mode compiles a (reduced-depth) cell and lists the largest
per-device HLO buffers — the working tool behind the §Perf memory
iterations.  It forces a 512-device host platform, so it runs as a
fresh process only (never import-triggered):

  PYTHONPATH=src python tools/membuf_probe.py --arch grok-1-314b \
      --shape train_4k --unit "attn" --layers 1 [--top 15]
"""
from __future__ import annotations

import argparse
import re
import sys

SHAPE_RE = re.compile(r"^\s*%?\S+ = ([a-z0-9]+)\[([\d,]+)\]")


# --------------------------- gossip probes -----------------------------


def host_peak_rss_bytes() -> int:
    """Peak resident set size of this process so far, in bytes.

    `ru_maxrss` is KiB on Linux and bytes on macOS; normalize to bytes.
    """
    import resource

    peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    if sys.platform != "darwin":
        peak *= 1024
    return int(peak)


def device_live_bytes() -> int:
    """Total bytes of live (committed) jax device buffers right now."""
    import jax

    return int(sum(int(a.nbytes) for a in jax.live_arrays()))


def memory_report() -> dict:
    """Snapshot both probes — call after the work being measured."""
    return {
        "host_peak_rss_bytes": host_peak_rss_bytes(),
        "device_live_bytes": device_live_bytes(),
    }


def gossip_memory_report(
    n: int,
    *,
    seed: int = 0,
    eps: float = 1e-3,
    fixed_ticks_scale: float = 0.2,
    trials: int = 1,
    backend: str = "lax",
    method: str = "vectorized",
) -> dict:
    """Build and execute a multiscale plan at size `n`, reporting the
    peak host RSS and live device-buffer bytes alongside the
    `build_seconds` breakdown.  Defaults mirror the large-n benchmark
    profile (fixed-iterations mode, lax backend, one trial).
    """
    import numpy as np

    from repro.core import (
        ExecOptions, build_plan, execute_plan, random_geometric_graph,
    )

    g = random_geometric_graph(n, seed=1000 + n)
    x0 = np.random.default_rng(n).normal(0, 1, n)
    plan = build_plan(g, seed=seed, method=method)
    res = execute_plan(
        plan, x0, eps=eps, seeds=tuple(seed + t for t in range(trials)),
        weighted=True, fixed_ticks_scale=fixed_ticks_scale,
        options=ExecOptions(backend=backend),
    )
    report = memory_report()
    report.update(
        n=int(n),
        levels=len(plan.levels),
        plan_build_s=dict(plan.build_seconds or {}),
        messages=[int(m) for m in np.asarray(res.messages)],
        err=[float(e) for e in np.atleast_1d(res.error(x0))],
    )
    return report


def graph_gen_memory_report(
    n: int,
    *,
    seed: int | None = None,
    method: str = "bucket",
    chunk: int | None = None,
) -> dict:
    """Peak host RSS of graph generation ALONE at size `n` — the probe
    behind the streamed bucket builder's O(chunk + nnz) memory claim
    (the old cKDTree + dense-padded path peaked on the `(n, max_deg)`
    intermediate instead).  `seed` defaults to the benchmark convention
    `1000 + n`."""
    import time

    from repro.core import random_geometric_graph

    kw = {} if chunk is None else {"chunk": chunk}
    t0 = time.perf_counter()
    g = random_geometric_graph(
        n, seed=(1000 + n) if seed is None else seed, method=method, **kw
    )
    dt = time.perf_counter() - t0
    return {
        "n": int(n),
        "method": method,
        "chunk": chunk,
        "nnz": int(g.nnz),
        "graph_gen_s": float(dt),
        "host_peak_rss_bytes": host_peak_rss_bytes(),
    }


# ---------------------------- model probe ------------------------------


def probe(arch, shape, unit=None, layers=None, top=15, multi_pod=False):
    import dataclasses
    from collections import Counter

    import jax

    from repro.configs import get_config
    from repro.launch.hlo_analysis import DTYPE_BYTES
    from repro.launch.mesh import make_production_mesh, set_mesh
    from repro.launch.specs import build_cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    changes = {}
    if unit:
        changes["block_unit"] = tuple(unit.split(","))
    if layers:
        changes["num_layers"] = layers
        if cfg.encoder_layers:
            changes["encoder_layers"] = min(cfg.encoder_layers, layers)
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    cell = build_cell(cfg, shape, mesh)
    with set_mesh(mesh):
        c = (
            jax.jit(cell.fn, in_shardings=cell.in_shardings,
                    out_shardings=cell.out_shardings,
                    donate_argnums=cell.donate)
            .lower(*cell.args_abs)
            .compile()
        )
    ma = c.memory_analysis()
    print(f"{arch} {shape} layers={cfg.num_layers} unit={cfg.block_unit}: "
          f"args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB")
    sizes = Counter()
    for line in c.as_text().splitlines():
        m = SHAPE_RE.match(line)
        if m and m.group(1) in DTYPE_BYTES:
            n = 1
            for d in m.group(2).split(","):
                n *= int(d)
            sizes[(m.group(1), m.group(2))] += 1
    items = sorted(
        sizes.items(),
        key=lambda kv: -DTYPE_BYTES[kv[0][0]]
        * eval(kv[0][1].replace(",", "*")),
    )
    shown = 0
    for (dt, dims), cnt in items:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DTYPE_BYTES[dt]
        if b < 2**27:
            break
        print(f"  {dt}[{dims}] x{cnt}  {b/2**30:.2f}GiB each")
        shown += 1
        if shown >= top:
            break


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--gossip-n", type=int, default=None,
                    help="probe the gossip plan+execute path at this n "
                         "instead of compiling a model cell")
    ap.add_argument("--graph-only", action="store_true",
                    help="with --gossip-n: probe graph generation alone "
                         "(the streamed builder's RSS, no plan/execute)")
    ap.add_argument("--graph-method", default="bucket",
                    help="graph builder for --graph-only (bucket|reference)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="bucket-builder chunk size for --graph-only")
    ap.add_argument("--scale", type=float, default=0.2,
                    help="fixed_ticks_scale for the gossip probe")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--unit", default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    if a.gossip_n is not None:
        import json

        if a.graph_only:
            rep = graph_gen_memory_report(
                a.gossip_n, method=a.graph_method, chunk=a.chunk
            )
            rss = rep["host_peak_rss_bytes"] / 2**30
            print(f"graph n={a.gossip_n} ({rep['method']}): "
                  f"peak_rss={rss:.2f}GiB nnz={rep['nnz']} "
                  f"gen={rep['graph_gen_s']:.2f}s")
            print(json.dumps(rep, indent=1))
        else:
            rep = gossip_memory_report(a.gossip_n, fixed_ticks_scale=a.scale)
            rss = rep["host_peak_rss_bytes"] / 2**30
            dev = rep["device_live_bytes"] / 2**20
            print(f"gossip n={a.gossip_n}: peak_rss={rss:.2f}GiB "
                  f"device_live={dev:.1f}MiB "
                  f"build={rep['plan_build_s'].get('total', 0.0):.2f}s")
            print(json.dumps(rep, indent=1))
    else:
        if a.arch is None:
            ap.error("--arch is required without --gossip-n")
        # the model probe compiles against a production-sized mesh;
        # the 512-device host forcing must precede the first jax import
        import os

        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count=512"
        )
        probe(a.arch, a.shape, a.unit, a.layers, a.top, a.multi_pod)
