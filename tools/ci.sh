#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a fast benchmark smoke.
#
#   tools/ci.sh            # tier-1 + fig2 smoke
#   tools/ci.sh --no-bench # tests only
#
# Works offline: hypothesis is optional (property tests skip cleanly,
# see tests/hypothesis_compat.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== benchmark smoke (fig2) =="
    python -m benchmarks.run --only fig2
fi

echo "CI OK"
