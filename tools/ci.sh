#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a fast benchmark smoke.
#
#   tools/ci.sh                     # tier-1 + fig2 smoke
#   tools/ci.sh --no-bench          # tests only
#   REPRO_BENCH_SMOKE=1 tools/ci.sh # + fig3 device-resident smoke
#                                   #   (n=500, trials=1, both engine
#                                   #   backends — guards the plan/execute
#                                   #   hot path against regressions)
#                                   # + compressed decentralized-train smoke
#                                   #   (2 steps, topk+rotation, multiscale,
#                                   #   R=8 — guards the SyncPlan/execute
#                                   #   training path end to end)
#
# Works offline: hypothesis is optional (property tests skip cleanly,
# see tests/hypothesis_compat.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== benchmark smoke (fig2) =="
    python -m benchmarks.run --only fig2
fi

if [[ "${REPRO_BENCH_SMOKE:-0}" == "1" ]]; then
    # scratch artifact name: the smoke must not clobber the full-run artifact
    echo "== benchmark smoke (fig3 n=500 trials=1, backend=lax) =="
    python -m benchmarks.fig3_vs_path_averaging --sizes 500 --trials 1 \
        --backend lax --artifact fig3_smoke
    echo "== benchmark smoke (fig3 n=500 trials=1, backend=pallas) =="
    python -m benchmarks.fig3_vs_path_averaging --sizes 500 --trials 1 \
        --backend pallas --artifact fig3_smoke
    echo "== compressed decentralized-train smoke (R=8, topk, multiscale) =="
    python examples/decentralized_consensus.py --strategy multiscale \
        --compress topk --rotate 4 --replicas 8 --steps 2
fi

echo "CI OK"
