#!/usr/bin/env bash
# CI entry point: tier-1 test suite + a fast benchmark smoke gated by the
# artifact-regression check.
#
#   tools/ci.sh                     # tier-1 (-m "not slow") + fig2/fig3
#                                   #   smokes + fig5 scenario-matrix
#                                   #   smoke through
#                                   #   tools/check_artifacts.py (±15%
#                                   #   message-count / error / priced-
#                                   #   cost gate vs the committed
#                                   #   artifacts)
#   tools/ci.sh --no-bench          # tests only
#   tools/ci.sh --bench-only        # gate + smokes only (CI job 2: the
#                                   #   tier1 job already ran the tests)
#   REPRO_BENCH_SMOKE=1 tools/ci.sh # + large-n CSR-path smoke gate
#                                   #   (tools/check_artifacts.py
#                                   #   --large-n-only: n=20k FI re-run
#                                   #   ±15% vs the committed
#                                   #   large_n_smoke artifact, incl.
#                                   #   the reference-vs-vectorized
#                                   #   plan-builder overlap parity)
#                                   # + fig3 device-resident smoke
#                                   #   (n=500, trials=1, both engine
#                                   #   backends — backend-suffixed
#                                   #   artifacts so the pallas run does
#                                   #   not clobber the lax run's
#                                   #   wall-clock/backend record), then
#                                   #   an entry appended to the
#                                   #   BENCH_gossip.json perf trajectory
#                                   # + compressed decentralized-train smoke
#                                   #   (2 steps, topk+rotation, multiscale,
#                                   #   R=8) and an async-overlap train
#                                   #   smoke (one-step-delayed averaging)
#                                   # + serving-fleet smoke (16 replicas,
#                                   #   p2c-from-gossip vs oracle vs random)
#                                   #   and a BENCH_serve.json trajectory
#                                   #   entry (fleet + paged-decode tok/s)
#                                   # + robust-train smoke (R=8, churn +
#                                   #   Byzantine, trimmed_mean + topk) and
#                                   #   the robust_train_smoke drift gate
#                                   #   (tools/check_artifacts.py
#                                   #   --robust-train-only: survivor
#                                   #   consensus error / loss / degradation
#                                   #   metrics ±15%)
#
# The slow tier (multi-device subprocess + vmap-/backend-parity tests) is
# NOT run here — .github/workflows/ci.yml's second job runs `-m slow`.
# A bare `python -m pytest -x -q` still runs both tiers.
#
# Works offline: hypothesis is optional (property tests skip cleanly,
# see tests/hypothesis_compat.py).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" != "--bench-only" ]]; then
    echo "== tier-1 tests (-m 'not slow') =="
    python -m pytest -x -q -m "not slow"
fi

if [[ "${1:-}" != "--no-bench" ]]; then
    echo "== benchmark smoke + artifact-regression gate (fig2 + fig3 + fig5 scenarios) =="
    # --fig5: re-runs the failure-scenario matrix smoke (n=300, 5
    # scenarios: baseline/churn/stragglers/regional/byzantine) and gates
    # achieved error + priced medium cost ±15% vs the committed
    # fig5_smoke artifact
    python tools/check_artifacts.py --fig5
fi

if [[ "${REPRO_BENCH_SMOKE:-0}" == "1" ]]; then
    # scratch artifact names: the smoke must not clobber the full-run
    # artifact, and each backend writes its own record
    echo "== benchmark smoke (fig3 n=500 trials=1, backend=lax) =="
    python -m benchmarks.fig3_vs_path_averaging --sizes 500 --trials 1 \
        --backend lax --artifact fig3_smoke_lax
    echo "== benchmark smoke (fig3 n=500 trials=1, backend=pallas) =="
    python -m benchmarks.fig3_vs_path_averaging --sizes 500 --trials 1 \
        --backend pallas --artifact fig3_smoke_pallas
    echo "== large-n smoke gate (n=20k FI, CSR path, ±15% vs committed) =="
    python tools/check_artifacts.py --large-n-only
    echo "== gossip perf trajectory (BENCH_gossip.json) =="
    python -m benchmarks.gossip_trajectory --label "ci smoke"
    echo "== compressed decentralized-train smoke (R=8, topk, multiscale) =="
    python examples/decentralized_consensus.py --strategy multiscale \
        --compress topk --rotate 4 --replicas 8 --steps 2
    echo "== async-overlap decentralized-train smoke (R=8, one_step) =="
    python examples/decentralized_consensus.py --strategy multiscale \
        --overlap --replicas 8 --steps 3
    echo "== serving-fleet smoke (16 replicas, 3 routers) + BENCH_serve.json =="
    python examples/serve_fleet.py --replicas 16 --ticks 120
    python -m benchmarks.serve_bench --label "ci smoke"
    echo "== robust-train smoke (R=8, churn+byzantine, trimmed_mean, topk) =="
    python examples/robust_training.py --replicas 8 --steps 8 \
        --churn 0.25 --byzantine 0.125 --aggregation trimmed_mean \
        --compress topk
    echo "== robust-train drift gate (survivor consensus error vs committed) =="
    python tools/check_artifacts.py --robust-train-only
fi

echo "CI OK"
