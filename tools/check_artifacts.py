#!/usr/bin/env python
"""Benchmark-regression gate over the committed artifacts.

Re-runs the fig2 smoke (same n / eps / seeds as the committed run —
the benchmark is deterministic, so honest drift comes from algorithm
changes, not noise) and compares per-level-count message means against
`benchmarks/artifacts/fig2_levels.json` within a relative tolerance.
Artifact drift then fails CI loudly instead of being silently committed
the next time someone regenerates the artifacts.

The fresh run is written to a scratch artifact (`fig2_levels_check`) so
the committed file is never clobbered by a drifting run — regenerating
the committed artifact on purpose stays an explicit
`python -m benchmarks.run --only fig2`.

    python tools/check_artifacts.py [--tolerance 0.15] [--trials N]

Exit status: 0 when every row is within tolerance, 1 on drift or a
missing committed artifact.  Wired into tools/ci.sh as the fig2 smoke.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

COMMITTED = "fig2_levels"
SCRATCH = "fig2_levels_check"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max relative drift of messages_mean per level count")
    ap.add_argument("--trials", type=int, default=None,
                    help="override trial count of the fresh run (defaults "
                         "to 3, the committed profile)")
    args = ap.parse_args()

    from benchmarks import fig2_levels
    from benchmarks.common import load_artifact

    committed = load_artifact(COMMITTED)
    if committed is None:
        print(f"check_artifacts: FAIL — committed artifact "
              f"benchmarks/artifacts/{COMMITTED}.json is missing; run "
              f"`python -m benchmarks.run --only fig2` and commit the result")
        return 1

    ks = sorted(int(k) for k in committed["rows"])
    trials = args.trials if args.trials is not None else 3
    print(f"check_artifacts: re-running fig2 smoke "
          f"(n={committed['n']}, eps={committed['eps']}, trials={trials}, "
          f"k={ks[0]}..{ks[-1]}) against the committed artifact "
          f"(tolerance ±{args.tolerance:.0%})")
    fig2_levels.run(
        n=int(committed["n"]), trials=trials, eps=float(committed["eps"]),
        max_k=ks[-1], artifact=SCRATCH,
    )
    fresh = load_artifact(SCRATCH)

    failures = []
    for k in ks:
        want = float(committed["rows"][str(k)]["messages_mean"])
        got_row = fresh["rows"].get(k, fresh["rows"].get(str(k)))
        if got_row is None:
            failures.append(f"  k={k}: missing from the fresh run")
            continue
        got = float(got_row["messages_mean"])
        rel = abs(got - want) / max(want, 1.0)
        status = "ok" if rel <= args.tolerance else "DRIFT"
        print(f"  k={k}: committed={want:.0f} fresh={got:.0f} "
              f"rel={rel:+.1%} [{status}]")
        if rel > args.tolerance:
            failures.append(
                f"  k={k}: messages_mean drifted {rel:.1%} "
                f"(committed {want:.0f} -> fresh {got:.0f}, "
                f"tolerance {args.tolerance:.0%})"
            )

    if failures:
        print("check_artifacts: FAIL — per-algorithm message counts drifted "
              "from the committed artifact:")
        print("\n".join(failures))
        print("If the drift is intentional (algorithm change), regenerate "
              "and commit the artifact: python -m benchmarks.run --only fig2")
        return 1
    print("check_artifacts: OK — fig2 message counts within "
          f"±{args.tolerance:.0%} of the committed artifact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
