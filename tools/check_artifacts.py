#!/usr/bin/env python
"""Benchmark-regression gate over the committed artifacts.

Re-runs the fig2 smoke (same n / eps / seeds as the committed run —
the benchmark is deterministic, so honest drift comes from algorithm
changes, not noise) and compares per-level-count message means against
`benchmarks/artifacts/fig2_levels.json` within a relative tolerance.
The same gate then covers the fig3 smoke: each backend-suffixed
committed artifact (`fig3_smoke_lax`, `fig3_smoke_pallas`) is re-run at
its recorded n / eps / trials and the per-algorithm message means are
compared within the same tolerance (wall-clocks are machine-dependent
and NOT gated).  Artifact drift then fails CI loudly instead of being
silently committed the next time someone regenerates the artifacts.

Fresh runs are written to scratch artifacts (`*_check`) so the
committed files are never clobbered by a drifting run — regenerating a
committed artifact on purpose stays an explicit
`python -m benchmarks.run --only fig2` / `REPRO_BENCH_SMOKE=1
tools/ci.sh`.

    python tools/check_artifacts.py [--tolerance 0.15] [--trials N]
                                    [--skip-fig3]

Exit status: 0 when every row is within tolerance, 1 on drift or a
missing committed artifact.  Wired into tools/ci.sh as the benchmark
smoke gate.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

COMMITTED = "fig2_levels"
SCRATCH = "fig2_levels_check"
FIG3_BACKENDS = ("lax", "pallas")
LARGE_N = "large_n_smoke"
FIG5 = "fig5_smoke"
ROBUST_TRAIN = "robust_train_smoke"
# minimum absolute graph_gen_s drift (seconds) that counts as real: the
# smoke builds in ~0.2s, where scheduler noise alone exceeds 15%
GRAPH_GEN_FLOOR_S = 0.5


def check_fig3(tolerance: float) -> list[str]:
    """Gate the backend-suffixed fig3 smoke message counts."""
    from benchmarks import fig3_vs_path_averaging
    from benchmarks.common import load_artifact

    failures = []
    for backend in FIG3_BACKENDS:
        name = f"fig3_smoke_{backend}"
        committed = load_artifact(name)
        if committed is None:
            failures.append(
                f"  {name}: committed artifact benchmarks/artifacts/"
                f"{name}.json is missing; run REPRO_BENCH_SMOKE=1 "
                f"tools/ci.sh and commit the result")
            continue
        sizes = tuple(sorted({
            int(n) for rows in committed["summary"].values() for n in rows
        }))
        print(f"check_artifacts: re-running fig3 smoke (backend={backend}, "
              f"sizes={sizes}, trials={committed['trials']}, "
              f"eps={committed['eps']}) against {name} "
              f"(tolerance ±{tolerance:.0%})")
        fig3_vs_path_averaging.run(
            sizes=sizes, trials=int(committed["trials"]),
            eps=float(committed["eps"]), backend=backend,
            artifact=f"{name}_check",
        )
        fresh = load_artifact(f"{name}_check")
        for algo, rows in committed["summary"].items():
            for n, rec in rows.items():
                want = float(rec["messages_mean"])
                got_rec = fresh["summary"].get(algo, {}).get(
                    n, fresh["summary"].get(algo, {}).get(str(n)))
                if got_rec is None:
                    failures.append(
                        f"  {name} {algo}@n{n}: missing from the fresh run")
                    continue
                got = float(got_rec["messages_mean"])
                rel = abs(got - want) / max(want, 1.0)
                status = "ok" if rel <= tolerance else "DRIFT"
                print(f"  {backend}/{algo}@n{n}: committed={want:.0f} "
                      f"fresh={got:.0f} rel={rel:+.1%} [{status}]")
                if rel > tolerance:
                    failures.append(
                        f"  {name} {algo}@n{n}: messages_mean drifted "
                        f"{rel:.1%} (committed {want:.0f} -> fresh {got:.0f},"
                        f" tolerance {tolerance:.0%})")
    return failures


def check_fig5(tolerance: float) -> list[str]:
    """Gate the fig5 failure-scenario smoke: achieved error and priced
    medium cost (energy) per scenario, plus the loss-model error, must
    stay within tolerance of the committed `fig5_smoke` artifact.

    The smoke is deterministic (shared plan, fixed gossip and
    failure-injection seeds), so drift means the scenario replay or the
    cost pricing changed — exactly what this gate is for.
    """
    from benchmarks import fig5_failures
    from benchmarks.common import load_artifact

    committed = load_artifact(FIG5)
    if committed is None:
        return [
            f"  {FIG5}: committed artifact benchmarks/artifacts/{FIG5}.json "
            f"is missing; run `python -m benchmarks.fig5_failures --n 300 "
            f"--trials 2 --scenario-trials 2 --ps 0.7,1.0 "
            f"--artifact {FIG5}` and commit the result"
        ]
    sm = committed.get("scenario_matrix") or {}
    sc_committed = sm.get("scenarios") or {}
    if len(sc_committed) < 4:
        return [
            f"  {FIG5}: committed artifact has {len(sc_committed)} "
            "scenarios; the gate wants the >=4-scenario matrix — "
            "regenerate with --scenario-trials > 0"
        ]
    ps = tuple(float(p) for p in committed["handshake"])
    print(f"check_artifacts: re-running fig5 smoke "
          f"(n={committed['n']}, trials={committed['trials']}, "
          f"eps={committed['eps']}, scenarios={sorted(sc_committed)}) "
          f"against {FIG5} (tolerance ±{tolerance:.0%})")
    fig5_failures.run(
        n=int(committed["n"]), eps=float(committed["eps"]), ps=ps,
        trials=int(committed["trials"]), backend=committed["backend"],
        schedule=committed.get("schedule", "presampled"),
        scenario_trials=int(sm["trials"]),
        scenario_scale=float(sm["fixed_ticks_scale"]),
        scenario_retransmit_p=float(sm["retransmit_p"]),
        artifact=f"{FIG5}_check",
    )
    fresh = load_artifact(f"{FIG5}_check")
    failures = []

    def gate(label, want, got, floor):
        rel = abs(got - want) / max(abs(want), floor)
        status = "ok" if rel <= tolerance else "DRIFT"
        print(f"  {label}: committed={want:.4g} fresh={got:.4g} "
              f"rel={rel:+.1%} [{status}]")
        if rel > tolerance:
            failures.append(
                f"  {FIG5} {label}: drifted {rel:.1%} "
                f"(committed {want:.4g} -> fresh {got:.4g}, "
                f"tolerance {tolerance:.0%})")

    fresh_sc = (fresh.get("scenario_matrix") or {}).get("scenarios") or {}
    for name, rec in sc_committed.items():
        got = fresh_sc.get(name)
        if got is None:
            failures.append(f"  {FIG5} scenario {name}: missing from the "
                            "fresh run")
            continue
        # error floor 1e-3: a reliable baseline converges to ~0 where
        # relative drift is meaningless noise on an already-passing run
        gate(f"scenario/{name}/err", float(rec["err_mean"]),
             float(got["err_mean"]), 1e-3)
        gate(f"scenario/{name}/energy", float(rec["energy_mean"]),
             float(got["energy_mean"]), 1.0)
    lm_want = committed["loss_model"]["multiscale"]
    lm_got = fresh["loss_model"]["multiscale"]
    gate("loss_model/ms_err", float(lm_want["err"]), float(lm_got["err"]),
         1e-3)
    return failures


def _run_robust_train(num_steps: int, artifact: str) -> dict:
    """Run the robust-training scenario smoke (tiny model, R=8,
    reliable baseline + churn+Byzantine with the trimmed-mean defense)
    and persist the summary metrics as `artifact`.  Deterministic:
    fixed model init, fixed synthetic stream, fixed failure seed."""
    import jax

    from benchmarks.common import save_artifact
    from repro.data import SyntheticLM
    from repro.dist import SyncConfig, SyncFailureModel
    from repro.models import Transformer
    from repro.models.config import ModelConfig
    from repro.optim import sgdm
    from repro.train import TrainScenario, run_train_scenarios

    R = 8
    cfg = ModelConfig(
        name="robust-gate", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        remat=False, dtype="float32",
    )
    model = Transformer(cfg, model_axis=1)
    params = model.init(jax.random.PRNGKey(0))
    data = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=R * 2, seed=7)
    scenarios = [
        TrainScenario("baseline", None, "mean", "reliable replicas"),
        TrainScenario(
            "churn_byzantine",
            SyncFailureModel(churn_fraction=0.125, byzantine_fraction=0.125,
                             byzantine_scale=10.0, seed=4),
            "trimmed_mean",
            "12.5% churn + 12.5% Byzantine (x10), trimmed-mean defense",
        ),
    ]
    res = run_train_scenarios(
        cfg, sgdm(), lambda s: 1e-2, SyncConfig("multiscale"), R,
        params, data, scenarios, num_steps=num_steps,
    )
    payload = {
        "R": R, "num_steps": num_steps,
        "scenarios": {
            r.scenario.name: {
                "final_loss": r.final_loss,
                "loss_drop": r.loss_drop,
                "survivor_error_final": r.survivor_error_final,
                "effective_replica_fraction_mean":
                    r.effective_replica_fraction_mean,
                "rejected_gradients_total": r.rejected_gradients_total,
            }
            for r in res
        },
    }
    save_artifact(artifact, payload)
    return payload


def check_robust_train(tolerance: float) -> list[str]:
    """Gate the robust-training smoke: per-scenario final loss,
    survivor consensus error (floor 1e-3 — a reliable baseline sits at
    ~0 where relative drift is noise), effective replica fraction, and
    rejected-gradient totals vs the committed `robust_train_smoke`
    artifact.  Drift means the failure injection, robust reduction, or
    degradation metrics changed."""
    from benchmarks.common import load_artifact

    committed = load_artifact(ROBUST_TRAIN)
    if committed is None:
        return [
            f"  {ROBUST_TRAIN}: committed artifact benchmarks/artifacts/"
            f"{ROBUST_TRAIN}.json is missing; run `python "
            f"tools/check_artifacts.py --robust-train-regen` and commit "
            f"the result"
        ]
    print(f"check_artifacts: re-running robust-train smoke "
          f"(R={committed['R']}, steps={committed['num_steps']}, "
          f"scenarios={sorted(committed['scenarios'])}) against "
          f"{ROBUST_TRAIN} (tolerance ±{tolerance:.0%})")
    fresh = _run_robust_train(
        int(committed["num_steps"]), f"{ROBUST_TRAIN}_check")
    failures = []

    def gate(label, want, got, floor):
        rel = abs(got - want) / max(abs(want), floor)
        status = "ok" if rel <= tolerance else "DRIFT"
        print(f"  {label}: committed={want:.4g} fresh={got:.4g} "
              f"rel={rel:+.1%} [{status}]")
        if rel > tolerance:
            failures.append(
                f"  {ROBUST_TRAIN} {label}: drifted {rel:.1%} "
                f"(committed {want:.4g} -> fresh {got:.4g}, "
                f"tolerance {tolerance:.0%})")

    floors = {
        "final_loss": 1.0,
        "loss_drop": 0.1,
        "survivor_error_final": 1e-3,
        "effective_replica_fraction_mean": 1e-2,
        "rejected_gradients_total": 1.0,
    }
    for name, rec in committed["scenarios"].items():
        got = fresh["scenarios"].get(name)
        if got is None:
            failures.append(
                f"  {ROBUST_TRAIN} scenario {name}: missing from the "
                "fresh run")
            continue
        for metric, floor in floors.items():
            gate(f"scenario/{name}/{metric}", float(rec[metric]),
                 float(got[metric]), floor)
    return failures


def check_large_n(tolerance: float) -> list[str]:
    """Gate the large-n CSR-path smoke (n=20k FI run) message count.

    Re-runs `benchmarks.large_n --smoke` at the committed artifact's
    exact profile into a scratch artifact and compares total messages.
    The run itself also re-asserts the reference-vs-vectorized overlap
    parity at n=2000, so plan-builder drift fails here too.
    """
    from benchmarks import large_n
    from benchmarks.common import load_artifact

    committed = load_artifact(LARGE_N)
    if committed is None:
        return [
            f"  {LARGE_N}: committed artifact benchmarks/artifacts/"
            f"{LARGE_N}.json is missing; run "
            f"`python -m benchmarks.large_n --smoke` and commit the result"
        ]
    overlap_n = int((committed.get("overlap") or {}).get("n", 2000))
    print(f"check_artifacts: re-running large-n smoke "
          f"(n={committed['n']}, scale={committed['fixed_ticks_scale']}, "
          f"backend={committed['backend']}, overlap_n={overlap_n}) against "
          f"{LARGE_N} (tolerance ±{tolerance:.0%})")
    try:
        large_n.run(
            n=int(committed["n"]), overlap_n=overlap_n,
            trials=int(committed["trials"]), eps=float(committed["eps"]),
            fixed_ticks_scale=float(committed["fixed_ticks_scale"]),
            backend=committed["backend"], artifact=f"{LARGE_N}_check",
        )
    except SystemExit as e:  # overlap-parity failure inside the benchmark
        return [f"  {LARGE_N}: {e}"]
    fresh = load_artifact(f"{LARGE_N}_check")
    failures = []
    want = float(committed["messages"][0])
    got = float(fresh["messages"][0])
    rel = abs(got - want) / max(want, 1.0)
    status = "ok" if rel <= tolerance else "DRIFT"
    print(f"  large_n@n{committed['n']}: committed={want:.0f} "
          f"fresh={got:.0f} rel={rel:+.1%} [{status}]")
    if rel > tolerance:
        failures.append(
            f"  {LARGE_N}@n{committed['n']}: messages drifted {rel:.1%} "
            f"(committed {want:.0f} -> fresh {got:.0f}, "
            f"tolerance {tolerance:.0%})")
    # graph_gen_s gate: the streamed builder's wall clock at the smoke
    # size, ±tolerance but with an absolute floor — sub-second timings
    # jitter tens of percent with host load, so only a drift that is
    # ALSO >= the floor in absolute seconds is a real builder regression
    want_g = committed.get("graph_gen_s")
    if want_g is not None:
        got_g = float(fresh["graph_gen_s"])
        want_g = float(want_g)
        rel_g = abs(got_g - want_g) / max(want_g, 1e-9)
        abs_g = abs(got_g - want_g)
        bad = rel_g > tolerance and abs_g >= GRAPH_GEN_FLOOR_S
        status = "DRIFT" if bad else "ok"
        print(f"  large_n@n{committed['n']}: graph_gen_s "
              f"committed={want_g:.3f}s fresh={got_g:.3f}s rel={rel_g:+.1%} "
              f"[{status}]")
        if bad:
            failures.append(
                f"  {LARGE_N}@n{committed['n']}: graph_gen_s drifted "
                f"{rel_g:.1%} ({want_g:.3f}s -> {got_g:.3f}s, tolerance "
                f"{tolerance:.0%} with {GRAPH_GEN_FLOOR_S}s floor)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="max relative drift of messages_mean per level count")
    ap.add_argument("--trials", type=int, default=None,
                    help="override trial count of the fresh run (defaults "
                         "to 3, the committed profile)")
    ap.add_argument("--skip-fig3", action="store_true",
                    help="gate only the fig2 artifact")
    ap.add_argument("--fig5", action="store_true",
                    help="also gate the fig5 failure-scenario smoke "
                         "(error + priced cost per scenario vs the "
                         "committed fig5_smoke artifact)")
    ap.add_argument("--fig5-only", action="store_true",
                    help="gate ONLY the fig5 failure-scenario smoke")
    ap.add_argument("--large-n", action="store_true",
                    help="also gate the large-n smoke (n=20k FI run; "
                         "slower, run under REPRO_BENCH_SMOKE=1)")
    ap.add_argument("--large-n-only", action="store_true",
                    help="gate ONLY the large-n smoke")
    ap.add_argument("--robust-train-only", action="store_true",
                    help="gate ONLY the robust-training scenario smoke "
                         "(survivor consensus error / loss / degradation "
                         "metrics vs the committed robust_train_smoke)")
    ap.add_argument("--robust-train-regen", action="store_true",
                    help="regenerate the committed robust_train_smoke "
                         "artifact in place (8 steps) and exit")
    args = ap.parse_args()

    from benchmarks import fig2_levels
    from benchmarks.common import load_artifact

    if args.robust_train_regen:
        _run_robust_train(8, ROBUST_TRAIN)
        print(f"check_artifacts: regenerated benchmarks/artifacts/"
              f"{ROBUST_TRAIN}.json — review and commit it")
        return 0

    if args.robust_train_only:
        failures = check_robust_train(args.tolerance)
        if failures:
            print("check_artifacts: FAIL — robust-train smoke drifted from "
                  "the committed artifact:")
            print("\n".join(failures))
            print("If the drift is intentional (algorithm change), "
                  "regenerate and commit: python tools/check_artifacts.py "
                  "--robust-train-regen")
            return 1
        print(f"check_artifacts: OK — robust-train smoke within "
              f"±{args.tolerance:.0%} of the committed artifact")
        return 0

    if args.large_n_only:
        failures = check_large_n(args.tolerance)
        if failures:
            print("check_artifacts: FAIL — large-n smoke drifted from the "
                  "committed artifact:")
            print("\n".join(failures))
            print("If the drift is intentional (algorithm change), "
                  "regenerate and commit: python -m benchmarks.large_n "
                  "--smoke")
            return 1
        print(f"check_artifacts: OK — large-n smoke within "
              f"±{args.tolerance:.0%} of the committed artifact")
        return 0

    if args.fig5_only:
        failures = check_fig5(args.tolerance)
        if failures:
            print("check_artifacts: FAIL — fig5 scenario smoke drifted from "
                  "the committed artifact:")
            print("\n".join(failures))
            print("If the drift is intentional (algorithm change), "
                  "regenerate and commit: python -m benchmarks.fig5_failures"
                  " --n 300 --trials 2 --scenario-trials 2 --ps 0.7,1.0 "
                  f"--artifact {FIG5}")
            return 1
        print(f"check_artifacts: OK — fig5 scenario smoke within "
              f"±{args.tolerance:.0%} of the committed artifact")
        return 0

    committed = load_artifact(COMMITTED)
    if committed is None:
        print(f"check_artifacts: FAIL — committed artifact "
              f"benchmarks/artifacts/{COMMITTED}.json is missing; run "
              f"`python -m benchmarks.run --only fig2` and commit the result")
        return 1

    ks = sorted(int(k) for k in committed["rows"])
    trials = args.trials if args.trials is not None else 3
    print(f"check_artifacts: re-running fig2 smoke "
          f"(n={committed['n']}, eps={committed['eps']}, trials={trials}, "
          f"k={ks[0]}..{ks[-1]}) against the committed artifact "
          f"(tolerance ±{args.tolerance:.0%})")
    fig2_levels.run(
        n=int(committed["n"]), trials=trials, eps=float(committed["eps"]),
        max_k=ks[-1], artifact=SCRATCH,
    )
    fresh = load_artifact(SCRATCH)

    failures = []
    for k in ks:
        want = float(committed["rows"][str(k)]["messages_mean"])
        got_row = fresh["rows"].get(k, fresh["rows"].get(str(k)))
        if got_row is None:
            failures.append(f"  k={k}: missing from the fresh run")
            continue
        got = float(got_row["messages_mean"])
        rel = abs(got - want) / max(want, 1.0)
        status = "ok" if rel <= args.tolerance else "DRIFT"
        print(f"  k={k}: committed={want:.0f} fresh={got:.0f} "
              f"rel={rel:+.1%} [{status}]")
        if rel > args.tolerance:
            failures.append(
                f"  k={k}: messages_mean drifted {rel:.1%} "
                f"(committed {want:.0f} -> fresh {got:.0f}, "
                f"tolerance {args.tolerance:.0%})"
            )

    if not args.skip_fig3:
        failures += check_fig3(args.tolerance)
    if args.fig5:
        failures += check_fig5(args.tolerance)
    if args.large_n:
        failures += check_large_n(args.tolerance)

    if failures:
        print("check_artifacts: FAIL — per-algorithm message counts drifted "
              "from the committed artifacts:")
        print("\n".join(failures))
        print("If the drift is intentional (algorithm change), regenerate "
              "and commit the artifacts: python -m benchmarks.run --only "
              "fig2 and REPRO_BENCH_SMOKE=1 tools/ci.sh for the fig3 smokes")
        return 1
    gated = "fig2" if args.skip_fig3 else "fig2 + fig3 smoke"
    if args.fig5:
        gated += " + fig5 scenario smoke"
    print(f"check_artifacts: OK — {gated} message counts within "
          f"±{args.tolerance:.0%} of the committed artifacts")
    return 0


if __name__ == "__main__":
    sys.exit(main())
